#!/usr/bin/env bash
# Trace-export smoke: drives the release binary's `serve-stdio` mode
# with `--trace-out`, submits one request, quits, then asserts the
# implicit `TRACED` report line appeared and that the written file is
# Chrome trace-event JSON carrying a request span with a terminal
# outcome.  tier1.sh runs this behind BENCH=1 TRACE_SMOKE=1; it is
# also runnable standalone after `cargo build --release`.
#
#   scripts/trace_smoke.sh

set -euo pipefail
cd "$(dirname "$0")/.."

BIN=./target/release/entquant
[[ -x "$BIN" ]] || { echo "trace smoke: build target/release/entquant first" >&2; exit 1; }

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT
trace="$tmp/trace.json"

out="$(printf 'SUBMIT 1 4 0102030405\nQUIT\n' \
    | "$BIN" serve-stdio --synthetic 4 --shards 2 --trace-out "$trace")"
echo "$out" | grep -q "^READY" || { echo "trace smoke: no READY"; echo "$out"; exit 1; }
echo "$out" | grep -q "^DONE 1 " || { echo "trace smoke: request incomplete"; echo "$out"; exit 1; }
echo "$out" | grep -q "^TRACED " || { echo "trace smoke: no TRACED line"; echo "$out"; exit 1; }
grep -q '"traceEvents"' "$trace" || { echo "trace smoke: not a Chrome trace: $trace"; exit 1; }
grep -q '"name":"request"' "$trace" || { echo "trace smoke: no request span"; exit 1; }
grep -q '"outcome":"done"' "$trace" || { echo "trace smoke: no terminal event"; exit 1; }
echo "trace smoke: OK ($(grep -c '"ph"' "$trace") event line(s))"
