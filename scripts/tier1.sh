#!/usr/bin/env bash
# Tier-1 verify gate (see ROADMAP.md): every PR must keep this green.
#
#   scripts/tier1.sh           # build + tests + lint + format check
#   scripts/tier1.sh --fast    # skip the release build (tests only)
#   BENCH=1 scripts/tier1.sh   # additionally smoke the tracked benches
#                              # (scripts/bench.sh -> BENCH_decode.json)
#   BENCH=1 TRACE_SMOKE=1 ...  # + trace-export smoke (scripts/trace_smoke.sh)
#
# Integration tests that need trained artifacts (`make artifacts`)
# self-skip with a note; the unit suites (ANS, container, parallel
# subsystem, corruption fuzz sweeps, shard-plan property tests, the
# fault-injection + scheduler stress suites) always run — seeded tests
# print their seed so a red run replays exactly.

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ "$FAST" == 0 ]]; then
    echo "== cargo build --release =="
    cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== static analysis (scripts/analyze.sh) =="
scripts/analyze.sh

echo "== cargo clippy (-D warnings) =="
if ! cargo clippy --version >/dev/null 2>&1; then
    echo "(clippy unavailable in this image; skipping lint gate)"
else
    # entquant + the entlint/chaosbench tools; NOT --workspace (the
    # vendored stubs are third-party-shaped and not held to this gate)
    cargo clippy -q -p entquant -p entlint -p chaosbench --all-targets -- -D warnings
fi

echo "== cargo fmt --check =="
if ! cargo fmt --version >/dev/null 2>&1; then
    echo "(rustfmt unavailable in this image; skipping format check)"
else
    cargo fmt --check -p entquant -p entlint -p chaosbench
fi

if [[ "${BENCH:-0}" == 1 ]]; then
    echo "== bench smoke (BENCH=1) =="
    BENCH_SMOKE=1 scripts/bench.sh
    echo "== chaos smoke (BENCH=1) =="
    CHAOS_SMOKE=1 scripts/chaos.sh
    if [[ "${TRACE_SMOKE:-0}" == 1 ]]; then
        echo "== trace smoke (TRACE_SMOKE=1) =="
        scripts/trace_smoke.sh
    fi
fi

echo "tier-1: OK"
