#!/usr/bin/env bash
# Process-level chaos gate (tools/chaosbench): spawns the release
# binary's `serve-stdio` mode as child processes and drives seeded
# open-loop scenarios against it strictly from the outside — steady
# state, a 2x overload burst into a bounded queue, a scripted fault
# storm under the recovery supervisor, and a SIGKILL + cold restart
# mid-trace.  Pass criteria are timing-independent (ledger balance,
# byte identity against a single-engine reference, shed evidence with
# retry hints); latency percentiles are recorded, not judged.  Emits
# BENCH_chaos.json (BENCH_chaos.smoke.json under CHAOS_SMOKE=1, which
# also skips the inter-arrival sleeps for a fast deterministic tier —
# this is what tier1.sh runs behind BENCH=1).
#
#   scripts/chaos.sh               # full scenarios
#   CHAOS_SMOKE=1 scripts/chaos.sh # fast deterministic smoke tier

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release (server binary + chaos harness) =="
cargo build --release -p entquant -p chaosbench

echo "== chaosbench (CHAOS_SMOKE=${CHAOS_SMOKE:-0}) =="
./target/release/chaosbench
