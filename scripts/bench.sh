#!/usr/bin/env bash
# Tracked benches.  Runs the hand-rolled bench binaries and captures:
#   * BENCH_decode.json — decode trajectory (MB/s for the seed scalar
#     path, chunk-parallel threads=N, and the fused bitstream->f32 path)
#   * BENCH_serve.json  — serve trajectory (tokens/s and p50
#     time-to-first-token at 1/2/4 shards under a synthetic request
#     trace through the continuous-batching scheduler)
#
#   scripts/bench.sh                 # full run
#   BENCH_SMOKE=1 scripts/bench.sh   # fast smoke (tier1.sh BENCH=1 hook)
#   BENCH_JSON=/path.json            # override the decode JSON path
#   BENCH_SERVE_JSON=/path.json      # override the serve JSON path

set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench decode
cargo bench --bench encoder
cargo bench --bench serve

# smoke runs write *.smoke.json so they never clobber the tracked
# full-run trajectories
if [[ "${BENCH_SMOKE:-0}" == 1 ]]; then
    DEFAULT_JSON=BENCH_decode.smoke.json
    DEFAULT_SERVE_JSON=BENCH_serve.smoke.json
else
    DEFAULT_JSON=BENCH_decode.json
    DEFAULT_SERVE_JSON=BENCH_serve.json
fi
echo
echo "== ${BENCH_JSON:-$DEFAULT_JSON} =="
cat "${BENCH_JSON:-$DEFAULT_JSON}"
echo
echo "== ${BENCH_SERVE_JSON:-$DEFAULT_SERVE_JSON} =="
cat "${BENCH_SERVE_JSON:-$DEFAULT_SERVE_JSON}"
