#!/usr/bin/env bash
# Tracked decode/encode benches.  Runs the hand-rolled bench binaries
# and captures the decode trajectory to BENCH_decode.json (MB/s for the
# seed scalar path, chunk-parallel threads=N, and the fused
# bitstream->f32 path).
#
#   scripts/bench.sh                 # full run
#   BENCH_SMOKE=1 scripts/bench.sh   # fast smoke (tier1.sh BENCH=1 hook)
#   BENCH_JSON=/path.json            # override the JSON output path

set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench decode
cargo bench --bench encoder

# smoke runs write BENCH_decode.smoke.json so they never clobber the
# tracked full-run trajectory
if [[ "${BENCH_SMOKE:-0}" == 1 ]]; then
    DEFAULT_JSON=BENCH_decode.smoke.json
else
    DEFAULT_JSON=BENCH_decode.json
fi
echo
echo "== ${BENCH_JSON:-$DEFAULT_JSON} =="
cat "${BENCH_JSON:-$DEFAULT_JSON}"
