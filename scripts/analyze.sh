#!/usr/bin/env bash
# Static-analysis + schedule-exploration gate (tier-1 stage; also
# runnable standalone):
#
#   scripts/analyze.sh                      # entlint + schedule sweep smoke
#   ENTQ_SCHED_SEEDS=500 scripts/analyze.sh # wider sweep
#   ENTQ_SCHED_SEED=12345 scripts/analyze.sh# replay one printed seed exactly
#   MIRI=1 scripts/analyze.sh               # additionally try cargo miri
#   TSAN=1 scripts/analyze.sh               # additionally try -Zsanitizer=thread
#
# entlint is deny-by-default: any rule violation in rust/src exits
# non-zero, and the only escape is an inline
# `// entlint: allow(<rule>) — <reason>` whose written reason entlint
# itself audits.  The miri/tsan stages self-skip when the image's
# toolchain lacks them (both need nightly components the offline image
# does not ship); they are belt-and-braces on images that have them.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== entlint (deny-by-default, rust/src) =="
cargo run -q -p entlint -- rust/src

echo "== entlint (deny-by-default, tools/chaosbench/src) =="
cargo run -q -p entlint -- tools/chaosbench/src

echo "== entlint self-tests (fixture corpus + self-clean) =="
cargo test -q -p entlint

echo "== schedule-exploration sweep (parallel/pool invariants) =="
# ENTQ_SCHED_SEEDS seeds (default 200), each printed for exact replay via
# ENTQ_SCHED_SEED=<seed>; the sweep perturbs every pool acquisition point
# with seeded yields/delays and re-asserts exactly-once / first-error /
# stop-join invariants on every explored schedule.
cargo test -q -p entquant --lib parallel::sched -- --nocapture

echo "== schedule-exploration sweep (serve lane state machine) =="
# same seed controls; the sweep perturbs admission/speculation/adoption/
# expiry/shed against the driver loop and re-asserts the ledger, the
# retry hints, the no-lane-leak gauge, and byte identity vs the
# unperturbed single-shard reference on every explored schedule.
cargo test -q -p entquant --lib serve::scheduler::sweep -- --nocapture

if [[ "${MIRI:-0}" == 1 ]]; then
    echo "== cargo miri (parallel suites) =="
    if cargo miri --version >/dev/null 2>&1; then
        cargo miri test -p entquant --lib parallel::
    else
        echo "(miri unavailable in this image; skipping)"
    fi
fi

if [[ "${TSAN:-0}" == 1 ]]; then
    echo "== thread sanitizer (parallel suites) =="
    if rustc -Zhelp >/dev/null 2>&1 && rustc --print target-list >/dev/null 2>&1 \
        && rustc +nightly --version >/dev/null 2>&1; then
        RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -p entquant --lib parallel:: \
            --target "$(rustc -vV | sed -n 's/^host: //p')"
    else
        echo "(nightly -Zsanitizer=thread unavailable in this image; skipping)"
    fi
fi

echo "analyze: OK"
