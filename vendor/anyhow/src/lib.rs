//! Offline drop-in subset of the `anyhow` crate (the container image has
//! no crates.io access).  Implements exactly the surface this repo uses:
//! `Error`, `Result<T>`, `anyhow!`, `bail!`, `ensure!`, and the
//! `Context` extension trait.  Semantics match upstream closely enough
//! that swapping in the real crate is a one-line Cargo.toml change.

use std::error::Error as StdError;
use std::fmt;

/// A string-backed error with an optional cause chain, mirroring
/// `anyhow::Error` for the APIs used in this repo.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { msg: msg.to_string(), source: None }
    }

    fn with_source(
        msg: String,
        source: Box<dyn StdError + Send + Sync + 'static>,
    ) -> Self {
        Error { msg, source: Some(source) }
    }

    /// Wrap this error with an outer context message (the `Context`
    /// machinery; keeps the inner message in the chain).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        let inner = ChainedError { msg: self.msg, source: self.source };
        Error { msg: context.to_string(), source: Some(Box::new(inner)) }
    }

    fn render(&self, f: &mut fmt::Formatter<'_>, chain: bool) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if chain {
            let mut src: Option<&(dyn StdError + 'static)> =
                self.source.as_ref().map(|b| b.as_ref() as &(dyn StdError + 'static));
            while let Some(s) = src {
                write!(f, ": {s}")?;
                src = s.source();
            }
        }
        Ok(())
    }
}

/// Internal node used to keep `context()` chains walkable via
/// `std::error::Error::source`.
struct ChainedError {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl fmt::Display for ChainedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for ChainedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl StdError for ChainedError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_ref().map(|b| b.as_ref() as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` prints the outermost message; `{e:#}` prints the chain,
        // matching upstream anyhow.
        self.render(f, f.alternate())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.render(f, true)
    }
}

// Like upstream: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion legal.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::with_source(e.to_string(), Box::new(e))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// `Result::context` / `with_context` extension, as in upstream anyhow.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// `Error` does not implement StdError (see above), so contextualizing
// an already-anyhow Result needs its own impl — same split as upstream.
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("value {x} and {}", 4);
        assert_eq!(e.to_string(), "value 3 and 4");
        let s = String::from("owned");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("reached end")
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "reached end");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("missing"));
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading /tmp/x".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "reading /tmp/x");
        assert_eq!(format!("{e:#}"), "reading /tmp/x: missing");
        assert!(format!("{e:?}").contains("missing"));
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let n: Option<u8> = None;
        assert_eq!(n.context("absent").unwrap_err().to_string(), "absent");
    }
}
