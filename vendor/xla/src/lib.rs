//! Compile-time stub of the `xla` PJRT bindings used by
//! `entquant::runtime`.  The offline image cannot build the real
//! bindings (no libxla), so this crate keeps the serving stack
//! compiling; `PjRtClient::cpu()` reports the backend as unavailable at
//! runtime, and every caller already degrades gracefully (runtime tests
//! and benches skip when artifacts / the backend are missing).
//!
//! Swap in the real bindings by pointing the `xla` path dependency at a
//! build with PJRT support; the API surface below matches it.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: entquant was built against the vendored `xla` stub \
         (vendor/xla); point the Cargo `xla` dependency at real PJRT bindings to serve"
            .to_string(),
    )
}

/// Element types a `Literal` can carry (subset used by entquant).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}
