//! Process-level chaos harness for the entquant serve stack.
//!
//! Spawns the release binary's `serve-stdio` mode as a child process
//! and drives it strictly from the outside — seeded open-loop Poisson
//! arrivals over stdin, events read back over stdout, faults injected
//! via `--fault-shard/--fault-step`, and one scenario that SIGKILLs the
//! whole server mid-trace and cold-restarts it.  Nothing is shared with
//! the server (std only, separate process), so a server-side bug cannot
//! corrupt the judge.
//!
//! Scenarios (each against a fresh server):
//!   steady         gentle arrivals, no bounds — zero shed, zero failed
//!   overload_burst ~2x arrivals into a bounded queue + step budgets —
//!                  must shed with retry hints, never panic, and every
//!                  admitted request must reach a terminal state
//!   fault_storm    scripted shard kill under a supervisor with spares —
//!                  reroute + auto-rejoin visible in server STATS
//!   kill9_restart  SIGKILL the server mid-decode, cold-restart, resubmit
//!                  the lost half — everything completes
//!
//! Every `DONE` output in every scenario must be byte-identical to a
//! single-engine unbounded reference run; every `EXPIRED` output must
//! be a prefix of it.  Pass criteria are timing-independent (ledger
//! balance + byte identity + shed evidence); latency numbers are
//! recorded, not judged.  Emits `BENCH_chaos.json`
//! (`BENCH_chaos.smoke.json` under `CHAOS_SMOKE=1`, which also skips
//! the inter-arrival sleeps; `CHAOS_JSON` overrides the path).
//!
//! Each load scenario also pulls the server's tick-domain trace
//! (`--trace-out`, written as Chrome trace-event JSON after the drain)
//! into `trace_chaos_<scenario>.json` — load one in Perfetto to see
//! request spans, lane occupancy, and shard lifecycle side by side.
//! The fault-storm trace is judged, not just recorded: some request's
//! span must contain a reroute instant (a request that was in flight
//! while its shard's range moved, and still completed).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

/// Server startup compresses a synthetic checkpoint in-process.
const READY_TIMEOUT: Duration = Duration::from_secs(180);
/// Ceiling on any single wait for the next server event.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(300);

// ------------------------------------------------------------ prng

/// splitmix64 — the same deterministic generator the repo's seeded
/// harnesses use, so a scenario replays exactly from its seed.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

// ------------------------------------------------------------ trace

#[derive(Clone)]
struct Request {
    cid: String,
    prompt_hex: String,
    max_new: usize,
}

fn hex(bytes: &[u8]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(s, "{b:02x}");
    }
    s
}

/// One master trace per run: every scenario submits a prefix of it, so
/// a single reference run maps every cid to its expected output.
fn master_trace(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let len = 2 + (rng.next_u64() % 14) as usize;
            let prompt: Vec<u8> = (0..len).map(|_| (rng.next_u64() % 64) as u8).collect();
            let max_new = 2 + (rng.next_u64() % 7) as usize;
            Request { cid: format!("r{i}"), prompt_hex: hex(&prompt), max_new }
        })
        .collect()
}

// ------------------------------------------------------------ server

/// A spawned `entquant serve-stdio` child: line protocol over pipes,
/// stdout drained by a dedicated reader thread so the harness never
/// blocks on a dead or wedged server.
struct Server {
    child: Child,
    stdin: Option<ChildStdin>,
    rx: Receiver<String>,
    ready_ms: f64,
    shards: usize,
}

impl Server {
    fn spawn(bin: &str, n_layers: usize, extra: &[&str]) -> Server {
        let t0 = Instant::now();
        let mut child = Command::new(bin)
            .arg("serve-stdio")
            .args(["--synthetic", &n_layers.to_string()])
            .args(extra)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .unwrap_or_else(|e| panic!("spawning {bin}: {e} (build with `cargo build --release`)"));
        let stdout = child.stdout.take().expect("child stdout");
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        // entlint: allow(no-stray-threads) — blocking pipe reader decoupling the
        // judge from a wedged or SIGKILLed server; this harness is not served code
        std::thread::spawn(move || {
            for line in BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if tx.send(line).is_err() {
                    break;
                }
            }
        });
        let shards = loop {
            match rx.recv_timeout(READY_TIMEOUT) {
                Ok(line) => {
                    if let Some(rest) = line.strip_prefix("READY ") {
                        break rest.trim().parse::<usize>().expect("READY shard count");
                    }
                }
                Err(e) => panic!("no READY from {bin} within {READY_TIMEOUT:?}: {e}"),
            }
        };
        let stdin = child.stdin.take();
        Server { child, stdin, rx, ready_ms: t0.elapsed().as_secs_f64() * 1e3, shards }
    }

    /// Best-effort line write: a SIGKILLed server tears the pipe down
    /// mid-scenario by design, and the ledger checks catch any request
    /// that was genuinely lost.
    fn send(&mut self, line: &str) {
        if let Some(w) = self.stdin.as_mut() {
            let _ = writeln!(w, "{line}");
            let _ = w.flush();
        }
    }

    fn submit(&mut self, r: &Request) {
        self.send(&format!("SUBMIT {} {} {}", r.cid, r.max_new, r.prompt_hex));
    }

    fn kill9(&mut self) {
        drop(self.stdin.take());
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Close stdin and block until the child exits; true iff it exited
    /// zero (no panic, no abort) — a hard pass criterion everywhere
    /// except the SIGKILL phase.
    fn wait_success(mut self) -> bool {
        drop(self.stdin.take());
        self.child.wait().map(|s| s.success()).unwrap_or(false)
    }
}

// ------------------------------------------------------------ judge

#[derive(Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Pending,
    Admitted,
    Shed,
    Done,
    Expired,
    Failed,
    Cancelled,
}

struct ReqState {
    submitted_at: Instant,
    ttft_ms: Option<f64>,
    outcome: Outcome,
    output_hex: String,
    retry_after: u64,
}

#[derive(Default)]
struct Tracker {
    states: HashMap<String, ReqState>,
    admissions: usize,
    stats: Option<String>,
}

impl Tracker {
    fn mark_submitted(&mut self, cid: &str) {
        self.states.insert(
            cid.to_string(),
            ReqState {
                submitted_at: Instant::now(),
                ttft_ms: None,
                outcome: Outcome::Pending,
                output_hex: String::new(),
                retry_after: 0,
            },
        );
    }

    /// Absorb one server event line; true once STATS has arrived.
    fn apply(&mut self, line: &str) -> bool {
        if let Some(json) = line.strip_prefix("STATS ") {
            self.stats = Some(json.to_string());
            return true;
        }
        let mut it = line.split_whitespace();
        let (Some(ev), Some(cid)) = (it.next(), it.next()) else { return false };
        let Some(st) = self.states.get_mut(cid) else { return false };
        match ev {
            "ADMITTED" => {
                st.outcome = Outcome::Admitted;
                self.admissions += 1;
            }
            "SHED" => {
                st.outcome = Outcome::Shed;
                st.retry_after = it.next().and_then(|v| v.parse().ok()).unwrap_or(0);
            }
            "FIRST" => st.ttft_ms = Some(st.submitted_at.elapsed().as_secs_f64() * 1e3),
            "DONE" => {
                st.outcome = Outcome::Done;
                st.output_hex = it.next().unwrap_or("").to_string();
            }
            "EXPIRED" => {
                st.outcome = Outcome::Expired;
                st.output_hex = it.next().unwrap_or("").to_string();
            }
            "FAILED" => st.outcome = Outcome::Failed,
            "CANCELLED" => st.outcome = Outcome::Cancelled,
            _ => {}
        }
        false
    }

    fn count(&self, o: Outcome) -> usize {
        self.states.values().filter(|s| s.outcome == o).count()
    }

    fn ttfts(&self) -> Vec<f64> {
        self.states.values().filter_map(|s| s.ttft_ms).collect()
    }
}

struct Scenario {
    name: &'static str,
    requests: usize,
    tracker: Tracker,
    wall_s: f64,
    restart_ready_ms: f64,
    server_ok: bool,
    /// file name of the pulled Chrome trace, when the scenario asked
    /// for one (`--trace-out`)
    trace_file: Option<String>,
}

// ------------------------------------------------------------ runners

/// Open-loop load: submit the trace with seeded exponential gaps (mean
/// `mean_gap_ms`; 0 = back-to-back burst), QUIT, then read events until
/// the terminal STATS line.  `trace_out` makes the server write its
/// Chrome trace there after the drain (it answers `TRACED` before
/// `STATS`).
#[allow(clippy::too_many_arguments)] // a scenario is one flat knob list
fn run_open_loop(
    name: &'static str,
    bin: &str,
    n_layers: usize,
    extra: &[&str],
    trace: &[Request],
    mean_gap_ms: f64,
    seed: u64,
    trace_out: Option<&str>,
) -> Scenario {
    let mut args: Vec<&str> = extra.to_vec();
    if let Some(p) = trace_out {
        args.push("--trace-out");
        args.push(p);
    }
    let mut srv = Server::spawn(bin, n_layers, &args);
    println!("  [{name}] server up: {} shard(s), ready in {:.0} ms", srv.shards, srv.ready_ms);
    let mut tr = Tracker::default();
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    for r in trace {
        if mean_gap_ms > 0.0 {
            let gap_ms = -mean_gap_ms * (1.0 - rng.uniform()).ln();
            std::thread::sleep(Duration::from_micros((gap_ms * 1e3) as u64));
        }
        tr.mark_submitted(&r.cid);
        srv.submit(r);
        while let Ok(line) = srv.rx.try_recv() {
            tr.apply(&line);
        }
    }
    srv.send("QUIT");
    loop {
        match srv.rx.recv_timeout(DRAIN_TIMEOUT) {
            Ok(line) => {
                if tr.apply(&line) {
                    break;
                }
            }
            Err(e) => panic!("[{name}] server went quiet before STATS: {e}"),
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let server_ok = srv.wait_success();
    let trace_file =
        trace_out.map(|p| p.rsplit('/').next().unwrap_or(p).to_string());
    Scenario {
        name,
        requests: trace.len(),
        tracker: tr,
        wall_s,
        restart_ready_ms: 0.0,
        server_ok,
        trace_file,
    }
}

/// SIGKILL mid-decode, then cold-restart and resubmit everything the
/// dead server never finished plus a second wave.
fn run_kill9(bin: &str, n_layers: usize, first: &[Request], second: &[Request]) -> Scenario {
    let name = "kill9_restart";
    let mut srv = Server::spawn(bin, n_layers, &["--shards", "2"]);
    println!("  [{name}] server up: {} shard(s), ready in {:.0} ms", srv.shards, srv.ready_ms);
    let mut tr = Tracker::default();
    let t0 = Instant::now();
    for r in first {
        tr.mark_submitted(&r.cid);
        srv.submit(r);
    }
    // wait until decode is demonstrably underway (a first token or a
    // completion), then SIGKILL with requests still in flight
    while tr.ttfts().is_empty() && tr.count(Outcome::Done) == 0 {
        match srv.rx.recv_timeout(DRAIN_TIMEOUT) {
            Ok(line) => {
                tr.apply(&line);
            }
            Err(e) => panic!("[{name}] no progress before the kill: {e}"),
        }
    }
    srv.kill9();
    while let Ok(line) = srv.rx.try_recv() {
        tr.apply(&line);
    }
    let survivors = tr.count(Outcome::Done);
    println!("  [{name}] SIGKILL delivered; {survivors} request(s) had completed");

    let mut srv2 = Server::spawn(bin, n_layers, &["--shards", "2"]);
    let restart_ready_ms = srv2.ready_ms;
    println!("  [{name}] cold restart READY in {restart_ready_ms:.0} ms");
    let lost: Vec<&Request> =
        first.iter().filter(|r| tr.states[&r.cid].outcome != Outcome::Done).collect();
    for r in lost.iter().copied().chain(second.iter()) {
        tr.mark_submitted(&r.cid);
        srv2.submit(r);
    }
    srv2.send("QUIT");
    loop {
        match srv2.rx.recv_timeout(DRAIN_TIMEOUT) {
            Ok(line) => {
                if tr.apply(&line) {
                    break;
                }
            }
            Err(e) => panic!("[{name}] restarted server went quiet before STATS: {e}"),
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let server_ok = srv2.wait_success();
    Scenario {
        name,
        requests: first.len() + second.len(),
        tracker: tr,
        wall_s,
        restart_ready_ms,
        server_ok,
        trace_file: None,
    }
}

// ------------------------------------------------------------ checks

/// Every completed output must be byte-identical to the single-engine
/// reference; every expired output must be a prefix of it.
fn check_identity(sc: &Scenario, reference: &HashMap<String, String>, v: &mut Vec<String>) {
    for (cid, st) in &sc.tracker.states {
        match st.outcome {
            Outcome::Done => {
                if reference.get(cid) != Some(&st.output_hex) {
                    v.push(format!("{}: {cid} diverged from the single-engine reference", sc.name));
                }
            }
            Outcome::Expired => {
                let r = reference.get(cid).map(String::as_str).unwrap_or("");
                if !r.starts_with(st.output_hex.as_str()) {
                    v.push(format!("{}: expired {cid} is not a reference prefix", sc.name));
                }
            }
            _ => {}
        }
    }
}

fn check_server_ok(sc: &Scenario, v: &mut Vec<String>) {
    if !sc.server_ok {
        v.push(format!("{}: server exited non-zero (panic or abort)", sc.name));
    }
}

/// Pull one numeric field out of the server's STATS json (flat keys,
/// no nesting — a full parser would be the only dependency).
fn stat_f64(stats: &str, key: &str) -> f64 {
    let pat = format!("\"{key}\": ");
    let Some(i) = stats.find(&pat) else { return 0.0 };
    let rest = &stats[i + pat.len()..];
    let end =
        rest.find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-')).unwrap_or(rest.len());
    rest[..end].parse().unwrap_or(0.0)
}

fn stat_u64(stats: &str, key: &str) -> u64 {
    stat_f64(stats, key) as u64
}

/// Pull one numeric field out of a single Chrome trace-event line
/// (the exporter writes one event per line, unquoted integer values).
fn line_u64(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat)? + pat.len();
    let rest = &line[i..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// The fault-storm trace judgment: the pulled Chrome trace must show a
/// `reroute` instant whose tick falls *inside* some request's
/// `B`..`E` span on the requests track — a request that was in flight
/// while its shard's block range moved to a survivor, and still
/// reached a terminal state.
fn check_cross_shard_trace(name: &str, path: &str, v: &mut Vec<String>) {
    let json = match std::fs::read_to_string(path) {
        Ok(j) => j,
        Err(e) => {
            v.push(format!("{name}: trace {path} unreadable: {e}"));
            return;
        }
    };
    let mut reroute_ts: Vec<u64> = Vec::new();
    let mut spans: HashMap<u64, (Option<u64>, Option<u64>)> = HashMap::new();
    for line in json.lines() {
        if line.contains("\"name\":\"reroute\"") {
            if let Some(ts) = line_u64(line, "ts") {
                reroute_ts.push(ts);
            }
        } else if line.contains("\"name\":\"request\"") && line.contains("\"pid\":0") {
            let (Some(tid), Some(ts)) = (line_u64(line, "tid"), line_u64(line, "ts")) else {
                continue;
            };
            let span = spans.entry(tid).or_insert((None, None));
            if line.contains("\"ph\":\"B\"") {
                span.0 = Some(ts);
            } else if line.contains("\"ph\":\"E\"") {
                span.1 = Some(ts);
            }
        }
    }
    if reroute_ts.is_empty() {
        v.push(format!("{name}: no reroute event in the pulled trace {path}"));
        return;
    }
    let crossed = spans.values().any(|&(b, e)| match (b, e) {
        (Some(b), Some(e)) => reroute_ts.iter().any(|&t| b <= t && t <= e),
        _ => false,
    });
    if !crossed {
        v.push(format!("{name}: no request span crosses a reroute tick in {path}"));
    }
}

// ------------------------------------------------------------ report

fn scenario_json(sc: &Scenario) -> String {
    let mut tt = sc.tracker.ttfts();
    tt.sort_by(f64::total_cmp);
    let p = |q: f64| -> f64 {
        if tt.is_empty() {
            return 0.0;
        }
        let rank = ((q * tt.len() as f64).ceil() as usize).clamp(1, tt.len());
        tt[rank - 1]
    };
    let stats = sc.tracker.stats.clone().unwrap_or_else(|| "null".into());
    let trace = match &sc.trace_file {
        Some(f) => format!("\"{f}\""),
        None => "null".into(),
    };
    // hist_* percentiles come from the server's own log2-histogram
    // metrics (tick-side truth); the bare p* ttft fields stay the
    // harness's outside-the-process wall-clock view
    format!(
        concat!(
            "    {{\"scenario\": \"{}\", \"requests\": {}, \"admitted\": {}, \"shed\": {}, ",
            "\"done\": {}, \"expired\": {}, \"failed\": {}, \"wall_s\": {:.3}, ",
            "\"restart_ready_ms\": {:.1}, \"p50_ttft_ms\": {:.2}, \"p99_ttft_ms\": {:.2}, ",
            "\"p999_ttft_ms\": {:.2}, \"hist_p50_ttft_ms\": {:.3}, \"hist_p99_ttft_ms\": {:.3}, ",
            "\"hist_p999_ttft_ms\": {:.3}, \"hist_p50_step_us\": {:.3}, ",
            "\"hist_p99_step_us\": {:.3}, \"hist_p999_step_us\": {:.3}, ",
            "\"tokens_per_s\": {:.1}, \"trace\": {},\n     \"server\": {}}}"
        ),
        sc.name,
        sc.requests,
        sc.tracker.admissions,
        sc.tracker.count(Outcome::Shed),
        sc.tracker.count(Outcome::Done),
        sc.tracker.count(Outcome::Expired),
        sc.tracker.count(Outcome::Failed),
        sc.wall_s,
        sc.restart_ready_ms,
        p(0.50),
        p(0.99),
        p(0.999),
        stat_f64(&stats, "p50_ttft_ms"),
        stat_f64(&stats, "p99_ttft_ms"),
        stat_f64(&stats, "p999_ttft_ms"),
        stat_f64(&stats, "p50_step_us"),
        stat_f64(&stats, "p99_step_us"),
        stat_f64(&stats, "p999_step_us"),
        stat_f64(&stats, "tokens_per_s"),
        trace,
        stats,
    )
}

fn report(sc: &Scenario) {
    println!(
        "  [{}] {} requests: {} done, {} shed, {} expired, {} failed in {:.2}s",
        sc.name,
        sc.requests,
        sc.tracker.count(Outcome::Done),
        sc.tracker.count(Outcome::Shed),
        sc.tracker.count(Outcome::Expired),
        sc.tracker.count(Outcome::Failed),
        sc.wall_s,
    );
}

// ------------------------------------------------------------ main

fn main() {
    let smoke = std::env::var("CHAOS_SMOKE").map(|v| v == "1").unwrap_or(false);
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/../..");
    let default_bin = format!("{root}/target/release/entquant");
    let bin = std::env::var("ENTQUANT_BIN").unwrap_or(default_bin);
    let n_layers = if smoke { 4 } else { 6 };
    let n_master = if smoke { 32 } else { 64 };
    let trace = master_trace(n_master, 0xC0FFEE);
    let (steady_n, overload_n, kill_n) = if smoke { (16, 24, 16) } else { (32, 48, 32) };
    let fault_n = 24usize;
    let gap = |full_ms: f64| if smoke { 0.0 } else { full_ms };
    let suffix = if smoke { ".smoke" } else { "" };
    let trace_path = |n: &str| format!("{root}/trace_chaos_{n}{suffix}.json");
    let mut v: Vec<String> = Vec::new();

    // every DONE below is judged against this one: a single engine, no
    // bounds, no faults — the plain sequential truth
    println!("== reference: 1 shard, unbounded ({n_master} requests, {n_layers} layers) ==");
    let refr =
        run_open_loop("reference", &bin, n_layers, &["--shards", "1"], &trace, 0.0, 1, None);
    report(&refr);
    if refr.tracker.count(Outcome::Done) != n_master {
        v.push("reference: not every request completed".into());
    }
    check_server_ok(&refr, &mut v);
    let reference: HashMap<String, String> = refr
        .tracker
        .states
        .iter()
        .filter(|(_, s)| s.outcome == Outcome::Done)
        .map(|(c, s)| (c.clone(), s.output_hex.clone()))
        .collect();

    println!("== scenario: steady ({steady_n} requests, gentle arrivals) ==");
    let steady_trace = trace_path("steady");
    let steady = run_open_loop(
        "steady",
        &bin,
        n_layers,
        &["--shards", "2"],
        &trace[..steady_n],
        gap(25.0),
        2,
        Some(&steady_trace),
    );
    report(&steady);
    if steady.tracker.count(Outcome::Shed) != 0 {
        v.push("steady: shed under gentle load with no bounds configured".into());
    }
    if steady.tracker.count(Outcome::Done) != steady_n {
        v.push("steady: not every request completed".into());
    }
    check_identity(&steady, &reference, &mut v);
    check_server_ok(&steady, &mut v);

    println!("== scenario: overload_burst ({overload_n} requests into a bounded queue) ==");
    let overload_args: &[&str] = &[
        "--shards",
        "2",
        "--max-queue-depth",
        "8",
        "--max-inflight-tokens",
        "96",
        "--step-budget",
        "12",
    ];
    let overload_trace = trace_path("overload_burst");
    let ov = run_open_loop(
        "overload_burst",
        &bin,
        n_layers,
        overload_args,
        &trace[..overload_n],
        gap(1.0),
        3,
        Some(&overload_trace),
    );
    report(&ov);
    if ov.tracker.count(Outcome::Shed) == 0 {
        v.push("overload_burst: the bounded queue never shed".into());
    }
    let shed_hintless =
        ov.tracker.states.values().any(|s| s.outcome == Outcome::Shed && s.retry_after == 0);
    if shed_hintless {
        v.push("overload_burst: a shed response carried no retry_after_steps hint".into());
    }
    if ov.tracker.count(Outcome::Failed) != 0 {
        v.push("overload_burst: requests failed (overload must shed or expire, not error)".into());
    }
    let non_terminal = ov.tracker.count(Outcome::Pending) + ov.tracker.count(Outcome::Admitted);
    if non_terminal != 0 {
        v.push(format!("overload_burst: {non_terminal} admitted request(s) never terminated"));
    }
    check_identity(&ov, &reference, &mut v);
    check_server_ok(&ov, &mut v);

    println!("== scenario: fault_storm ({fault_n} requests, scripted shard kill + spares) ==");
    let fault_args: &[&str] = &[
        "--shards",
        "2",
        "--fault-shard",
        "1",
        "--fault-step",
        "3",
        "--supervisor-spares",
        "2",
        "--evict-after",
        "1",
    ];
    let fault_trace = trace_path("fault_storm");
    let fs = run_open_loop(
        "fault_storm",
        &bin,
        n_layers,
        fault_args,
        &trace[..fault_n],
        gap(5.0),
        4,
        Some(&fault_trace),
    );
    report(&fs);
    check_cross_shard_trace("fault_storm", &fault_trace, &mut v);
    let fstats = fs.tracker.stats.clone().unwrap_or_default();
    if stat_u64(&fstats, "reroutes") == 0 {
        v.push("fault_storm: the scripted fault produced no reroute".into());
    }
    if stat_u64(&fstats, "rejoins") == 0 {
        v.push("fault_storm: the supervisor never rejoined a spare".into());
    }
    if fs.tracker.count(Outcome::Done) != fault_n || fs.tracker.count(Outcome::Failed) != 0 {
        v.push("fault_storm: requests were lost to the fault".into());
    }
    check_identity(&fs, &reference, &mut v);
    check_server_ok(&fs, &mut v);

    println!("== scenario: kill9_restart ({kill_n} requests, SIGKILL mid-trace) ==");
    let half = kill_n / 2;
    let k9 = run_kill9(&bin, n_layers, &trace[..half], &trace[half..kill_n]);
    report(&k9);
    if k9.tracker.count(Outcome::Done) != kill_n {
        v.push("kill9_restart: not every request completed after the cold restart".into());
    }
    if k9.restart_ready_ms <= 0.0 {
        v.push("kill9_restart: restart READY latency was not observed".into());
    }
    check_identity(&k9, &reference, &mut v);
    check_server_ok(&k9, &mut v);

    // tracked artifact
    let scenarios = [&steady, &ov, &fs, &k9];
    let body: Vec<String> = scenarios.iter().map(|s| scenario_json(s)).collect();
    let json = format!(
        "{{\n  \"bench\": \"chaos\",\n  \"smoke\": {smoke},\n  \"n_layers\": {n_layers},\n  \"scenarios\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    let default_name = if smoke { "BENCH_chaos.smoke.json" } else { "BENCH_chaos.json" };
    let path = std::env::var("CHAOS_JSON").unwrap_or_else(|_| format!("{root}/{default_name}"));
    std::fs::write(&path, &json).expect("writing chaos json");
    println!("wrote {path}");

    if v.is_empty() {
        println!("chaos: OK ({} scenarios + reference, all invariants held)", scenarios.len());
    } else {
        for msg in &v {
            eprintln!("chaos violation: {msg}");
        }
        std::process::exit(1);
    }
}
