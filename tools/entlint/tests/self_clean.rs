//! The shipped tree must be clean: every rule hit in `rust/src/`
//! carries a written escape.  This is the same walk the CLI does, run
//! as a test so `cargo test -p entlint` alone catches a regression.

use std::path::{Path, PathBuf};

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read_dir {}: {e}", dir.display()))
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out);
        } else if p.extension().map_or(false, |e| e == "rs") {
            out.push(p);
        }
    }
}

#[test]
fn rust_src_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let root = root.canonicalize().expect("rust/src exists relative to tools/entlint");
    let mut files = Vec::new();
    walk(&root, &mut files);
    assert!(files.len() > 20, "walk found only {} files — wrong root?", files.len());
    let mut report = String::new();
    let mut bad = 0usize;
    for path in &files {
        let src = std::fs::read_to_string(path).unwrap();
        let rel = path
            .strip_prefix(&root)
            .unwrap()
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        for v in entlint::lint_file_contents(&rel, &src) {
            report.push_str(&format!("{rel}:{}: [{}] {}\n", v.line, v.rule, v.msg));
            bad += 1;
        }
    }
    assert_eq!(bad, 0, "rust/src is not entlint-clean:\n{report}");
}
