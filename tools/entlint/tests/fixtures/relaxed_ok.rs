// entlint fixture — the justified twin of relaxed_bad.rs: a plain
// comment on the site (or the line above) satisfies ordering-audit; no
// allow-escape is needed.
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(c: &AtomicUsize) -> usize {
    // Relaxed: independent monotonic counter, no cross-variable ordering
    c.fetch_add(1, Ordering::Relaxed)
}
