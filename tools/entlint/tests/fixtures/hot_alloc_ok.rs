// entlint fixture — the escaped twin of hot_alloc_bad.rs.
// entlint: hot
pub fn decode_step(out: &mut [f32], n: usize) {
    // entlint: allow(hot-path-alloc-free) — fixture: cold setup branch
    let scratch = vec![0.0f32; n];
    for (o, s) in out.iter_mut().zip(&scratch) {
        *o = *s;
    }
}
