// entlint fixture — an escape with no written reason is itself a
// violation (`bad-directive`); an unauditable hatch is a hole.
// entlint: allow(no-panic-on-untrusted)
pub fn noop() {}
