// entlint fixture — the escaped twin of stray_threads_bad.rs.
// entlint: allow(no-stray-threads) — fixture: pretend this is a sanctioned helper
pub fn fan_out(n: usize) {
    for _ in 0..n {
        std::thread::spawn(|| {});
    }
}
