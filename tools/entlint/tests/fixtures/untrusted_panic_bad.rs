// entlint fixture — virtual path `ans/fixture.rs` (untrusted scope).
pub fn first_byte(payload: &Vec<u8>) -> u8 {
    payload.get(0).copied().unwrap()
}
