// entlint fixture — virtual path `ans/fixture.rs`: #[cfg(test)] items
// are exempt from every rule (tests may unwrap/index freely).
pub fn id(x: u8) -> u8 {
    x
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_and_indexes_freely() {
        let v = vec![1u8, 2];
        assert_eq!(*v.get(0).unwrap(), v[0]);
    }
}
