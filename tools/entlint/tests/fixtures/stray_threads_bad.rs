// entlint fixture — linted with virtual path `serve/fixture.rs`.
pub fn fan_out(n: usize) {
    for _ in 0..n {
        std::thread::spawn(|| {});
    }
}
