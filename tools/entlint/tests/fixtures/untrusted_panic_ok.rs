// entlint fixture — the escaped twin of untrusted_panic_bad.rs.
// entlint: allow(no-panic-on-untrusted) — fixture: caller guarantees non-empty
pub fn first_byte(payload: &Vec<u8>) -> u8 {
    payload.get(0).copied().unwrap()
}
