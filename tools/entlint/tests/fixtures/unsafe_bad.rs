// entlint fixture — virtual path `model/fixture.rs` (safety-comment is
// path-independent).  Note: rust/src itself carries
// #![forbid(unsafe_code)]; this rule is the backstop for the day one
// module relaxes that to `deny` for a SIMD kernel.
pub fn transmute_len(v: &[u8]) -> usize {
    unsafe { v.as_ptr().add(v.len()).offset_from(v.as_ptr()) as usize }
}
