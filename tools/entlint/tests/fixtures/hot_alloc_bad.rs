// entlint fixture — virtual path `model/fixture.rs` (hot markers are
// path-independent).
// entlint: hot
pub fn decode_step(out: &mut [f32], n: usize) {
    let scratch = vec![0.0f32; n];
    for (o, s) in out.iter_mut().zip(&scratch) {
        *o = *s;
    }
}
