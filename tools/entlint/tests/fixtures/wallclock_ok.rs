// entlint fixture — the escaped twin of wallclock_bad.rs.
pub fn step_with_deadline() -> bool {
    // entlint: allow(no-wallclock-in-replay) — fixture: metrics timing only
    let t0 = std::time::Instant::now();
    t0.elapsed().as_millis() < 5
}
