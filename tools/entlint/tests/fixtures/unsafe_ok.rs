// entlint fixture — the justified twin of unsafe_bad.rs: a SAFETY:
// comment on the block (or the line directly above) is the proof
// obligation.
pub fn transmute_len(v: &[u8]) -> usize {
    // SAFETY: same allocation; add(len) is one-past-the-end, which offset_from permits
    unsafe { v.as_ptr().add(v.len()).offset_from(v.as_ptr()) as usize }
}
