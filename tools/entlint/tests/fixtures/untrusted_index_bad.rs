// entlint fixture — virtual path `store/fixture.rs` (untrusted scope):
// direct indexing, the non-method flavor of no-panic-on-untrusted.
pub fn header_len(bytes: &Vec<u8>) -> usize {
    bytes[4] as usize
}
