// entlint fixture — virtual path `coordinator/engine.rs` (replay scope).
pub fn step_with_deadline() -> bool {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_millis() < 5
}
