//! Fixture corpus: each known-bad snippet triggers exactly its one
//! rule; each escaped (or comment-justified) twin passes clean.  The
//! `rel` paths are virtual — rule scopes key off the path, so a fixture
//! can exercise any scope without living there.

use entlint::lint_file_contents;

fn fixture(name: &str) -> String {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// Assert the fixture yields exactly `n` violations, all of rule `rule`.
fn expect_only(name: &str, rel: &str, rule: &str, n: usize) {
    let v = lint_file_contents(rel, &fixture(name));
    assert_eq!(
        v.len(),
        n,
        "{name} as {rel}: want {n} violation(s) of [{rule}], got {v:?}"
    );
    for viol in &v {
        assert_eq!(viol.rule, rule, "{name} as {rel}: unexpected rule in {v:?}");
    }
}

fn expect_clean(name: &str, rel: &str) {
    let v = lint_file_contents(rel, &fixture(name));
    assert!(v.is_empty(), "{name} as {rel}: want clean, got {v:?}");
}

#[test]
fn stray_threads_fires_and_escapes() {
    expect_only("stray_threads_bad.rs", "serve/fixture.rs", "no-stray-threads", 1);
    expect_clean("stray_threads_ok.rs", "serve/fixture.rs");
}

#[test]
fn stray_threads_is_legal_in_parallel() {
    // same bad source, but under parallel/ — the one sanctioned home
    expect_clean("stray_threads_bad.rs", "parallel/fixture.rs");
}

#[test]
fn hot_alloc_fires_and_escapes() {
    expect_only("hot_alloc_bad.rs", "model/fixture.rs", "hot-path-alloc-free", 1);
    expect_clean("hot_alloc_ok.rs", "model/fixture.rs");
}

#[test]
fn untrusted_panic_fires_and_escapes() {
    expect_only("untrusted_panic_bad.rs", "ans/fixture.rs", "no-panic-on-untrusted", 1);
    expect_clean("untrusted_panic_ok.rs", "ans/fixture.rs");
}

#[test]
fn untrusted_indexing_fires() {
    expect_only("untrusted_index_bad.rs", "store/fixture.rs", "no-panic-on-untrusted", 1);
}

#[test]
fn untrusted_rules_only_fire_in_untrusted_modules() {
    // the same unwrap is fine outside ans//store/
    expect_clean("untrusted_panic_bad.rs", "model/fixture.rs");
}

#[test]
fn wallclock_fires_and_escapes() {
    expect_only("wallclock_bad.rs", "coordinator/engine.rs", "no-wallclock-in-replay", 1);
    expect_clean("wallclock_ok.rs", "coordinator/engine.rs");
}

#[test]
fn wallclock_is_legal_outside_replay_paths() {
    expect_clean("wallclock_bad.rs", "serve/metrics.rs");
}

#[test]
fn relaxed_fires_and_a_plain_comment_justifies() {
    expect_only("relaxed_bad.rs", "model/fixture.rs", "ordering-audit", 1);
    expect_clean("relaxed_ok.rs", "model/fixture.rs");
}

#[test]
fn unsafe_without_safety_comment_fires() {
    expect_only("unsafe_bad.rs", "model/fixture.rs", "safety-comment", 1);
    expect_clean("unsafe_ok.rs", "model/fixture.rs");
}

#[test]
fn reasonless_escape_is_itself_a_violation() {
    expect_only("bad_directive.rs", "model/fixture.rs", "bad-directive", 1);
}

#[test]
fn cfg_test_items_are_exempt() {
    expect_clean("cfg_test_skipped.rs", "ans/fixture.rs");
}
