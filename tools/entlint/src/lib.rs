//! entlint — repo-specific invariant linter for entquant.
//!
//! The repo's headline guarantees (byte-identical decode at any shard
//! count, allocation-free serving steady state, no panics on untrusted
//! containers, deterministic fault replay) are enforced dynamically by
//! tests; entlint pins the *source-level* invariants behind them so
//! they cannot silently regress as the concurrency surface grows:
//!
//! | rule | what it denies | where |
//! |---|---|---|
//! | `no-stray-threads` | `thread::spawn`/`scope`/`Builder` | everywhere except `parallel/` |
//! | `hot-path-alloc-free` | `Vec::new`/`with_capacity`, `vec!`, `format!`, `.to_vec()`, `.collect()`, `.clone()` | fns marked `// entlint: hot` |
//! | `no-panic-on-untrusted` | `.unwrap()`, `.expect()`, direct `[..]` indexing | `ans/`, `store/` |
//! | `no-wallclock-in-replay` | `Instant::now`, `SystemTime` | engine, packed KV cache, fault injection, serve replay paths |
//! | `ordering-audit` | `Ordering::Relaxed` without a justifying comment | everywhere |
//! | `safety-comment` | `unsafe { .. }` without a `// SAFETY:` comment | everywhere (moot while lib.rs forbids unsafe) |
//!
//! Escapes are inline and must carry a written reason (see
//! [`rules`]).  Offline-image constraint: the lexer is hand-rolled —
//! no `syn`, no proc-macro machinery, no dependencies at all.

pub mod lexer;
pub mod rules;

pub use rules::{lint_file_contents, Violation, RULES};
