//! CLI: `entlint [root]` — walk `root` (default `rust/src`), lint every
//! `.rs` file, print `path:line: [rule] msg` per violation, exit
//! non-zero if any were found.  Deny-by-default: there is no flag to
//! downgrade a rule; the only way past a diagnostic is an inline
//! escape with a written reason, which is itself auditable.

use std::path::{Path, PathBuf};

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map_or(false, |e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn main() {
    let root = std::env::args().nth(1).unwrap_or_else(|| "rust/src".to_string());
    let root = PathBuf::from(root);
    let mut files = Vec::new();
    if let Err(e) = walk(&root, &mut files) {
        eprintln!("entlint: cannot walk {}: {e}", root.display());
        std::process::exit(2);
    }
    let mut bad = 0usize;
    for path in &files {
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("entlint: cannot read {}: {e}", path.display());
                bad += 1;
                continue;
            }
        };
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        for v in entlint::lint_file_contents(&rel, &src) {
            println!("{}:{}: [{}] {}", path.display(), v.line, v.rule, v.msg);
            bad += 1;
        }
    }
    println!("entlint: {} files, {} violation(s)", files.len(), bad);
    std::process::exit(if bad > 0 { 1 } else { 0 });
}
