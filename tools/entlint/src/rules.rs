//! The entlint rule engine: directive parsing, scope resolution, and
//! the five repo-specific checks.
//!
//! Deny-by-default: every hit is a violation unless an inline escape
//! covers it —
//!
//! ```text
//! // entlint: allow(<rule>[, <rule>]) — <written reason>     (fn- or line-scoped)
//! // entlint: allow-file(<rule>) — <written reason>          (whole file)
//! // entlint: hot                                            (marks the next fn hot)
//! ```
//!
//! A directive comment directly above an `fn` item (attributes and
//! visibility modifiers in between are fine) covers the whole body;
//! anywhere else it covers the next code line.  Escapes without a
//! written reason, naming unknown rules, or binding to nothing are
//! themselves violations (`bad-directive`) — an escape hatch you can't
//! audit is a hole, not a hatch.

use crate::lexer::{is_keyword, lex, Kind, Tok};

pub const RULES: &[&str] = &[
    "no-stray-threads",
    "hot-path-alloc-free",
    "no-panic-on-untrusted",
    "no-wallclock-in-replay",
    "ordering-audit",
    "safety-comment",
];

/// Paths (relative to the lint root, `/`-separated) where deterministic
/// replay must not read wall time.
const REPLAY_PATHS: &[&str] = &[
    "coordinator/engine.rs",
    "coordinator/kv.rs",
    "runtime/fault.rs",
    "serve/shard.rs",
    "serve/scheduler.rs",
    "parallel/",
    "obs/",
];
/// Modules that decode untrusted bytes (containers come off disk or
/// the wire) and therefore must never panic on malformed input.
const UNTRUSTED_PATHS: &[&str] = &["ans/", "store/"];
/// The one module allowed to touch `std::thread` directly.
const THREAD_OK_PATHS: &[&str] = &["parallel/"];
const THREAD_FNS: &[&str] = &["spawn", "scope", "Builder"];

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub line: usize,
    pub rule: String,
    pub msg: String,
}

enum Directive {
    Hot,
    Allow(Vec<String>),
    AllowFile(Vec<String>),
    Bad(String),
}

/// Parse an `entlint:` comment; `None` when the comment is unrelated.
fn parse_directive(comment: &str) -> Option<Directive> {
    let body = comment
        .trim_start_matches('/')
        .trim_start_matches('!')
        .trim_start_matches('*')
        .trim();
    let body = body.strip_prefix("entlint:")?.trim();
    if body == "hot" || body.starts_with("hot ") {
        return Some(Directive::Hot);
    }
    for kind in ["allow-file", "allow"] {
        if let Some(rest) = body.strip_prefix(kind) {
            let rest = rest.trim_start();
            let Some(rest) = rest.strip_prefix('(') else {
                return Some(Directive::Bad(format!("malformed {kind} directive (expected `(`)")));
            };
            let Some(close) = rest.find(')') else {
                return Some(Directive::Bad(format!("malformed {kind} directive (unclosed `(`)")));
            };
            let rules: Vec<String> = rest[..close]
                .split(',')
                .map(str::trim)
                .filter(|r| !r.is_empty())
                .map(str::to_string)
                .collect();
            if rules.is_empty() {
                return Some(Directive::Bad(format!("{kind} directive names no rule")));
            }
            for r in &rules {
                if !RULES.contains(&r.as_str()) {
                    return Some(Directive::Bad(format!("unknown rule `{r}`")));
                }
            }
            let mut reason = rest[close + 1..].trim();
            // reason separator: em-dash, --, - or :
            for sep in ["\u{2014}", "--", "-", ":"] {
                if let Some(r) = reason.strip_prefix(sep) {
                    reason = r.trim();
                    break;
                }
            }
            if reason.is_empty() {
                return Some(Directive::Bad(format!(
                    "{kind}({}) has no written reason",
                    rules.join(", ")
                )));
            }
            return Some(if kind == "allow-file" {
                Directive::AllowFile(rules)
            } else {
                Directive::Allow(rules)
            });
        }
    }
    Some(Directive::Bad(format!("unrecognized entlint directive: `{body}`")))
}

fn in_scope(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel == *p || (p.ends_with('/') && rel.starts_with(p)))
}

struct FileLint {
    rel: String,
    toks: Vec<Tok>,
    viol: Vec<Violation>,
    allow_file: Vec<String>,
    line_allows: Vec<(usize, String)>,            // (line, rule)
    fn_allows: Vec<(usize, usize, Vec<String>)>,  // (body_open_tok, body_close_tok, rules)
    hot_fns: Vec<(usize, usize)>,                 // (body_open_tok, body_close_tok)
    skip_spans: Vec<(usize, usize)>,              // #[cfg(test)] items, token spans
    comment_lines: Vec<usize>,                    // lines a comment covers
    safety_lines: Vec<usize>,                     // lines a `SAFETY:` comment covers
}

impl FileLint {
    fn new(rel: &str, src: &str) -> Self {
        FileLint {
            rel: rel.to_string(),
            toks: lex(src),
            viol: Vec::new(),
            allow_file: Vec::new(),
            line_allows: Vec::new(),
            fn_allows: Vec::new(),
            hot_fns: Vec::new(),
            skip_spans: Vec::new(),
            comment_lines: Vec::new(),
            safety_lines: Vec::new(),
        }
    }

    fn err(&mut self, line: usize, rule: &str, msg: String) {
        self.viol.push(Violation { line, rule: rule.to_string(), msg });
    }

    // ---- pass 1: directives, cfg(test) spans, fn spans
    fn structure(&mut self) {
        let n = self.toks.len();
        // record comment coverage lines (incl. multi-line block comments)
        for t in &self.toks {
            if t.kind == Kind::Comment {
                let newlines = t.text.chars().filter(|&c| c == '\n').count();
                let has_safety = t.text.contains("SAFETY:");
                for ln in t.line..=t.line + newlines {
                    self.comment_lines.push(ln);
                    if has_safety {
                        self.safety_lines.push(ln);
                    }
                }
            }
        }

        // cfg(test) spans: `#` `[` ... cfg ( test ) ... `]` <item>
        let mut i = 0usize;
        while i < n {
            let t = &self.toks[i];
            if t.kind == Kind::Punct && t.text == "#" && i + 1 < n && self.toks[i + 1].text == "[" {
                let mut depth = 0i64;
                let mut j = i + 1;
                let mut is_cfg_test = false;
                while j < n {
                    let tj = &self.toks[j];
                    if tj.kind == Kind::Punct && tj.text == "[" {
                        depth += 1;
                    } else if tj.kind == Kind::Punct && tj.text == "]" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    } else if tj.kind == Kind::Ident && tj.text == "cfg" {
                        if j + 2 < n
                            && self.toks[j + 1].text == "("
                            && self.toks[j + 2].text == "test"
                        {
                            is_cfg_test = true;
                        }
                    }
                    j += 1;
                }
                if is_cfg_test {
                    let end = self.item_end(j + 1);
                    self.skip_spans.push((i, end));
                    i = end + 1;
                    continue;
                }
            }
            i += 1;
        }

        // directives + fn spans
        let mut pending: Vec<(bool, Vec<String>, usize)> = Vec::new(); // (is_hot, rules, line)
        let mut i = 0usize;
        while i < n {
            if self.toks[i].kind == Kind::Comment {
                let (line, text) = (self.toks[i].line, self.toks[i].text.clone());
                match parse_directive(&text) {
                    Some(Directive::Bad(msg)) => self.err(line, "bad-directive", msg),
                    Some(Directive::AllowFile(rules)) => self.allow_file.extend(rules),
                    Some(Directive::Hot) => pending.push((true, Vec::new(), line)),
                    Some(Directive::Allow(rules)) => pending.push((false, rules, line)),
                    None => {}
                }
                i += 1;
                continue;
            }
            if !pending.is_empty() {
                // does an fn item start here (skipping attrs + modifiers)?
                if let Some(fn_tok) = self.fn_ahead(i) {
                    let body = self.fn_body_span(fn_tok);
                    for (is_hot, rules, _) in pending.drain(..) {
                        if is_hot {
                            self.hot_fns.push(body);
                        } else {
                            self.fn_allows.push((body.0, body.1, rules));
                        }
                    }
                } else {
                    let line = self.toks[i].line;
                    for (is_hot, rules, dline) in pending.drain(..) {
                        if is_hot {
                            self.err(
                                dline,
                                "bad-directive",
                                "hot marker does not precede a fn".to_string(),
                            );
                        } else {
                            for r in rules {
                                self.line_allows.push((line, r));
                            }
                        }
                    }
                }
            }
            i += 1;
        }
        for (_, _, dline) in pending {
            self.err(
                dline,
                "bad-directive",
                "directive at end of file binds to nothing".to_string(),
            );
        }
    }

    /// End token index of the item starting at token `i` (brace-matched,
    /// or the terminating `;`).
    fn item_end(&self, i: usize) -> usize {
        let n = self.toks.len();
        let mut depth = 0i64;
        let mut j = i;
        while j < n {
            let t = &self.toks[j];
            if t.kind == Kind::Punct {
                if t.text == "{" {
                    depth += 1;
                } else if t.text == "}" {
                    depth -= 1;
                    if depth == 0 {
                        return j;
                    }
                } else if t.text == ";" && depth == 0 {
                    return j;
                }
            }
            j += 1;
        }
        n.saturating_sub(1)
    }

    /// If an fn item starts at token `i` (past attrs/modifiers), return
    /// the index of its `fn` token.
    fn fn_ahead(&self, i: usize) -> Option<usize> {
        let n = self.toks.len();
        let mut j = i;
        while j < n {
            let t = &self.toks[j];
            if t.kind == Kind::Punct && t.text == "#" && j + 1 < n && self.toks[j + 1].text == "[" {
                let mut depth = 0i64;
                let mut k = j + 1;
                while k < n {
                    if self.toks[k].text == "[" {
                        depth += 1;
                    } else if self.toks[k].text == "]" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                j = k + 1;
                continue;
            }
            if t.kind == Kind::Comment {
                j += 1;
                continue;
            }
            if t.kind == Kind::Ident
                && ["pub", "const", "async", "unsafe", "extern", "crate"].contains(&t.text.as_str())
            {
                j += 1;
                continue;
            }
            if t.kind == Kind::Punct && (t.text == "(" || t.text == ")") {
                j += 1; // pub(crate)
                continue;
            }
            if t.kind == Kind::Str {
                j += 1; // extern "C"
                continue;
            }
            if t.kind == Kind::Ident && t.text == "fn" {
                return Some(j);
            }
            return None;
        }
        None
    }

    /// (body_open_tok, body_close_tok) of the fn at `fn_tok`; a bodyless
    /// trait decl returns `(k, k)` at its `;`.  `(..)`/`[..]` nesting in
    /// the signature is tracked so `;` inside an array type (e.g.
    /// `[u32; 256]`) does not terminate the scan early.
    fn fn_body_span(&self, fn_tok: usize) -> (usize, usize) {
        let n = self.toks.len();
        let mut depth = 0i64;
        let mut j = fn_tok;
        while j < n {
            let t = &self.toks[j];
            if t.kind == Kind::Punct {
                match t.text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => return (j, self.item_end(j)),
                    ";" if depth == 0 => return (j, j),
                    _ => {}
                }
            }
            j += 1;
        }
        (n.saturating_sub(1), n.saturating_sub(1))
    }

    fn in_skip(&self, i: usize) -> bool {
        self.skip_spans.iter().any(|&(a, b)| a <= i && i <= b)
    }

    fn allowed(&self, rule: &str, line: usize, tok_i: usize) -> bool {
        if self.allow_file.iter().any(|r| r == rule) {
            return true;
        }
        if self
            .line_allows
            .iter()
            .any(|(ln, r)| (*ln == line || *ln + 1 == line) && r == rule)
        {
            return true;
        }
        self.fn_allows
            .iter()
            .any(|(a, b, rules)| *a <= tok_i && tok_i <= *b && rules.iter().any(|r| r == rule))
    }

    fn in_hot(&self, i: usize) -> bool {
        self.hot_fns.iter().any(|&(a, b)| a <= i && i <= b)
    }

    fn has_comment(&self, line: usize) -> bool {
        self.comment_lines.contains(&line)
    }

    // ---- pass 2: rule checks over the code token stream
    fn check(&mut self) {
        let code: Vec<usize> =
            (0..self.toks.len()).filter(|&k| self.toks[k].kind != Kind::Comment).collect();
        let untrusted = in_scope(&self.rel, UNTRUSTED_PATHS);
        let replay = in_scope(&self.rel, REPLAY_PATHS);
        let threads_ok = in_scope(&self.rel, THREAD_OK_PATHS);
        let mut out: Vec<Violation> = Vec::new();

        for (ci, &i) in code.iter().enumerate() {
            if self.in_skip(i) {
                continue;
            }
            let nxt = |d: usize| code.get(ci + d).map(|&k| &self.toks[k]);
            let prv = |d: usize| ci.checked_sub(d).map(|idx| &self.toks[code[idx]]);
            let t = &self.toks[i];

            // no-stray-threads
            if t.kind == Kind::Ident && t.text == "thread" && !threads_ok {
                if let (Some(a), Some(b), Some(c)) = (nxt(1), nxt(2), nxt(3)) {
                    if a.text == ":"
                        && b.text == ":"
                        && c.kind == Kind::Ident
                        && THREAD_FNS.contains(&c.text.as_str())
                        && !self.allowed("no-stray-threads", t.line, i)
                    {
                        out.push(Violation {
                            line: t.line,
                            rule: "no-stray-threads".to_string(),
                            msg: format!(
                                "thread::{} outside parallel/ (route work through the parallel subsystem)",
                                c.text
                            ),
                        });
                    }
                }
            }

            // hot-path-alloc-free
            if self.in_hot(i) {
                let mut hit: Option<String> = None;
                if t.kind == Kind::Ident && t.text == "Vec" {
                    if let (Some(a), Some(b), Some(c)) = (nxt(1), nxt(2), nxt(3)) {
                        if a.text == ":"
                            && b.text == ":"
                            && (c.text == "new" || c.text == "with_capacity")
                        {
                            hit = Some(format!("Vec::{}", c.text));
                        }
                    }
                }
                if t.kind == Kind::Ident && (t.text == "vec" || t.text == "format") {
                    if let Some(a) = nxt(1) {
                        if a.text == "!" {
                            hit = Some(format!("{}!", t.text));
                        }
                    }
                }
                if t.kind == Kind::Punct && t.text == "." {
                    if let Some(a) = nxt(1) {
                        if a.kind == Kind::Ident
                            && ["to_vec", "collect", "clone"].contains(&a.text.as_str())
                        {
                            if let Some(b) = nxt(2) {
                                if b.text == "(" || b.text == ":" {
                                    hit = Some(format!(".{}()", a.text));
                                }
                            }
                        }
                    }
                }
                if let Some(h) = hit {
                    if !self.allowed("hot-path-alloc-free", t.line, i) {
                        out.push(Violation {
                            line: t.line,
                            rule: "hot-path-alloc-free".to_string(),
                            msg: format!(
                                "{h} inside a `// entlint: hot` fn (steady-state decode must not allocate)"
                            ),
                        });
                    }
                }
            }

            // no-panic-on-untrusted
            if untrusted {
                if t.kind == Kind::Punct && t.text == "." {
                    if let Some(a) = nxt(1) {
                        if a.kind == Kind::Ident && (a.text == "unwrap" || a.text == "expect") {
                            // `self.expect(..)` is the parser's own method,
                            // not Option/Result::expect
                            let recv_self = prv(1).map_or(false, |p| {
                                p.kind == Kind::Ident
                                    && p.text == "self"
                                    && prv(2).map_or(true, |q| q.text != ".")
                            });
                            let meth = a.text.clone();
                            if nxt(2).map_or(false, |b| b.text == "(")
                                && !(meth == "expect" && recv_self)
                                && !self.allowed("no-panic-on-untrusted", t.line, i)
                            {
                                out.push(Violation {
                                    line: t.line,
                                    rule: "no-panic-on-untrusted".to_string(),
                                    msg: format!(
                                        ".{meth}() in an untrusted-decode module (return Result instead)"
                                    ),
                                });
                            }
                        }
                    }
                }
                if t.kind == Kind::Punct && t.text == "[" {
                    let is_index = prv(1).map_or(false, |p| {
                        (p.kind == Kind::Ident && !is_keyword(&p.text))
                            || p.kind == Kind::Num
                            || (p.kind == Kind::Punct
                                && (p.text == ")" || p.text == "]" || p.text == "?"))
                    });
                    if is_index && !self.allowed("no-panic-on-untrusted", t.line, i) {
                        out.push(Violation {
                            line: t.line,
                            rule: "no-panic-on-untrusted".to_string(),
                            msg: "direct index/slice in an untrusted-decode module \
                                  (use get()/checked slicing and return Result)"
                                .to_string(),
                        });
                    }
                }
            }

            // no-wallclock-in-replay
            if replay {
                let mut hit: Option<&str> = None;
                if t.kind == Kind::Ident && t.text == "Instant" {
                    if let (Some(a), Some(b), Some(c)) = (nxt(1), nxt(2), nxt(3)) {
                        if a.text == ":" && b.text == ":" && c.text == "now" {
                            hit = Some("Instant::now");
                        }
                    }
                }
                if t.kind == Kind::Ident && t.text == "SystemTime" {
                    hit = Some("SystemTime");
                }
                if let Some(h) = hit {
                    if !self.allowed("no-wallclock-in-replay", t.line, i) {
                        out.push(Violation {
                            line: t.line,
                            rule: "no-wallclock-in-replay".to_string(),
                            msg: format!(
                                "{h} on a deterministic replay path (wall time may not influence decode/replay)"
                            ),
                        });
                    }
                }
            }

            // safety-comment (future-proofing: the tree forbids unsafe today,
            // but if lib.rs is ever relaxed to `deny` for a SIMD kernel, every
            // block must carry its proof obligation)
            if t.kind == Kind::Ident && t.text == "unsafe" {
                if nxt(1).map_or(false, |a| a.kind == Kind::Punct && a.text == "{") {
                    let justified = self.safety_lines.contains(&t.line)
                        || self.safety_lines.contains(&(t.line - 1));
                    if !justified && !self.allowed("safety-comment", t.line, i) {
                        out.push(Violation {
                            line: t.line,
                            rule: "safety-comment".to_string(),
                            msg: "unsafe block without a `// SAFETY:` comment \
                                  on this or the previous line"
                                .to_string(),
                        });
                    }
                }
            }

            // ordering-audit
            if t.kind == Kind::Ident && t.text == "Ordering" {
                if let (Some(a), Some(b), Some(c)) = (nxt(1), nxt(2), nxt(3)) {
                    if a.text == ":" && b.text == ":" && c.kind == Kind::Ident && c.text == "Relaxed"
                    {
                        let justified = self.has_comment(t.line) || self.has_comment(t.line - 1);
                        if !justified && !self.allowed("ordering-audit", t.line, i) {
                            out.push(Violation {
                                line: t.line,
                                rule: "ordering-audit".to_string(),
                                msg: "Ordering::Relaxed without a justifying comment \
                                      on this or the previous line"
                                    .to_string(),
                            });
                        }
                    }
                }
            }
        }
        self.viol.extend(out);
    }

    fn run(mut self) -> Vec<Violation> {
        self.structure();
        self.check();
        self.viol
    }
}

/// Lint one file's contents.  `rel` is the path relative to the lint
/// root (`/`-separated) — rule scopes (`ans/`, `parallel/`, ...) key
/// off it, so fixtures can exercise any scope by picking a virtual
/// path.
pub fn lint_file_contents(rel: &str, src: &str) -> Vec<Violation> {
    FileLint::new(rel, src).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directive_requires_reason() {
        let v = lint_file_contents("ans/x.rs", "// entlint: allow(no-panic-on-untrusted)\nfn f() {}\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "bad-directive");
    }

    #[test]
    fn directive_rejects_unknown_rule() {
        let v = lint_file_contents("ans/x.rs", "// entlint: allow(no-such-rule) — why\nfn f() {}\n");
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("unknown rule"));
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(v: &[u8]) -> u8 { v[0] }\n}\n";
        assert!(lint_file_contents("ans/x.rs", src).is_empty());
    }

    #[test]
    fn fn_level_allow_covers_body_with_array_type_in_signature() {
        // the `;` inside `[u32; 256]` must not truncate the fn span
        let src = "// entlint: allow(no-panic-on-untrusted) — fixed-size table\n\
                   fn f(t: [u32; 256], i: u8) -> u32 { t[i as usize] }\n";
        assert!(lint_file_contents("ans/x.rs", src).is_empty());
    }

    #[test]
    fn scopes_only_fire_on_their_paths() {
        let idx = "fn f(v: &[u8]) -> u8 { v[0] }\n";
        assert_eq!(lint_file_contents("ans/x.rs", idx).len(), 1);
        assert!(lint_file_contents("model/x.rs", idx).is_empty());
    }
}
