//! Minimal Rust lexer — just enough structure for entlint's rules.
//!
//! The offline build image has no `syn`, so this hand-rolls the token
//! kinds the rules need: comments (kept as tokens — directives live in
//! them), strings (plain / raw / byte), char-vs-lifetime
//! disambiguation, identifiers, numbers, and single-char punctuation.
//! It does not need to be a *complete* Rust lexer; it needs to never
//! misclassify a comment or string boundary, because everything
//! downstream keys off those.

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Life,
    Punct,
    Comment,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

pub const KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub",
    "ref", "return", "static", "struct", "trait", "type", "unsafe", "use", "where", "while",
];

pub fn is_keyword(s: &str) -> bool {
    KEYWORDS.contains(&s)
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize Rust source.  Comments are emitted as tokens (entlint
/// directives live inside them); whitespace is dropped.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let text = |from: usize, to: usize| -> String { b[from..to].iter().collect() };
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let mut j = i;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Comment, text: text(i, j), line });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            toks.push(Tok { kind: Kind::Comment, text: text(i, j), line: start });
            i = j;
            continue;
        }
        // raw / byte strings: r"...", r#"..."#, br"...", b"...", b'.'
        let mut c = c;
        if c == 'r' || c == 'b' {
            let mut j = i;
            let pfx = b[j];
            if pfx == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 1;
            }
            if b[j] == 'r' && j + 1 < n && (b[j + 1] == '#' || b[j + 1] == '"') {
                j += 1;
                let mut hashes = 0usize;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    j += 1;
                    // find closing `"###...`
                    let mut end = n;
                    let mut k = j;
                    'scan: while k < n {
                        if b[k] == '"' {
                            let mut h = 0usize;
                            while h < hashes && k + 1 + h < n && b[k + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                end = k;
                                break 'scan;
                            }
                        }
                        k += 1;
                    }
                    let stop = (end + 1 + hashes).min(n);
                    let t = text(i, stop);
                    let newlines = t.chars().filter(|&c| c == '\n').count();
                    toks.push(Tok { kind: Kind::Str, text: t, line });
                    line += newlines;
                    i = stop;
                    continue;
                }
            }
            if pfx == 'b' && i + 1 < n && b[i + 1] == '"' {
                i += 1; // fall through to plain string below
                c = '"';
            } else if pfx == 'b' && i + 1 < n && b[i + 1] == '\'' {
                i += 1;
                c = '\'';
            }
        }
        // plain string
        if c == '"' {
            let start = line;
            let mut j = i + 1;
            while j < n {
                if b[j] == '\\' {
                    j += 2;
                } else if b[j] == '"' {
                    j += 1;
                    break;
                } else {
                    if b[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            let j = j.min(n);
            toks.push(Tok { kind: Kind::Str, text: text(i, j), line: start });
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let j = i + 1;
            if j < n && is_ident_start(b[j]) {
                let mut k = j;
                while k < n && is_ident_cont(b[k]) {
                    k += 1;
                }
                if k < n && b[k] == '\'' {
                    toks.push(Tok { kind: Kind::Char, text: text(i, k + 1), line });
                    i = k + 1;
                } else {
                    toks.push(Tok { kind: Kind::Life, text: text(i, k), line });
                    i = k;
                }
                continue;
            }
            // escaped or punctuation char literal
            let mut j = i + 1;
            if j < n && b[j] == '\\' {
                j += 2;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                j += 1;
            } else {
                j += 1;
                if j < n && b[j] == '\'' {
                    j += 1;
                }
            }
            let j = j.min(n);
            toks.push(Tok { kind: Kind::Char, text: text(i, j), line });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: text(i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (is_ident_cont(b[j]) || b[j] == '.') {
                // don't swallow `..` (range) or a method call `.foo`
                if b[j] == '.' {
                    if j + 1 < n && (b[j + 1] == '.' || is_ident_start(b[j + 1])) {
                        break;
                    }
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Num, text: text(i, j), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_are_tokens() {
        let ts = kinds("a // hi\nb /* x /* y */ z */ c");
        assert_eq!(ts[1], (Kind::Comment, "// hi".to_string()));
        assert_eq!(ts[3], (Kind::Comment, "/* x /* y */ z */".to_string()));
        assert_eq!(ts[4].1, "c");
    }

    #[test]
    fn strings_hide_their_contents() {
        let ts = kinds(r#"let s = "vec![] // not a comment";"#);
        assert!(ts.iter().all(|(k, _)| *k != Kind::Comment));
        assert_eq!(ts.iter().filter(|(k, _)| *k == Kind::Str).count(), 1);
    }

    #[test]
    fn raw_and_byte_strings() {
        let ts = kinds(r###"let s = r#"a "quoted" b"#; let t = b"bytes";"###);
        assert_eq!(ts.iter().filter(|(k, _)| *k == Kind::Str).count(), 2);
    }

    #[test]
    fn lifetime_vs_char() {
        let ts = kinds("fn f<'a>(x: &'a u8) { let c = 'x'; let e = '\\n'; }");
        assert_eq!(ts.iter().filter(|(k, _)| *k == Kind::Life).count(), 2);
        assert_eq!(ts.iter().filter(|(k, _)| *k == Kind::Char).count(), 2);
    }

    #[test]
    fn numbers_do_not_swallow_ranges_or_methods() {
        let ts = kinds("0..x.len(); 1.5f64; 2.clone()");
        let nums: Vec<&str> =
            ts.iter().filter(|(k, _)| *k == Kind::Num).map(|(_, t)| t.as_str()).collect();
        assert_eq!(nums, vec!["0", "1.5f64", "2"]);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let ts = lex("a\nb\n\nc");
        let lines: Vec<usize> = ts.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }
}
