"""L1 Pallas kernel: quantize-dequantize ("fake quant") onto the Float8
E4M3 / Int8 grids with per-output-channel scales.

This is the compute hot-spot of the *compression* path: the EntQuant
rate-distortion objective (paper eq. 3) evaluates

    W_q = clamp(round_gamma(W / s), -Qmax, Qmax)        (codes)
    What = s * W_q                                      (dequant)

once per L-BFGS iteration for every layer.  The kernel fuses the divide,
grid rounding, clamp and rescale in one VMEM pass over row-tiles of W
(one row = one output channel = one scale), so W streams HBM->VMEM once.

Grid rounding:
  * float8: XLA's convert-to-f8e4m3fn (round-to-nearest-even, saturating
    to +-448; e4m3fn has no inf).  Signed zeros are resolved by the
    round-trip (paper §A.1: "we resolve signed zeros").
  * int8:   round-half-away-from-zero, clamp to +-127.

interpret=True as everywhere (see qmatmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F8_MAX = 448.0  # largest finite e4m3fn magnitude
I8_MAX = 127.0

BR = 8  # rows (output channels) per program instance


def _round_f8(u: jax.Array) -> jax.Array:
    u = jnp.clip(u, -F8_MAX, F8_MAX)
    return u.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def _round_i8(u: jax.Array) -> jax.Array:
    # round half away from zero, matching the rust symmetric quantizer
    r = jnp.sign(u) * jnp.floor(jnp.abs(u) + 0.5)
    return jnp.clip(r, -I8_MAX, I8_MAX)


def _fakequant_kernel(w_ref, s_ref, codes_ref, what_ref, *, fmt: str):
    w = w_ref[...]
    s = s_ref[...][:, None]
    safe = jnp.where(s == 0.0, 1.0, s)
    u = w / safe
    q = _round_f8(u) if fmt == "f8" else _round_i8(u)
    q = jnp.where(s == 0.0, 0.0, q)
    codes_ref[...] = q
    what_ref[...] = q * s


def fakequant(w: jax.Array, s: jax.Array, fmt: str = "f8"):
    """Returns (codes, what): the grid codes and the dequantized estimate.

    w: [N, K] weight matrix (row = output channel), s: [N] scales.
    """
    assert fmt in ("f8", "i8")
    n, k = w.shape
    assert s.shape == (n,)
    br = n
    for b in range(min(n, BR), 0, -1):
        if n % b == 0:
            br = b
            break

    out_shape = [
        jax.ShapeDtypeStruct((n, k), jnp.float32),
        jax.ShapeDtypeStruct((n, k), jnp.float32),
    ]
    codes, what = pl.pallas_call(
        functools.partial(_fakequant_kernel, fmt=fmt),
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(w.astype(jnp.float32), s.astype(jnp.float32))
    return codes, what
