"""L1 Pallas kernel: fused channel-wise dequantization + matmul.

The paper's inference hot path is the Marlin fused dequant-GEMM (CUDA).
TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of warp-level
shuffles we tile the GEMM for the MXU systolic array with BlockSpec and
fuse the per-output-channel dequantization into the epilogue of the
K-reduction:

    y[m, n] = ( sum_k  x[m, k] * wq[n, k] ) * s[n]

where `wq` holds the *decoded symbol values* (Float8/Int8 grid points
materialized as f32 by the rust-side ANS decode) and `s` is the
per-output-channel scale.  Because `s` depends only on the output channel
it commutes with the K-sum, so the multiply happens once per output tile
rather than once per weight element — the same trick Marlin plays in its
epilogue.

The HBM<->VMEM schedule is expressed by the BlockSpec index maps: each
(i, j) program instance streams K-tiles of x and wq through VMEM and
accumulates into the output tile, which stays resident in VMEM across the
K-loop (grid is (M/bm, N/bn, K/bk), K innermost).

Pallas runs with interpret=True throughout: the CPU PJRT plugin cannot
execute Mosaic custom-calls; the real-TPU VMEM/MXU figures are estimated
in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes, MXU-shaped. Clamped to the actual dims for small operands.
BM, BN, BK = 128, 128, 128


def _qmatmul_kernel(x_ref, wq_ref, s_ref, o_ref, *, n_k: int):
    """One (i, j, k) program instance.

    x_ref:  (bm, bk) VMEM tile of activations
    wq_ref: (bn, bk) VMEM tile of quantized-symbol values
    s_ref:  (bn,)    per-output-channel scales for this j-tile
    o_ref:  (bm, bn) output tile, resident across the K-loop
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction for this K-tile; f32 accumulate.
    acc = jnp.dot(x_ref[...], wq_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    # Dequant epilogue: apply the channel scale once, after the last K-tile.
    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * s_ref[...][None, :]


def _block(dim: int, tile: int) -> int:
    """Largest divisor of `dim` that is <= tile (keeps grids exact for the
    non-power-of-two widths of the S/M/L ladder, e.g. 192 or 688)."""
    for b in range(min(dim, tile), 0, -1):
        if dim % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=())
def qmatmul(x: jax.Array, wq: jax.Array, s: jax.Array) -> jax.Array:
    """y = (x @ wq.T) * s  with x:[M,K], wq:[N,K], s:[N] -> y:[M,N].

    Shapes must be multiples of the clamped tile sizes (the serving
    configs guarantee this; tests sweep tile-aligned shapes).
    """
    m, k = x.shape
    n, k2 = wq.shape
    assert k == k2, f"contraction mismatch {k} != {k2}"
    assert s.shape == (n,)
    bm, bn, bk = _block(m, BM), _block(n, BN), _block(k, BK)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), wq.astype(jnp.float32), s.astype(jnp.float32))


def vmem_footprint_bytes(m: int, n: int, k: int) -> int:
    """Estimated per-core VMEM residency of one program instance (f32)."""
    bm, bn, bk = _block(m, BM), _block(n, BN), _block(k, BK)
    return 4 * (bm * bk + bn * bk + bn + bm * bn)


def mxu_utilization_estimate(m: int, n: int, k: int) -> float:
    """Fraction of MXU 128x128 tile lanes occupied by the chosen blocks."""
    bm, bn = _block(m, BM), _block(n, BN)
    return (bm / 128.0) * (bn / 128.0)
