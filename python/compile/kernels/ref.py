"""Pure-jnp oracles for the Pallas kernels (pytest correctness signal)."""

import jax
import jax.numpy as jnp

F8_MAX = 448.0
I8_MAX = 127.0


def qmatmul_ref(x: jax.Array, wq: jax.Array, s: jax.Array) -> jax.Array:
    """y = (x @ wq.T) * s."""
    return (
        jnp.dot(
            x.astype(jnp.float32),
            wq.astype(jnp.float32).T,
            preferred_element_type=jnp.float32,
        )
        * s.astype(jnp.float32)[None, :]
    )


def round_f8_ref(u: jax.Array) -> jax.Array:
    u = jnp.clip(u, -F8_MAX, F8_MAX)
    return u.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def round_i8_ref(u: jax.Array) -> jax.Array:
    r = jnp.sign(u) * jnp.floor(jnp.abs(u) + 0.5)
    return jnp.clip(r, -I8_MAX, I8_MAX)


def fakequant_ref(w: jax.Array, s: jax.Array, fmt: str = "f8"):
    w = w.astype(jnp.float32)
    s = s.astype(jnp.float32)
    safe = jnp.where(s == 0.0, 1.0, s)[:, None]
    u = w / safe
    q = round_f8_ref(u) if fmt == "f8" else round_i8_ref(u)
    q = jnp.where(s[:, None] == 0.0, 0.0, q)
    return q, q * s[:, None]
