"""Synthetic byte-level corpus + evaluation-task generator.

Stands in for the paper's C4/WikiText-2 (perplexity) and the 8-task
LM-Eval zero-shot suite (accuracy) — see DESIGN.md §2.  Everything is
deterministic under a seed and written into artifacts/ at build time, so
the rust eval harness only ever *reads* data (python never on the request
path).

The language is a small templated grammar with enough structure that a
few hundred training steps produce a model whose weight matrices carry
realistic heavy-tailed statistics, and whose behaviour degrades
measurably (but gracefully) under compression:

  * declarative sentences:   "the brave fox guards the old tower ."
  * arithmetic facts:        "2 + 5 = 7 ."
  * key-value recall:        "set k to m . recall k gives m ."
  * copy/repeat patterns:    "say abc again abc ."
  * comparisons:             "9 is more than 3 ."

The eight zero-shot tasks mirror the LM-Eval harness mechanics exactly:
each item is a context plus N candidate continuations scored by model
log-likelihood (length-normalized), accuracy = argmax == gold.
"""

import json
import random

ADJS = ["brave", "old", "tiny", "green", "quiet", "swift", "grim", "pale"]
NOUNS = ["fox", "tower", "river", "stone", "crow", "lamp", "gate", "ship"]
VERBS = ["guards", "finds", "breaks", "lifts", "hides", "moves", "holds", "sees"]
KEYS = list("kqzjxv")
VALS = list("mwpgbt")

INSTR_PREFIX = "Q: "
INSTR_INFIX = " A: "


def _sentence(rng: random.Random) -> str:
    kind = rng.randrange(10)
    if kind < 4:
        return (
            f"the {rng.choice(ADJS)} {rng.choice(NOUNS)} {rng.choice(VERBS)} "
            f"the {rng.choice(ADJS)} {rng.choice(NOUNS)} ."
        )
    if kind < 6:
        a, b = rng.randrange(10), rng.randrange(10)
        return f"{a} + {b} = {a + b} ."
    if kind < 8:
        k, v = rng.choice(KEYS), rng.choice(VALS)
        return f"set {k} to {v} . recall {k} gives {v} ."
    if kind < 9:
        word = "".join(rng.choice("abcdefgh") for _ in range(3))
        return f"say {word} again {word} ."
    a, b = rng.randrange(10), rng.randrange(10)
    rel = "more" if a > b else "less" if a < b else "same"
    if rel == "same":
        return f"{a} is the same as {b} ."
    return f"{a} is {rel} than {b} ."


def generate_text(n_sentences: int, seed: int) -> bytes:
    rng = random.Random(seed)
    parts = [_sentence(rng) for _ in range(n_sentences)]
    return (" ".join(parts) + " ").encode("ascii")


def _instruct_sample(rng: random.Random) -> str:
    kind = rng.randrange(3)
    if kind == 0:
        a, b = rng.randrange(10), rng.randrange(10)
        return f"{INSTR_PREFIX}what is {a} + {b} ?{INSTR_INFIX}{a + b} ."
    if kind == 1:
        k, v = rng.choice(KEYS), rng.choice(VALS)
        return f"{INSTR_PREFIX}set {k} to {v} . what is {k} ?{INSTR_INFIX}{v} ."
    word = "".join(rng.choice("abcdefgh") for _ in range(3))
    return f"{INSTR_PREFIX}repeat {word} .{INSTR_INFIX}{word} ."


def generate_instruct_text(n_samples: int, seed: int) -> bytes:
    rng = random.Random(seed)
    return (" ".join(_instruct_sample(rng) for _ in range(n_samples)) + " ").encode("ascii")


# ---------------------------------------------------------------------------
# zero-shot tasks (the LM-Eval analogue)


def _mc(context: str, gold: str, distractors: list) -> dict:
    options = [gold] + distractors
    return {"context": context, "options": options, "answer": 0}


def _task_noun_cloze(rng):
    a1, n1, v = rng.choice(ADJS), rng.choice(NOUNS), rng.choice(VERBS)
    a2, n2 = rng.choice(ADJS), rng.choice(NOUNS)
    ctx = f"the {a1} {n1} {v} the {a2}"
    bad = rng.sample([w for w in VERBS if w != v], 3)  # verbs are wrong POS here
    return _mc(ctx, f" {n2} .", [f" {w} ." for w in bad])


def _task_arith(rng):
    a, b = rng.randrange(10), rng.randrange(10)
    ctx = f"{a} + {b} ="
    wrong = rng.sample([x for x in range(19) if x != a + b], 3)
    return _mc(ctx, f" {a + b} .", [f" {x} ." for x in wrong])


def _task_recall(rng):
    k, v = rng.choice(KEYS), rng.choice(VALS)
    ctx = f"set {k} to {v} . recall {k} gives"
    bad = rng.sample([x for x in VALS if x != v], 3)
    return _mc(ctx, f" {v} .", [f" {x} ." for x in bad])


def _task_copy(rng):
    word = "".join(rng.choice("abcdefgh") for _ in range(3))
    ctx = f"say {word} again"
    bad = ["".join(rng.choice("abcdefgh") for _ in range(3)) for _ in range(3)]
    return _mc(ctx, f" {word} .", [f" {b} ." for b in bad])


def _task_compare(rng):
    a, b = rng.randrange(10), rng.randrange(10)
    while a == b:
        b = rng.randrange(10)
    rel = "more" if a > b else "less"
    anti = "less" if a > b else "more"
    ctx = f"{a} is"
    return _mc(ctx, f" {rel} than {b} .", [f" {anti} than {b} ."])


def _task_article(rng):
    # "the X Y" bigram grammaticality: gold keeps adj-noun order
    a, n = rng.choice(ADJS), rng.choice(NOUNS)
    ctx = "the"
    return _mc(ctx, f" {a} {n} ", [f" {n} {a} "])


def _task_sum_carry(rng):
    a = rng.randrange(5, 10)
    b = rng.randrange(10 - a, 10)  # force sum >= 10 (two-digit answer)
    ctx = f"{a} + {b} ="
    wrong = rng.sample([x for x in range(10, 19) if x != a + b], 3)
    return _mc(ctx, f" {a + b} .", [f" {x} ." for x in wrong])


def _task_period(rng):
    # sentence termination: after "the ADJ NOUN VERB the ADJ NOUN" comes "."
    s = (
        f"the {rng.choice(ADJS)} {rng.choice(NOUNS)} {rng.choice(VERBS)} "
        f"the {rng.choice(ADJS)} {rng.choice(NOUNS)}"
    )
    return _mc(s, " .", [" the", " +"])


TASKS = {
    "noun_cloze": _task_noun_cloze,
    "arith": _task_arith,
    "recall": _task_recall,
    "copy": _task_copy,
    "compare": _task_compare,
    "article": _task_article,
    "sum_carry": _task_sum_carry,
    "period": _task_period,
}

# harder, instruction-format tasks (the GSM8K/IFEval analogue; Figure 1)
def _task_instr_arith(rng):
    a, b = rng.randrange(10), rng.randrange(10)
    ctx = f"{INSTR_PREFIX}what is {a} + {b} ?{INSTR_INFIX.rstrip()}"
    wrong = rng.sample([x for x in range(19) if x != a + b], 3)
    return _mc(ctx, f" {a + b} .", [f" {x} ." for x in wrong])


def _task_instr_recall(rng):
    k, v = rng.choice(KEYS), rng.choice(VALS)
    ctx = f"{INSTR_PREFIX}set {k} to {v} . what is {k} ?{INSTR_INFIX.rstrip()}"
    bad = rng.sample([x for x in VALS if x != v], 3)
    return _mc(ctx, f" {v} .", [f" {x} ." for x in bad])


def _task_instr_repeat(rng):
    word = "".join(rng.choice("abcdefgh") for _ in range(3))
    ctx = f"{INSTR_PREFIX}repeat {word} .{INSTR_INFIX.rstrip()}"
    bad = ["".join(rng.choice("abcdefgh") for _ in range(3)) for _ in range(3)]
    return _mc(ctx, f" {word} .", [f" {b} ." for b in bad])


INSTRUCT_TASKS = {
    "instr_arith": _task_instr_arith,
    "instr_recall": _task_instr_recall,
    "instr_repeat": _task_instr_repeat,
}


def generate_tasks(n_items: int, seed: int, suite: str = "base") -> dict:
    """suite: "base" (8 LM-Eval-style tasks) or "instruct" (Figure 1)."""
    table = TASKS if suite == "base" else INSTRUCT_TASKS
    out = {}
    for i, (name, gen) in enumerate(sorted(table.items())):
        rng = random.Random(seed * 1000 + i)
        out[name] = [gen(rng) for _ in range(n_items)]
    return out


def write_all(outdir: str, seed: int = 7, n_train_sentences: int = 60000,
              n_valid_sentences: int = 4000, n_task_items: int = 200) -> None:
    import os

    os.makedirs(outdir, exist_ok=True)
    with open(f"{outdir}/train.bin", "wb") as f:
        f.write(generate_text(n_train_sentences, seed))
    with open(f"{outdir}/valid.bin", "wb") as f:
        f.write(generate_text(n_valid_sentences, seed + 1))
    with open(f"{outdir}/instruct_train.bin", "wb") as f:
        f.write(generate_instruct_text(8000, seed + 2))
    with open(f"{outdir}/tasks_base.json", "w") as f:
        json.dump(generate_tasks(n_task_items, seed + 3, "base"), f)
    with open(f"{outdir}/tasks_instruct.json", "w") as f:
        json.dump(generate_tasks(n_task_items, seed + 4, "instruct"), f)
