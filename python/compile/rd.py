"""L2: the EntQuant rate-distortion objective (paper eq. 3) with a
straight-through estimator through the quantizer, built on the L1 Pallas
fakequant kernel so the AOT-lowered HLO contains the kernel.

    objective(s; W, lam) = ||W - What||_1 / ||W||_1  +  lam * mean(|W_q|)

* d is the paper's relative entry-wise l1 distortion.
* R is the paper's entry-wise l1 norm of the quantized codes; we take the
  *mean* rather than the raw sum so the lam <-> target-entropy mapping is
  dimension-free (this is what makes Figure A.1's clustering
  model-independent; the paper normalizes implicitly via its lam grid).

STE (Bengio et al. 2013): the rounding step q(u) is treated as identity
in the backward pass (pass-through, including through the clamp — noted
in DESIGN.md).  Analytic gradients:

    codes = q(W/s):    d codes / d s = -W / s^2
    What  = s*codes:   d What  / d s = codes - W/s

aot.py lowers `rd_value_and_grad` per weight shape so the rust L-BFGS can
optionally evaluate the objective through PJRT; the rust-native objective
(rust/src/rd/objective.rs) implements identical semantics and is
cross-checked against fixtures dumped by aot.py.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.fakequant import fakequant
from .kernels.ref import fakequant_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fq_ste(w, s, fmt: str = "f8", use_kernel: bool = True):
    """(codes, what) with straight-through gradients."""
    f = fakequant if use_kernel else fakequant_ref
    return f(w, s, fmt)


def _fq_fwd(w, s, fmt, use_kernel):
    codes, what = fq_ste(w, s, fmt, use_kernel)
    return (codes, what), (w, s, codes)


def _fq_bwd(fmt, use_kernel, res, grads):
    """Clipped STE: pass-through across the *rounding* only.  Inside the
    clamp range q(u) ~ u; where |u| > Qmax the code is pinned at +-Qmax,
    so d codes/d· = 0 and d what/d s = codes.  (Plain pass-through
    through the clamp is the classic failure mode: it keeps pushing s
    down even when every symbol is saturated.)"""
    qmax = 448.0 if fmt == "f8" else 127.0
    w, s, codes = res
    g_codes, g_what = grads
    safe = jnp.where(s == 0.0, 1.0, s)[:, None]
    u = w / safe
    inside = (jnp.abs(u) <= qmax).astype(w.dtype)
    grad_w = (g_codes / safe + g_what) * inside
    grad_s_mat = inside * (g_codes * (-u / safe) + g_what * (codes - u)) \
        + (1.0 - inside) * g_what * codes
    grad_s = jnp.sum(grad_s_mat, axis=1)
    return grad_w, grad_s


fq_ste.defvjp(_fq_fwd, _fq_bwd)


def rd_objective(s, w, lam, fmt: str = "f8", use_kernel: bool = True):
    """Scalar objective; differentiable w.r.t. the scale vector s."""
    codes, what = fq_ste(w, s, fmt, use_kernel)
    d = jnp.sum(jnp.abs(w - what)) / (jnp.sum(jnp.abs(w)) + 1e-12)
    r = jnp.mean(jnp.abs(codes))
    return d + lam * r


def rd_value_and_grad(s, w, lam, fmt: str = "f8", use_kernel: bool = True):
    """(value, grad_s) — the artifact aot.py exports per weight shape."""
    return jax.value_and_grad(rd_objective)(s, w, lam, fmt, use_kernel)


def absmax_init(w: jax.Array, fmt: str = "f8") -> jax.Array:
    """Paper eq. (1): s_j = max|W_j| / Qmax per output channel."""
    qmax = 448.0 if fmt == "f8" else 127.0
    return jnp.max(jnp.abs(w), axis=1) / qmax


def empirical_entropy_bits(codes: jax.Array) -> float:
    """Paper eq. (2): empirical entropy of the code symbols, bits/param."""
    import numpy as np

    vals, counts = np.unique(np.asarray(codes), return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())
