"""The .eqw weight-container format shared with the rust side.

Layout (little endian):
    magic   b"EQW1"
    u32     header_len (bytes of UTF-8 JSON)
    bytes   JSON header:
              { "config": {...ModelConfig...},
                "tensors": [ {"name": str, "shape": [..], "dtype": "f32",
                               "offset": int, "nbytes": int}, ... ],
                "meta": {...free-form (train log summary etc.)...} }
    bytes   raw tensor data, concatenated, 16-byte aligned per tensor

Tensor naming convention (canonical order, shared with rust/src/model):
    embed                         [V, D]
    blocks.{i}.{wq|wk|wv|wo|w_gate|w_up|w_down}
    blocks.{i}.{norm_attn|norm_mlp}
    norm_final                    [D]
    head                          [V, D]
"""

import json
import struct

import numpy as np

MAGIC = b"EQW1"
ALIGN = 16


def write_eqw(path: str, config: dict, tensors: "list[tuple[str, np.ndarray]]",
              meta: dict | None = None) -> None:
    records = []
    blobs = []
    offset = 0
    for name, arr in tensors:
        arr = np.ascontiguousarray(arr, dtype=np.float32)
        pad = (-offset) % ALIGN
        offset += pad
        blobs.append(b"\x00" * pad)
        records.append(
            {
                "name": name,
                "shape": list(arr.shape),
                "dtype": "f32",
                "offset": offset,
                "nbytes": arr.nbytes,
            }
        )
        blobs.append(arr.tobytes())
        offset += arr.nbytes
    header = json.dumps(
        {"config": config, "tensors": records, "meta": meta or {}}
    ).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        f.write(b"".join(blobs))


def read_eqw(path: str):
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, f"bad magic {magic!r}"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen).decode("utf-8"))
        data = f.read()
    tensors = {}
    for rec in header["tensors"]:
        raw = data[rec["offset"] : rec["offset"] + rec["nbytes"]]
        tensors[rec["name"]] = np.frombuffer(raw, dtype=np.float32).reshape(rec["shape"])
    return header, tensors


def weights_to_tensor_list(weights, cfg) -> list:
    """Flatten a model.Weights pytree into the canonical (name, array) list."""
    import numpy as np

    out = [("embed", np.asarray(weights.embed))]
    for i, bw in enumerate(weights.blocks):
        for field in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                      "norm_attn", "norm_mlp"):
            out.append((f"blocks.{i}.{field}", np.asarray(getattr(bw, field))))
    out.append(("norm_final", np.asarray(weights.norm_final)))
    out.append(("head", np.asarray(weights.head)))
    return out
