"""AOT compile path: corpus -> checkpoints -> HLO text artifacts.

Run once via `make artifacts` (idempotent: skips anything that exists).
Python never runs on the request path; the rust coordinator loads the
HLO *text* emitted here through xla::HloModuleProto::from_text_file.

HLO text — NOT lowered.compiler_ir().serialize() — is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids
which xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifact inventory (written to ../artifacts, manifest.json describes it):

  corpus/                     synthetic corpus + zero-shot task suites
  model_{S,M,L}.eqw           trained checkpoints (+ model_M_instruct.eqw)
  train_log_{size}.json       loss curves (EXPERIMENTS.md e2e record)
  hlo/embed_p_b{B}_s{S}.hlo.txt     tokens -> activations     (prefill)
  hlo/block_p_b{B}_s{S}.hlo.txt     one quantized block        (prefill)
  hlo/head_p_b{B}_s{S}.hlo.txt      activations -> logits      (prefill)
  hlo/embed_d_b{B}.hlo.txt          decode-step variants
  hlo/block_d_b{B}_c{C}.hlo.txt
  hlo/head_d_b{B}.hlo.txt
  hlo/rd_valgrad_{N}x{K}.hlo.txt    RD objective value+grad (L-BFGS inner)
  fixtures/*.json             cross-language correctness fixtures
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from . import rd
from .configs import CONFIGS, SERVE_SIZE, PREFILL_SLOTS, DECODE_SLOTS, BLOCK_LINEARS
from .model import block_prefill, block_decode, embed_fwd, head_fwd

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write(path: str, text: str, manifest: list, name: str, inputs, outputs):
    with open(path, "w") as f:
        f.write(text)
    manifest.append({"name": name, "path": os.path.relpath(path, os.path.dirname(os.path.dirname(path))),
                     "inputs": inputs, "outputs": outputs})
    print(f"  wrote {path} ({len(text) // 1024} KiB)")


def _io_spec(specs):
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs]


def _block_weight_specs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    shapes = {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w_gate": (f, d), "w_up": (f, d), "w_down": (d, f),
    }
    codes = [_spec(shapes[n]) for n in BLOCK_LINEARS]
    scales = [_spec((shapes[n][0],)) for n in BLOCK_LINEARS]
    return codes, scales


def export_serving(outdir: str, manifest: list) -> None:
    cfg = CONFIGS[SERVE_SIZE]
    d, v, h, hd = cfg.d_model, cfg.vocab, cfg.n_heads, cfg.head_dim
    codes_s, scales_s = _block_weight_specs(cfg)
    norm = _spec((d,))

    for b, s in PREFILL_SLOTS:
        # embed
        fn = functools.partial(embed_fwd)
        low = jax.jit(fn).lower(_spec((b, s), I32), _spec((v, d)))
        _write(f"{outdir}/embed_p_b{b}_s{s}.hlo.txt", to_hlo_text(low), manifest,
               f"embed_p_b{b}_s{s}",
               _io_spec([_spec((b, s), I32), _spec((v, d))]),
               _io_spec([_spec((b, s, d))]))
        # block
        fn = functools.partial(block_prefill, cfg=cfg)
        startspec = _spec((b,), I32)
        low = jax.jit(fn).lower(_spec((b, s, d)), codes_s, scales_s, norm, norm, startspec)
        _write(f"{outdir}/block_p_b{b}_s{s}.hlo.txt", to_hlo_text(low), manifest,
               f"block_p_b{b}_s{s}",
               _io_spec([_spec((b, s, d))] + codes_s + scales_s + [norm, norm, startspec]),
               _io_spec([_spec((b, s, d)), _spec((b, h, s, hd)), _spec((b, h, s, hd))]))
        # head
        low = jax.jit(head_fwd).lower(_spec((b, s, d)), norm, _spec((v, d)))
        _write(f"{outdir}/head_p_b{b}_s{s}.hlo.txt", to_hlo_text(low), manifest,
               f"head_p_b{b}_s{s}",
               _io_spec([_spec((b, s, d)), norm, _spec((v, d))]),
               _io_spec([_spec((b, s, v))]))

    for b, c in DECODE_SLOTS:
        low = jax.jit(embed_fwd).lower(_spec((b, 1), I32), _spec((v, d)))
        _write(f"{outdir}/embed_d_b{b}.hlo.txt", to_hlo_text(low), manifest,
               f"embed_d_b{b}",
               _io_spec([_spec((b, 1), I32), _spec((v, d))]),
               _io_spec([_spec((b, 1, d))]))
        kv = _spec((b, h, c, hd))
        startspec = _spec((b,), I32)
        fn = functools.partial(block_decode, cfg=cfg)
        low = jax.jit(fn).lower(_spec((b, 1, d)), codes_s, scales_s, norm, norm,
                                kv, kv, _spec((), I32), startspec)
        _write(f"{outdir}/block_d_b{b}_c{c}.hlo.txt", to_hlo_text(low), manifest,
               f"block_d_b{b}_c{c}",
               _io_spec([_spec((b, 1, d))] + codes_s + scales_s
                        + [norm, norm, kv, kv, _spec((), I32), startspec]),
               _io_spec([_spec((b, 1, d)), kv, kv]))
        low = jax.jit(head_fwd).lower(_spec((b, 1, d)), norm, _spec((v, d)))
        _write(f"{outdir}/head_d_b{b}.hlo.txt", to_hlo_text(low), manifest,
               f"head_d_b{b}",
               _io_spec([_spec((b, 1, d)), norm, _spec((v, d))]),
               _io_spec([_spec((b, 1, v))]))


def export_rd(outdir: str, manifest: list) -> None:
    cfg = CONFIGS[SERVE_SIZE]
    d, f = cfg.d_model, cfg.d_ff
    shapes = sorted({(d, d), (f, d), (d, f)})
    for n, k in shapes:
        fn = functools.partial(rd.rd_value_and_grad, fmt="f8", use_kernel=True)
        low = jax.jit(fn).lower(_spec((n,)), _spec((n, k)), _spec(()))
        _write(f"{outdir}/rd_valgrad_{n}x{k}.hlo.txt", to_hlo_text(low), manifest,
               f"rd_valgrad_{n}x{k}",
               _io_spec([_spec((n,)), _spec((n, k)), _spec(())]),
               _io_spec([_spec(()), _spec((n,))]))


def export_fixtures(outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    # 1. full e4m3fn grid: byte pattern -> f32 value (rust codec oracle)
    import ml_dtypes

    grid = np.arange(256, dtype=np.uint8).view(ml_dtypes.float8_e4m3fn).astype(np.float32)
    with open(f"{outdir}/f8_grid.json", "w") as f:
        json.dump([None if not np.isfinite(x) else float(x) for x in grid], f)

    # 2. fakequant fixture: w, s -> codes, what (both formats)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 16), F32) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(1), (8, 16), F32))
    s = rd.absmax_init(w, "f8")
    fix = {"w": np.asarray(w).tolist(), "s_f8": np.asarray(s).tolist()}
    for fmt in ("f8", "i8"):
        sf = rd.absmax_init(w, fmt)
        from .kernels.ref import fakequant_ref

        codes, what = fakequant_ref(w, sf, fmt)
        fix[f"s_{fmt}"] = np.asarray(sf).tolist()
        fix[f"codes_{fmt}"] = np.asarray(codes).tolist()
        fix[f"what_{fmt}"] = np.asarray(what).tolist()
    with open(f"{outdir}/fakequant.json", "w") as f:
        json.dump(fix, f)

    # 3. RD objective value+grad fixture (rust L-BFGS oracle).  Scales are
    # nudged off the AbsMax point so no |w/s| sits exactly on the clamp
    # boundary (XLA may lower x/s as x*rcp(s), flipping the borderline
    # element's inside/outside classification vs strict IEEE division).
    lam = 0.05
    s = s * 1.07
    val, grad = rd.rd_value_and_grad(s, w, lam, fmt="f8", use_kernel=False)
    with open(f"{outdir}/rd_grad.json", "w") as f:
        json.dump({"w": np.asarray(w).tolist(), "s": np.asarray(s).tolist(),
                   "lam": lam, "value": float(val),
                   "grad": np.asarray(grad).tolist()}, f)

    # 4. model forward fixture: trained S model on fixed tokens -> logits
    from .eqw_io import read_eqw
    from .model import forward_train, Weights, BlockWeights

    art = os.path.dirname(outdir)
    spath = f"{art}/model_S.eqw"
    if os.path.exists(spath):
        header, tensors = read_eqw(spath)
        cfg = CONFIGS["S"]
        blocks = []
        for i in range(cfg.n_layers):
            blocks.append(BlockWeights(*[jnp.asarray(tensors[f"blocks.{i}.{n}"])
                                         for n in ("wq", "wk", "wv", "wo", "w_gate",
                                                   "w_up", "w_down", "norm_attn",
                                                   "norm_mlp")]))
        weights = Weights(jnp.asarray(tensors["embed"]), blocks,
                          jnp.asarray(tensors["norm_final"]), jnp.asarray(tensors["head"]))
        rng = np.random.default_rng(123)
        tokens = rng.integers(32, 127, size=(2, 24)).astype(np.int32)
        logits = forward_train(weights, jnp.asarray(tokens), cfg)
        with open(f"{outdir}/model_fwd.json", "w") as f:
            json.dump({"tokens": tokens.tolist(),
                       "logits_sample": np.asarray(logits[:, -1, :8]).tolist(),
                       "logits_mean": float(jnp.mean(logits)),
                       "logits_std": float(jnp.std(logits))}, f)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--skip-train", action="store_true")
    p.add_argument("--sizes", default="S,M,L")
    args = p.parse_args()
    art = args.out
    os.makedirs(art, exist_ok=True)

    # 1. corpus
    cdir = f"{art}/corpus"
    if not os.path.exists(f"{cdir}/train.bin"):
        print("[aot] generating corpus")
        corpus_mod.write_all(cdir)
    else:
        print("[aot] corpus exists")

    # 2. checkpoints
    if not args.skip_train:
        print("[aot] training checkpoints (skips existing)")
        from .train import train_all

        train_all(art, cdir, sizes=tuple(args.sizes.split(",")))

    # 3. HLO artifacts
    hdir = f"{art}/hlo"
    os.makedirs(hdir, exist_ok=True)
    manifest: list = []
    mpath = f"{art}/manifest.json"
    if os.path.exists(mpath):
        print("[aot] manifest exists; skipping HLO export")
    else:
        print("[aot] exporting serving HLO")
        export_serving(hdir, manifest)
        print("[aot] exporting RD valgrad HLO")
        export_rd(hdir, manifest)
        with open(mpath, "w") as f:
            json.dump({"serve_size": SERVE_SIZE,
                       "config": CONFIGS[SERVE_SIZE].to_json(),
                       "block_linears": BLOCK_LINEARS,
                       "prefill_slots": PREFILL_SLOTS,
                       "decode_slots": DECODE_SLOTS,
                       "executables": manifest}, f, indent=1)

    # 4. fixtures
    print("[aot] writing fixtures")
    export_fixtures(f"{art}/fixtures")
    print("[aot] done")


if __name__ == "__main__":
    main()
