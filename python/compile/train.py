"""Build-time training of the S/M/L checkpoints on the synthetic corpus.

This is the "load a small real model" half of the end-to-end mandate:
random Gaussian weights have none of the heavy-tailed, outlier-bearing
structure the paper's entropy argument relies on, so we actually *train*
the substitute models (hand-rolled Adam; optax is not available in this
image).  Loss curves are logged to artifacts/train_log_{size}.json and
summarized in EXPERIMENTS.md.

The "instruct" variant fine-tunes the base checkpoint on the
instruction-formatted split (the paper's instruction-tuned-model
scenario, Figure 1 / Table E.1).
"""

import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .configs import CONFIGS, ModelConfig
from .eqw_io import weights_to_tensor_list, write_eqw
from .model import Weights, init_weights, loss_fn

STEPS = {"S": 400, "M": 350, "L": 300}
INSTRUCT_STEPS = 150
BATCH = 16
SEQ = 128
LR = 3e-3


def _adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return z, jax.tree_util.tree_map(jnp.zeros_like, params)


@partial(jax.jit, static_argnames=("cfg",))
def _train_step(weights, m, v, tokens, step, cfg: ModelConfig):
    loss, grads = jax.value_and_grad(loss_fn)(weights, tokens, cfg)
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    t = step + 1
    lr_t = LR * jnp.sqrt(1 - b2**t) / (1 - b1**t)
    # cosine decay to 10%
    total = 500.0
    lr_t = lr_t * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(t / total, 1.0))))
    weights = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr_t * mm / (jnp.sqrt(vv) + eps), weights, m, v
    )
    return weights, m, v, loss


def _batches(data: np.ndarray, rng: np.random.Generator):
    n = len(data) - SEQ - 1
    while True:
        idx = rng.integers(0, n, size=BATCH)
        yield np.stack([data[i : i + SEQ + 1] for i in idx]).astype(np.int32)


def train_model(cfg: ModelConfig, corpus: bytes, steps: int, seed: int = 0,
                init: Weights | None = None, log_path: str | None = None) -> Weights:
    data = np.frombuffer(corpus, dtype=np.uint8)
    weights = init if init is not None else init_weights(cfg, jax.random.PRNGKey(seed))
    m, v = _adam_init(weights)
    gen = _batches(data, np.random.default_rng(seed + 1))
    log = []
    t0 = time.time()
    for step in range(steps):
        tokens = jnp.asarray(next(gen))
        weights, m, v, loss = _train_step(weights, m, v, tokens, step, cfg)
        if step % 10 == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss)})
    wall = time.time() - t0
    if log_path:
        with open(log_path, "w") as f:
            json.dump({"config": cfg.name, "steps": steps, "wall_s": wall, "log": log}, f)
    print(f"  [{cfg.name}] {steps} steps, loss {log[0]['loss']:.3f} -> "
          f"{log[-1]['loss']:.3f}, {wall:.0f}s")
    return weights


def train_all(outdir: str, corpus_dir: str, sizes=("S", "M", "L"),
              with_instruct: bool = True) -> None:
    os.makedirs(outdir, exist_ok=True)
    with open(f"{corpus_dir}/train.bin", "rb") as f:
        corpus = f.read()
    with open(f"{corpus_dir}/instruct_train.bin", "rb") as f:
        instruct = f.read()

    for size in sizes:
        cfg = CONFIGS[size]
        path = f"{outdir}/model_{size}.eqw"
        if os.path.exists(path):
            print(f"  [{size}] exists, skipping")
            continue
        w = train_model(cfg, corpus, STEPS[size], seed=42,
                        log_path=f"{outdir}/train_log_{size}.json")
        write_eqw(path, cfg.to_json(), weights_to_tensor_list(w, cfg),
                  meta={"trained_steps": STEPS[size]})
        if with_instruct and size == "M":
            ipath = f"{outdir}/model_{size}_instruct.eqw"
            wi = train_model(cfg, instruct, INSTRUCT_STEPS, seed=43, init=w,
                             log_path=f"{outdir}/train_log_{size}_instruct.json")
            write_eqw(ipath, cfg.to_json(), weights_to_tensor_list(wi, cfg),
                      meta={"trained_steps": STEPS[size] + INSTRUCT_STEPS,
                            "instruct": True})
