"""Model-size configurations shared across the compile path.

Three decoder-only byte-level transformers stand in for the paper's
LLaMA 7B/13B/70B ladder (see DESIGN.md §2 for the substitution argument).
The serving artifacts (PJRT-loaded HLO) are exported for SERVE_SIZE only;
offline evaluation runs through the rust f32 reference forward for all
sizes.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_ctx: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def params(self) -> int:
        """Total parameter count (embeddings + blocks + head + norms)."""
        d, f = self.d_model, self.d_ff
        per_block = 4 * d * d + 3 * d * f + 2 * d  # attn + swiglu + 2 rmsnorm
        return self.vocab * d * 2 + self.n_layers * per_block + d

    def to_json(self) -> dict:
        return asdict(self)


# The S/M/L ladder. d_ff is the SwiGLU inner width (~2.7x d_model like
# LLaMA's 8/3 rule, rounded to a multiple of 16 for clean tiling).
CONFIGS = {
    "S": ModelConfig("S", vocab=256, d_model=128, n_layers=4, n_heads=4, d_ff=352, max_ctx=256),
    "M": ModelConfig("M", vocab=256, d_model=192, n_layers=6, n_heads=6, d_ff=512, max_ctx=256),
    "L": ModelConfig("L", vocab=256, d_model=256, n_layers=8, n_heads=8, d_ff=688, max_ctx=256),
}

# Size whose serving artifacts (per-block prefill/decode HLO) are exported.
SERVE_SIZE = "M"

# Fixed-shape serving slots: the dynamic batcher packs requests into these.
PREFILL_SLOTS = [(1, 128), (4, 128)]  # (batch, seq)
DECODE_SLOTS = [(1, 256), (4, 256)]  # (batch, max_ctx)

# Names of the quantized linear weights inside one transformer block, in
# the canonical serialization order shared with the rust side.
BLOCK_LINEARS = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]
