"""L2: the JAX transformer (LLaMA-style decoder) whose quantized linears
call the L1 Pallas qmatmul kernel.

Two families of entry points:

* training/eval path (`forward_train`) — plain f32 linears, used by
  train.py to produce the build-time checkpoints.
* serving path (`embed_fwd`, `block_prefill`, `block_decode`, `head_fwd`)
  — per-transformer-block functions over *quantized* weights
  (symbol-value codes + channel scales), AOT-lowered by aot.py into the
  HLO artifacts the rust coordinator executes block-by-block, mirroring
  the paper's §A.1 block-wise decode pipeline.

Architecture: pre-RMSNorm, multi-head causal attention with RoPE, SwiGLU
MLP, untied byte-level embedding + output head.  Only the 7 per-block
linears (wq wk wv wo w_gate w_up w_down) are quantized; embeddings, head
and norms stay high precision, matching the paper's scope ("all linear
layers").
"""

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, BLOCK_LINEARS
from .kernels.qmatmul import qmatmul


class BlockWeights(NamedTuple):
    wq: jax.Array  # [D, D]   (rows = output channels)
    wk: jax.Array  # [D, D]
    wv: jax.Array  # [D, D]
    wo: jax.Array  # [D, D]
    w_gate: jax.Array  # [F, D]
    w_up: jax.Array  # [F, D]
    w_down: jax.Array  # [D, F]
    norm_attn: jax.Array  # [D]
    norm_mlp: jax.Array  # [D]


class Weights(NamedTuple):
    embed: jax.Array  # [V, D]
    blocks: list  # [BlockWeights]
    norm_final: jax.Array  # [D]
    head: jax.Array  # [V, D]


# ---------------------------------------------------------------------------
# init / primitives


def init_weights(cfg: ModelConfig, key: jax.Array) -> Weights:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    keys = jax.random.split(key, cfg.n_layers + 2)

    def dense(k, out_dim, in_dim):
        std = 1.0 / math.sqrt(in_dim)
        return jax.random.normal(k, (out_dim, in_dim), jnp.float32) * std

    blocks = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[i], 7)
        blocks.append(
            BlockWeights(
                wq=dense(ks[0], d, d),
                wk=dense(ks[1], d, d),
                wv=dense(ks[2], d, d),
                wo=dense(ks[3], d, d),
                w_gate=dense(ks[4], f, d),
                w_up=dense(ks[5], f, d),
                w_down=dense(ks[6], d, f),
                norm_attn=jnp.ones((d,), jnp.float32),
                norm_mlp=jnp.ones((d,), jnp.float32),
            )
        )
    embed = jax.random.normal(keys[-2], (v, d), jnp.float32) * 0.02
    head = dense(keys[-1], v, d)
    return Weights(embed=embed, blocks=blocks, norm_final=jnp.ones((d,), jnp.float32), head=head)


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope_angles(positions: jax.Array, head_dim: int) -> tuple:
    """positions: [...]; returns cos/sin of shape [..., head_dim//2]."""
    half = head_dim // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    theta = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(theta), jnp.sin(theta)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, H, S, hd]; cos/sin: [S, hd//2] (broadcast over B, H)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# ---------------------------------------------------------------------------
# attention / mlp over a generic "linear" callable


def _attention(x, lin, cfg: ModelConfig, k_cache=None, v_cache=None, pos=None, start=None):
    """x: [B, S, D]. If k_cache/v_cache given (decode), S == 1 and pos is
    the write index; returns (out, new_k_cache, new_v_cache) with caches of
    shape [B, H, C, hd]. Prefill returns caches of shape [B, H, S, hd].

    `start` ([B] int32) is the left-padding boundary the dynamic batcher
    uses: key positions < start[b] are masked out.  Left-padding keeps
    each request's real tokens ending at the slot's last position while
    RoPE's relative-distance property keeps attention geometry intact.
    """
    b, s_len, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    if start is None:
        start = jnp.zeros((b,), jnp.int32)

    q = lin("wq", x)  # [B, S, D]
    k = lin("wk", x)
    v = lin("wv", x)

    def heads(t):
        return t.reshape(b, s_len, h, hd).transpose(0, 2, 1, 3)  # [B,H,S,hd]

    q, k, v = heads(q), heads(k), heads(v)

    if k_cache is None:
        positions = jnp.arange(s_len)
        cos, sin = rope_angles(positions, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
        mask = jnp.tril(jnp.ones((s_len, s_len), bool))[None, None]
        pad = (jnp.arange(s_len)[None, :] >= start[:, None])[:, None, None, :]
        att = jnp.where(mask & pad, att, -1e30)
        p = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, v)
        new_k, new_v = k, v
    else:
        # decode step: write k/v at `pos`, attend over cache[start..pos]
        c = k_cache.shape[2]
        cos, sin = rope_angles(pos[None], hd)  # [1, hd//2]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        new_k = jax.lax.dynamic_update_slice(k_cache, k, (0, 0, pos, 0))
        new_v = jax.lax.dynamic_update_slice(v_cache, v, (0, 0, pos, 0))
        att = jnp.einsum("bhqd,bhkd->bhqk", q, new_k) / math.sqrt(hd)  # [B,H,1,C]
        idx = jnp.arange(c)[None, :]
        valid = (idx <= pos) & (idx >= start[:, None])
        att = jnp.where(valid[:, None, None, :], att, -1e30)
        p = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, new_v)

    out = out.transpose(0, 2, 1, 3).reshape(b, s_len, d)
    return lin("wo", out), new_k, new_v


def _mlp(x, lin):
    return lin("w_down", jax.nn.silu(lin("w_gate", x)) * lin("w_up", x))


def _block(x, bw: BlockWeights, lin, cfg, k_cache=None, v_cache=None, pos=None, start=None):
    att, nk, nv = _attention(rmsnorm(x, bw.norm_attn), lin, cfg, k_cache, v_cache, pos, start)
    x = x + att
    x = x + _mlp(rmsnorm(x, bw.norm_mlp), lin)
    return x, nk, nv


# ---------------------------------------------------------------------------
# training path: plain f32 linears


def _f32_lin(bw: BlockWeights):
    def lin(name, x):
        w = getattr(bw, name)
        return jnp.einsum("bsd,nd->bsn", x, w)

    return lin


def forward_train(weights: Weights, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens: [B, S] int32 -> logits [B, S, V]."""
    x = weights.embed[tokens]
    for bw in weights.blocks:
        x, _, _ = _block(x, bw, _f32_lin(bw), cfg)
    x = rmsnorm(x, weights.norm_final)
    return jnp.einsum("bsd,vd->bsv", x, weights.head)


def loss_fn(weights: Weights, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Next-token cross-entropy over [B, S]."""
    logits = forward_train(weights, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# serving path: quantized linears through the Pallas kernel
#
# Weights arrive as (codes, scale) pairs: codes are the decoded symbol
# values (f32 materialization of the Float8/Int8 grid points produced by
# the rust ANS decode), scale is per output channel.


def _q_lin(qw: dict):
    def lin(name, x):
        codes, scale = qw[name]
        b, s_len, d = x.shape
        y = qmatmul(x.reshape(b * s_len, d), codes, scale)
        return y.reshape(b, s_len, codes.shape[0])

    return lin


class QBlockParams(NamedTuple):
    """Flat, ordered parameter list for one quantized block (serving)."""

    codes: list  # 7 arrays, order BLOCK_LINEARS
    scales: list  # 7 arrays
    norm_attn: jax.Array
    norm_mlp: jax.Array


def _qw_dict(codes, scales):
    return {n: (c, s) for n, c, s in zip(BLOCK_LINEARS, codes, scales)}


def embed_fwd(tokens: jax.Array, embed: jax.Array) -> jax.Array:
    """tokens [B, S] -> x [B, S, D]."""
    return embed[tokens]


def head_fwd(x: jax.Array, norm_final: jax.Array, head: jax.Array) -> jax.Array:
    """x [B, S, D] -> logits [B, S, V] (head stays f32)."""
    x = rmsnorm(x, norm_final)
    return jnp.einsum("bsd,vd->bsv", x, head)


def block_prefill(x, codes, scales, norm_attn, norm_mlp, start, cfg: ModelConfig):
    """x [B, S, D], start [B] i32 -> (x', k [B,H,S,hd], v [B,H,S,hd])."""
    bw = BlockWeights(*([None] * 7), norm_attn=norm_attn, norm_mlp=norm_mlp)
    lin = _q_lin(_qw_dict(codes, scales))
    return _block(x, bw, lin, cfg, start=start)


def block_decode(x, codes, scales, norm_attn, norm_mlp, k_cache, v_cache, pos, start,
                 cfg: ModelConfig):
    """x [B, 1, D], caches [B, H, C, hd], pos scalar i32, start [B] i32."""
    bw = BlockWeights(*([None] * 7), norm_attn=norm_attn, norm_mlp=norm_mlp)
    lin = _q_lin(_qw_dict(codes, scales))
    return _block(x, bw, lin, cfg, k_cache=k_cache, v_cache=v_cache, pos=pos, start=start)
