# pytest: L2 model — shapes, prefill/decode equivalence, quantized path.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import CONFIGS, ModelConfig, BLOCK_LINEARS
from compile import rd
from compile.kernels.ref import fakequant_ref
from compile.model import (
    init_weights, forward_train, loss_fn, embed_fwd, head_fwd,
    block_prefill, block_decode, rmsnorm, rope_angles, apply_rope,
)

# vocab must cover printable ascii (the corpus is bytes 32..126)
TINY = ModelConfig("T", vocab=128, d_model=32, n_layers=2, n_heads=2, d_ff=48, max_ctx=32)


def _qparams(bw, fmt="f8"):
    codes, scales = [], []
    for n in BLOCK_LINEARS:
        W = getattr(bw, n)
        s = rd.absmax_init(W, fmt)
        c, _ = fakequant_ref(W, s, fmt)
        codes.append(c)
        scales.append(s)
    return codes, scales


@pytest.fixture(scope="module")
def tiny():
    return init_weights(TINY, jax.random.PRNGKey(0))


def test_shapes_and_param_count(tiny):
    toks = jnp.zeros((3, 7), jnp.int32)
    logits = forward_train(tiny, toks, TINY)
    assert logits.shape == (3, 7, 128)
    n = sum(np.prod(np.asarray(getattr(bw, f)).shape)
            for bw in tiny.blocks
            for f in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                      "norm_attn", "norm_mlp"))
    n += np.asarray(tiny.embed).size + np.asarray(tiny.head).size + 32
    assert n == TINY.params()


def test_loss_is_finite_and_reasonable(tiny):
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (4, 16)), jnp.int32)
    loss = float(loss_fn(tiny, toks, TINY))
    assert np.isfinite(loss)
    assert abs(loss - np.log(128)) < 1.5  # ~uniform at init


def test_causality(tiny):
    """Changing a future token must not affect past logits."""
    rng = np.random.default_rng(1)
    t1 = rng.integers(0, 128, (1, 12)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % 128
    l1 = forward_train(tiny, jnp.asarray(t1), TINY)
    l2 = forward_train(tiny, jnp.asarray(t2), TINY)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_serving_path_matches_train_path(tiny):
    toks = jnp.asarray(np.random.default_rng(2).integers(0, 128, (2, 8)), jnp.int32)
    want = forward_train(tiny, toks, TINY)
    x = embed_fwd(toks, tiny.embed)
    for bw in tiny.blocks:
        codes, scales = _qparams(bw)
        x, _, _ = block_prefill(x, codes, scales, bw.norm_attn, bw.norm_mlp,
                                jnp.zeros((x.shape[0],), jnp.int32), TINY)
    got = head_fwd(x, tiny.norm_final, tiny.head)
    # only f8-absmax quantization error: logits stay highly correlated and
    # the error is small relative to the logit spread
    g, t = np.asarray(got).ravel(), np.asarray(want).ravel()
    corr = np.corrcoef(g, t)[0, 1]
    assert corr > 0.99, corr
    assert float(np.max(np.abs(g - t))) < 5 * float(np.std(t))


def test_decode_matches_prefill(tiny):
    B, S, C = 2, 9, 16
    toks = jnp.asarray(np.random.default_rng(3).integers(0, 128, (B, S)), jnp.int32)
    qp = [_qparams(bw) for bw in tiny.blocks]

    x = embed_fwd(toks, tiny.embed)
    for (codes, scales), bw in zip(qp, tiny.blocks):
        x, _, _ = block_prefill(x, codes, scales, bw.norm_attn, bw.norm_mlp,
                                jnp.zeros((x.shape[0],), jnp.int32), TINY)
    want = head_fwd(x, tiny.norm_final, tiny.head)[:, -1]

    x_all = embed_fwd(toks, tiny.embed)
    caches = [[jnp.zeros((B, TINY.n_heads, C, TINY.head_dim))] * 2 for _ in tiny.blocks]
    for pos in range(S):
        x = x_all[:, pos : pos + 1]
        for li, ((codes, scales), bw) in enumerate(zip(qp, tiny.blocks)):
            x, k, v = block_decode(x, codes, scales, bw.norm_attn, bw.norm_mlp,
                                   caches[li][0], caches[li][1],
                                   jnp.asarray(pos, jnp.int32),
                                   jnp.zeros((B,), jnp.int32), TINY)
            caches[li] = [k, v]
    got = head_fwd(x, tiny.norm_final, tiny.head)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_rmsnorm_unit_scale():
    x = jnp.asarray(np.random.default_rng(4).normal(size=(5, 8)), jnp.float32)
    y = np.asarray(rmsnorm(x, jnp.ones((8,))))
    np.testing.assert_allclose((y**2).mean(axis=-1), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    hd = 8
    cos, sin = rope_angles(jnp.arange(4), hd)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(1, 1, 4, hd)), jnp.float32)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(y[0, 0, 0]), np.asarray(x[0, 0, 0]), rtol=1e-6)


def test_training_reduces_loss():
    from compile.train import train_model
    from compile.corpus import generate_text

    corpus = generate_text(2000, seed=11)
    w0 = init_weights(TINY, jax.random.PRNGKey(1))
    data = jnp.asarray(np.frombuffer(corpus[:2000], np.uint8)[None, :129].astype(np.int32))
    before = float(loss_fn(w0, data, TINY))
    w1 = train_model(TINY, corpus, steps=30, seed=5)
    after = float(loss_fn(w1, data, TINY))
    assert after < before - 0.5, (before, after)
