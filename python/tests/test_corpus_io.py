# pytest: corpus determinism + .eqw container round-trip + HLO text export.
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus
from compile.eqw_io import write_eqw, read_eqw, weights_to_tensor_list
from compile.configs import CONFIGS, ModelConfig
from compile.model import init_weights


def test_corpus_deterministic():
    a = corpus.generate_text(100, seed=3)
    b = corpus.generate_text(100, seed=3)
    assert a == b
    assert a != corpus.generate_text(100, seed=4)
    assert all(32 <= c < 127 for c in a), "printable ascii only"


def test_tasks_wellformed():
    tasks = corpus.generate_tasks(20, seed=1, suite="base")
    assert len(tasks) == 8, "the LM-Eval analogue has 8 tasks"
    for name, items in tasks.items():
        assert len(items) == 20
        for it in items:
            assert it["answer"] == 0
            assert len(it["options"]) >= 2
            assert len(set(it["options"])) == len(it["options"]), (name, it)


def test_instruct_tasks_wellformed():
    tasks = corpus.generate_tasks(10, seed=2, suite="instruct")
    assert len(tasks) == 3
    for items in tasks.values():
        for it in items:
            assert it["context"].startswith(corpus.INSTR_PREFIX)


def test_task_options_distinguishable_by_bytes():
    tasks = corpus.generate_tasks(50, seed=5, suite="base")
    for items in tasks.values():
        for it in items:
            gold = it["options"][0]
            assert all(gold != o for o in it["options"][1:])


def test_eqw_roundtrip(tmp_path):
    cfg = ModelConfig("T", vocab=32, d_model=16, n_layers=1, n_heads=2, d_ff=24, max_ctx=16)
    w = init_weights(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "t.eqw")
    write_eqw(path, cfg.to_json(), weights_to_tensor_list(w, cfg), meta={"x": 1})
    header, tensors = read_eqw(path)
    assert header["config"]["d_model"] == 16
    assert header["meta"]["x"] == 1
    np.testing.assert_array_equal(tensors["embed"], np.asarray(w.embed))
    np.testing.assert_array_equal(tensors["blocks.0.w_gate"], np.asarray(w.blocks[0].w_gate))
    # alignment: every offset is 16-byte aligned
    for rec in header["tensors"]:
        assert rec["offset"] % 16 == 0


def test_hlo_text_export_parses():
    """to_hlo_text output must contain an ENTRY computation and the right
    parameter count — the minimal structural contract the rust loader needs."""
    from compile.aot import to_hlo_text

    f = lambda a, b: (jnp.dot(a, b) + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(f).lower(spec, spec))
    assert "ENTRY" in text
    assert text.count("parameter(") == 2
