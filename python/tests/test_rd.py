# pytest: rate-distortion objective, STE gradients, entropy behaviour.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import rd
from compile.kernels.ref import fakequant_ref


def _w(seed, n=16, k=32, heavy=True):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n, k), jnp.float32)
    if heavy:  # log-normal magnitudes: LLM-like heavy tails
        w = w * jnp.exp(jax.random.normal(jax.random.PRNGKey(seed + 1), (n, k)))
    return w


def test_absmax_init_uses_full_range():
    w = _w(0)
    for fmt, qmax in (("f8", 448.0), ("i8", 127.0)):
        s = rd.absmax_init(w, fmt)
        codes, _ = fakequant_ref(w, s, fmt)
        assert float(jnp.max(jnp.abs(codes))) == pytest.approx(qmax, rel=0.08)


def test_objective_zero_distortion_at_fine_scale_identity():
    # if W already lies on the f8 grid with s=1, distortion is 0
    w = jnp.asarray([[1.0, 2.0, -0.5, 0.25]])
    s = jnp.ones((1,))
    val = float(rd.rd_objective(s, w, 0.0, "f8", use_kernel=False))
    assert val == 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), lam=st.floats(1e-4, 0.2))
def test_grad_matches_finite_difference_direction(seed, lam):
    w = _w(seed)
    s = rd.absmax_init(w, "f8")
    val, g = rd.rd_value_and_grad(s, w, lam, "f8", use_kernel=False)
    # full-vector directional FD along the gradient: stepping with the
    # gradient must not be better than stepping against it (STE grads are
    # approximate near rounding boundaries, so allow slack; what L-BFGS
    # relies on is the *average* descent direction)
    eps = 1e-2 * float(jnp.mean(s)) / (float(jnp.linalg.norm(g)) + 1e-9)
    plus = rd.rd_objective(s + eps * g, w, lam, "f8", use_kernel=False)
    minus = rd.rd_objective(s - eps * g, w, lam, "f8", use_kernel=False)
    assert float(plus) >= float(minus) - 0.05 * abs(float(val))


def test_kernel_and_ref_objective_agree():
    w = _w(3)
    s = rd.absmax_init(w, "f8")
    v1, g1 = rd.rd_value_and_grad(s, w, 0.03, "f8", use_kernel=True)
    v2, g2 = rd.rd_value_and_grad(s, w, 0.03, "f8", use_kernel=False)
    assert float(v1) == pytest.approx(float(v2), rel=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-6)


def _optimize_scales(w, lam, iters=250, fmt="f8"):
    """Log-space normalized GD stand-in for L-BFGS (tests only).  Scales
    must travel orders of magnitude for entropy to drop (the f8 grid is
    log-uniform, so entropy only falls once weights reach the uniform
    denormal region) — hence the log parametrization, same as the rust
    encoder."""
    u = jnp.log(rd.absmax_init(w, fmt))
    for _ in range(iters):
        s = jnp.exp(u)
        _, g = rd.rd_value_and_grad(s, w, lam, fmt, use_kernel=False)
        gu = g * s
        eta = 0.08 / (float(jnp.mean(jnp.abs(gu))) + 1e-12)
        u = u - eta * gu
    return jnp.exp(u)


def test_larger_lambda_gives_lower_entropy():
    """The paper's core mechanism (Figure A.1): lam controls the entropy
    of the code distribution monotonically."""
    w = _w(7, n=32, k=64)
    ents = []
    for lam in (1e-3, 0.3, 3.0):
        codes, _ = fakequant_ref(w, _optimize_scales(w, lam), "f8")
        ents.append(rd.empirical_entropy_bits(codes))
    assert ents[2] < ents[1] < ents[0], ents
    assert ents[2] < ents[0] - 1.0, ents


def test_clipped_ste_no_collapse_at_tiny_lambda():
    """Regression: plain pass-through STE through the clamp collapses the
    scales at small lam (every symbol saturates and the gradient keeps
    pushing).  With clipped STE the optimum stays near AbsMax."""
    w = _w(21)
    s0 = rd.absmax_init(w, "f8")
    s = _optimize_scales(w, 1e-4, iters=150)
    ratio = float(jnp.mean(s / s0))
    assert 0.5 < ratio < 20.0, ratio
    _, what = fakequant_ref(w, s, "f8")
    d = float(jnp.sum(jnp.abs(w - what)) / jnp.sum(jnp.abs(w)))
    assert d < 0.1, d


def test_optimization_reduces_objective():
    w = _w(9)
    lam = 0.05
    s = rd.absmax_init(w, "f8")
    v0, _ = rd.rd_value_and_grad(s, w, lam, "f8", use_kernel=False)
    for _ in range(80):
        _, g = rd.rd_value_and_grad(s, w, lam, "f8", use_kernel=False)
        s = jnp.maximum(s - 0.02 * jnp.abs(s) * jnp.sign(g), 1e-8)
    v1, _ = rd.rd_value_and_grad(s, w, lam, "f8", use_kernel=False)
    assert float(v1) < float(v0)


def test_entropy_bits_bounds():
    codes = jnp.asarray(np.zeros((8, 8), np.float32))
    assert rd.empirical_entropy_bits(codes) == 0.0
    codes = jnp.asarray(np.arange(256, dtype=np.float32).reshape(16, 16))
    assert rd.empirical_entropy_bits(codes) == pytest.approx(8.0)
