# pytest: Pallas kernels vs pure-jnp oracle — the CORE correctness signal.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qmatmul import qmatmul, vmem_footprint_bytes, mxu_utilization_estimate
from compile.kernels.fakequant import fakequant, F8_MAX, I8_MAX
from compile.kernels.ref import qmatmul_ref, fakequant_ref, round_f8_ref, round_i8_ref

DIMS = st.sampled_from([1, 2, 4, 8, 16, 24, 128, 192])
SMALL_DIMS = st.sampled_from([1, 3, 8, 16, 48])


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------- qmatmul


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, k=SMALL_DIMS, seed=st.integers(0, 2**16))
def test_qmatmul_matches_ref(m, n, k, seed):
    x = _rand(seed, (m, k))
    wq = _rand(seed + 1, (n, k), 3.0)
    s = jnp.abs(_rand(seed + 2, (n,))) + 1e-3
    got = qmatmul(x, wq, s)
    want = qmatmul_ref(x, wq, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_qmatmul_serving_shapes():
    # the exact shapes the serving artifacts use (M config)
    for (m, n, k) in [(512, 192, 192), (512, 512, 192), (512, 192, 512),
                      (4, 192, 192), (1, 512, 192)]:
        x = _rand(0, (m, k))
        wq = _rand(1, (n, k))
        s = jnp.ones((n,))
        np.testing.assert_allclose(np.asarray(qmatmul(x, wq, s)),
                                   np.asarray(qmatmul_ref(x, wq, s)),
                                   rtol=1e-5, atol=1e-4)


def test_qmatmul_zero_scale_rows_are_zero():
    x = _rand(3, (8, 16))
    wq = _rand(4, (8, 16))
    s = jnp.asarray([0.0, 1.0] * 4)
    y = np.asarray(qmatmul(x, wq, s))
    assert np.all(y[:, 0::2] == 0.0)


def test_qmatmul_bf16_inputs_upcast():
    x = _rand(5, (8, 16)).astype(jnp.bfloat16)
    wq = _rand(6, (8, 16))
    s = jnp.ones((8,))
    got = qmatmul(x, wq, s)
    want = qmatmul_ref(x, wq, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=1e-1)


def test_vmem_estimates_positive_and_bounded():
    b = vmem_footprint_bytes(512, 512, 512)
    assert 0 < b <= 16 * 2**20, "tile set must fit VMEM"
    assert 0 < mxu_utilization_estimate(512, 512, 512) <= 1.0
    assert mxu_utilization_estimate(1, 192, 192) < 0.1  # decode underfills MXU


# -------------------------------------------------------------- fakequant


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from([1, 2, 8, 24, 64]), k=SMALL_DIMS,
       seed=st.integers(0, 2**16), fmt=st.sampled_from(["f8", "i8"]),
       logscale=st.floats(-3, 3))
def test_fakequant_matches_ref(n, k, seed, fmt, logscale):
    w = _rand(seed, (n, k), float(np.exp(logscale)))
    s = jnp.abs(_rand(seed + 1, (n,))) * 0.1 + 1e-3
    c1, h1 = fakequant(w, s, fmt)
    c2, h2 = fakequant_ref(w, s, fmt)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


def test_fakequant_zero_scale_gives_zero():
    w = _rand(7, (4, 8))
    s = jnp.zeros((4,))
    c, h = fakequant(w, s, "f8")
    assert np.all(np.asarray(c) == 0) and np.all(np.asarray(h) == 0)


def test_fakequant_f8_saturates():
    w = jnp.full((1, 4), 1e6)
    s = jnp.ones((1,))
    c, _ = fakequant(w, s, "f8")
    assert np.all(np.asarray(c) == F8_MAX)


def test_fakequant_i8_saturates():
    w = jnp.full((1, 4), -1e6)
    s = jnp.ones((1,))
    c, _ = fakequant(w, s, "i8")
    assert np.all(np.asarray(c) == -I8_MAX)


def test_round_i8_half_away_from_zero():
    u = jnp.asarray([0.5, -0.5, 1.5, -1.5, 2.4999])
    r = np.asarray(round_i8_ref(u))
    np.testing.assert_array_equal(r, [1.0, -1.0, 2.0, -2.0, 2.0])


def test_round_f8_is_idempotent_on_grid():
    # every representable magnitude should round to itself
    import ml_dtypes

    grid = np.arange(256, dtype=np.uint8).view(ml_dtypes.float8_e4m3fn).astype(np.float32)
    grid = grid[np.isfinite(grid)]
    r = np.asarray(round_f8_ref(jnp.asarray(grid)))
    np.testing.assert_array_equal(r, grid)


def test_codes_are_representable_f8_values():
    import ml_dtypes

    w = _rand(9, (16, 32), 5.0)
    s = jnp.abs(_rand(10, (16,))) + 0.01
    c, _ = fakequant(w, s, "f8")
    grid = set(np.arange(256, dtype=np.uint8).view(ml_dtypes.float8_e4m3fn)
               .astype(np.float32)[np.isfinite(np.arange(256, dtype=np.uint8)
               .view(ml_dtypes.float8_e4m3fn).astype(np.float32))].tolist())
    grid.add(0.0)  # signed zero resolved
    assert set(np.asarray(c).ravel().tolist()) <= grid
