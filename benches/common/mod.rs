//! Shared hand-rolled bench harness (criterion is not available in this
//! offline image): warmup + repeated timing with mean/min reporting.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub iters: usize,
}

pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    // warmup
    f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("{name:<44} mean {mean:>10.3} ms   min {min:>10.3} ms   ({iters} iters)");
    BenchResult { name: name.to_string(), mean_ms: mean, min_ms: min, iters }
}

pub fn throughput(name: &str, bytes: usize, iters: usize, f: impl FnMut()) -> f64 {
    let r = bench(name, iters, f);
    let mbs = bytes as f64 / 1e6 / (r.min_ms / 1e3);
    println!("{:<44}   -> {mbs:.1} MB/s (best)", "");
    mbs
}

pub fn artifacts_ready() -> bool {
    std::path::Path::new(&format!("{}/model_S.eqw", entquant::artifacts_dir())).exists()
}
