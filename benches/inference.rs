//! Bench: end-to-end serving throughput per residency mode (Figure 5 /
//! F.1-F.3).  Uses the PJRT engine over the M-model artifacts; skips
//! cleanly when artifacts are missing.

mod common;

use common::artifacts_ready;
use entquant::coordinator::{pack, EngineOpts, Request, Residency, ServingEngine};
use entquant::runtime::Runtime;
use entquant::store::pipeline::{compress_model, CompressOpts};

fn main() {
    if !artifacts_ready() {
        println!("artifacts missing; run `make artifacts` first");
        return;
    }
    let art = entquant::artifacts_dir();
    if !std::path::Path::new(&format!("{art}/manifest.json")).exists() {
        println!("manifest missing; run `make artifacts` first");
        return;
    }
    let model = entquant::model::load_eqw(&format!("{art}/model_M.eqw")).unwrap();
    let (cm, rep) = compress_model(
        &model,
        &CompressOpts { target_bits: Some(3.0), ..Default::default() },
    )
    .unwrap();
    println!(
        "serving M at {:.2} effective bits/param\n",
        rep.effective_bits_per_param
    );
    let valid = std::fs::read(format!("{art}/corpus/valid.bin")).unwrap();
    let max_new = 12;
    println!(
        "{:<14} {:>6} {:>11} {:>13} {:>14} {:>12}",
        "Mode", "Batch", "TTFT(ms)", "Prefill(ms)", "Decode tok/s", "ResidentMiB"
    );
    for residency in [
        Residency::Bf16Resident,
        Residency::F8Resident,
        Residency::EntQuant,
        Residency::DiskOffload,
    ] {
        for batch_n in [1usize, 4] {
            let rt = Runtime::new(&art).unwrap();
            let engine = ServingEngine::new(
                rt,
                cm.clone(),
                EngineOpts { residency, ..Default::default() },
            )
            .unwrap();
            let reqs: Vec<Request> = (0..batch_n)
                .map(|i| Request {
                    id: i as u64,
                    prompt: valid[i * 101..i * 101 + 64].to_vec(),
                    max_new_tokens: max_new,
                })
                .collect();
            let batch = &pack(&reqs, &[(1, 128), (4, 128)])[0];
            // warm the executable cache, then measure
            let _ = engine.generate(batch, 2).unwrap();
            let (_, m) = engine.generate(batch, max_new).unwrap();
            println!(
                "{:<14} {batch_n:>6} {:>11.0} {:>13.0} {:>14.1} {:>12.2}",
                format!("{residency:?}"),
                m.ttft_ms,
                m.prefill_ms,
                (m.decode_tokens * batch_n) as f64 / (m.decode_ms / 1e3),
                engine.resident_weight_bytes() as f64 / (1 << 20) as f64
            );
        }
    }
    println!("\nexpected shape (paper Fig 5): EntQuant ~ F8Resident within 1.5-2x of Bf16, DiskOffload far behind on decode");
}
