//! Bench: rANS decode/encode throughput across entropy levels, chunk
//! sizes and framing — the substrate numbers behind Figure 5's decode
//! overhead and the §A.1 block-joint ablation.  Run via `cargo bench`
//! (or `scripts/bench.sh`, which also captures the tracked
//! `BENCH_decode.json`: seed-scalar vs chunk-parallel vs fused MB/s).
//!
//! `BENCH_SMOKE=1` shrinks sizes/iterations for the tier-1 smoke hook.

mod common;

use common::{bench, throughput};
use entquant::ans::rans::decode_chunk;
use entquant::ans::{Bitstream, Huffman};
use entquant::entropy::entropy_of;
use entquant::tensor::Rng;

fn skewed(n: usize, spread: f64, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| ((rng.normal().abs() * spread) as usize).min(255) as u8).collect()
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let n = if smoke { 1 << 20 } else { 4 << 20 }; // symbols ~ M-model blocks
    let iters = if smoke { 2 } else { 5 };

    println!("== rANS decode throughput vs entropy (n = {} MiB) ==", n >> 20);
    for spread in [0.3f64, 2.0, 10.0, 60.0] {
        let data = skewed(n, spread, 7);
        let h = entropy_of(&data);
        let bs = Bitstream::encode(&data, 256 * 1024);
        let mut out = vec![0u8; n];
        throughput(
            &format!(
                "decode H={h:.2} bits ({:.2} bits/sym stored)",
                bs.payload.len() as f64 * 8.0 / n as f64
            ),
            n,
            iters,
            || bs.decode_into(&mut out, 1).unwrap(),
        );
    }

    println!("\n== decode throughput vs chunk size (H~3.3) ==");
    let data = skewed(n, 10.0, 9);
    for chunk in [16 * 1024, 64 * 1024, 256 * 1024, 1024 * 1024] {
        let bs = Bitstream::encode(&data, chunk);
        let mut out = vec![0u8; n];
        throughput(&format!("decode chunk={}KiB", chunk >> 10), n, iters, || {
            bs.decode_into(&mut out, 1).unwrap()
        });
    }

    // chunk-parallel decode on the shared pool vs the scalar loop
    // (nvCOMP parallelizes across GPU blocks; we fan out 256 KiB chunks
    // across OS threads, two per worker for the 8-chain joint loop)
    let max_threads = entquant::parallel::default_threads();
    println!("\n== decode throughput vs threads (chunk=256KiB, H~3.3, {max_threads} available) ==");
    let bs = Bitstream::encode(&data, 256 * 1024);
    let mut thread_counts = vec![1usize, 2, 4, 8];
    thread_counts.retain(|&t| t <= max_threads.max(1));
    if !thread_counts.contains(&max_threads) {
        thread_counts.push(max_threads);
    }
    let mut scalar_mb_s = 0.0;
    let mut parallel_mb_s = 0.0;
    for &t in &thread_counts {
        let mut out = vec![0u8; n];
        let mbs = throughput(&format!("decode threads={t}"), n, iters, || {
            bs.decode_into(&mut out, t).unwrap()
        });
        if t == 1 {
            scalar_mb_s = mbs;
        } else if scalar_mb_s > 0.0 {
            println!("{:<44}   -> {:.2}x vs scalar", "", mbs / scalar_mb_s);
        }
        if t == max_threads {
            parallel_mb_s = mbs;
        }
    }

    // the tentpole comparison: the fused bitstream->f32 hot path vs the
    // seed serving path (per-chunk Vec + memcpy via decode_chunk, then
    // a separate LUT map allocating the f32 code buffer)
    println!("\n== fused decode->dequant (bitstream -> f32 codes) ==");
    let lut: [f32; 256] = core::array::from_fn(|i| i as f32 * 0.125 - 16.0);
    let mut sym = vec![0u8; n];
    let seed_mb_s = throughput("seed path: decode_chunk + LUT map", n, iters, || {
        let mut poff = 0usize;
        let mut soff = 0usize;
        for &len in &bs.chunk_lens {
            let len = len as usize;
            let m = bs.chunk_size.min(n - soff);
            let dec = decode_chunk(&bs.payload[poff..poff + len], m, &bs.table).unwrap();
            sym[soff..soff + m].copy_from_slice(&dec);
            poff += len;
            soff += m;
        }
        let codes: Vec<f32> = sym.iter().map(|&s| lut[s as usize]).collect();
        std::hint::black_box(&codes);
    });
    let mut codes = vec![0.0f32; n];
    let fused_mb_s = throughput("fused decode threads=1 (8-chain pairs)", n, iters, || {
        bs.decode_fused_into(&mut codes, &lut, 1).unwrap()
    });
    println!("{:<44}   -> {:.2}x vs seed path", "", fused_mb_s / seed_mb_s);
    let fused_par_mb_s =
        throughput(&format!("fused decode threads={max_threads}"), n, iters, || {
            bs.decode_fused_into(&mut codes, &lut, max_threads).unwrap()
        });

    // tracked bench trajectory: scalar vs threads=N vs fused, MB/s
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"decode\",\n",
            "  \"smoke\": {smoke},\n",
            "  \"n_symbols\": {n},\n",
            "  \"threads\": {threads},\n",
            "  \"seed_scalar_mb_s\": {seed:.1},\n",
            "  \"scalar_mb_s\": {scalar:.1},\n",
            "  \"parallel_mb_s\": {par:.1},\n",
            "  \"fused_mb_s\": {fused:.1},\n",
            "  \"fused_parallel_mb_s\": {fused_par:.1},\n",
            "  \"fused_speedup_vs_seed\": {speedup:.2}\n",
            "}}\n"
        ),
        smoke = smoke,
        n = n,
        threads = max_threads,
        seed = seed_mb_s,
        scalar = scalar_mb_s,
        par = parallel_mb_s,
        fused = fused_mb_s,
        fused_par = fused_par_mb_s,
        speedup = fused_mb_s / seed_mb_s,
    );
    // smoke numbers are not comparable to full runs: default them to a
    // separate file so a BENCH=1 tier-1 pass never clobbers the
    // tracked full-run trajectory
    let default_name = if smoke { "BENCH_decode.smoke.json" } else { "BENCH_decode.json" };
    let path = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| format!("{}/{default_name}", env!("CARGO_MANIFEST_DIR")));
    std::fs::write(&path, &json).expect("writing bench json");
    println!("\nwrote {path}");

    println!("\n== encode throughput vs threads ==");
    let data = skewed(n, 10.0, 11);
    let scalar_ser = Bitstream::encode(&data, 256 * 1024).serialize();
    for &t in &thread_counts {
        bench(&format!("rans encode {}MiB threads={t}", n >> 20), iters, || {
            let _ = Bitstream::encode_parallel(&data, 256 * 1024, t);
        });
        // parallel framing must be byte-identical to the scalar path
        assert_eq!(Bitstream::encode_parallel(&data, 256 * 1024, t).serialize(), scalar_ser);
    }

    println!("\n== ANS vs Huffman in the sub-1-bit regime (the paper's motivation) ==");
    let mut rare = vec![0u8; 1 << 20];
    for i in 0..4000 {
        rare[i * 260] = 1 + (i % 7) as u8;
    }
    let h = entropy_of(&rare);
    let bs = Bitstream::encode(&rare, 256 * 1024);
    let huff = Huffman::from_data(&rare);
    println!(
        "H = {h:.3} bits/sym | ANS stores {:.3} bits/sym | Huffman floor {:.3} bits/sym",
        bs.payload.len() as f64 * 8.0 / rare.len() as f64,
        huff.mean_bits(&rare)
    );
}
