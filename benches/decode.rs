//! Bench: rANS decode/encode throughput across entropy levels, chunk
//! sizes and framing — the substrate numbers behind Figure 5's decode
//! overhead and the §A.1 block-joint ablation.  Run via `cargo bench`.

mod common;

use common::{bench, throughput};
use entquant::ans::{Bitstream, Huffman};
use entquant::entropy::entropy_of;
use entquant::tensor::Rng;

fn skewed(n: usize, spread: f64, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| ((rng.normal().abs() * spread) as usize).min(255) as u8).collect()
}

fn main() {
    let n = 4 << 20; // 4M symbols ~ one M-model block x8
    println!("== rANS decode throughput vs entropy (n = {} MiB) ==", n >> 20);
    for spread in [0.3f64, 2.0, 10.0, 60.0] {
        let data = skewed(n, spread, 7);
        let h = entropy_of(&data);
        let bs = Bitstream::encode(&data, 256 * 1024);
        let mut out = vec![0u8; n];
        throughput(
            &format!("decode H={h:.2} bits ({:.2} bits/sym stored)", bs.payload.len() as f64 * 8.0 / n as f64),
            n,
            5,
            || bs.decode_into(&mut out, 1).unwrap(),
        );
    }

    println!("\n== decode throughput vs chunk size (H~3.3) ==");
    let data = skewed(n, 10.0, 9);
    for chunk in [16 * 1024, 64 * 1024, 256 * 1024, 1024 * 1024] {
        let bs = Bitstream::encode(&data, chunk);
        let mut out = vec![0u8; n];
        throughput(&format!("decode chunk={}KiB", chunk >> 10), n, 5, || {
            bs.decode_into(&mut out, 1).unwrap()
        });
    }

    // the tentpole comparison: chunk-parallel decode on the shared pool
    // vs the scalar loop (nvCOMP parallelizes across GPU blocks; we fan
    // out 256 KiB chunks across OS threads)
    let max_threads = entquant::parallel::default_threads();
    println!("\n== decode throughput vs threads (chunk=256KiB, H~3.3, {max_threads} available) ==");
    let bs = Bitstream::encode(&data, 256 * 1024);
    let mut thread_counts = vec![1usize, 2, 4, 8];
    thread_counts.retain(|&t| t <= max_threads.max(1));
    if !thread_counts.contains(&max_threads) {
        thread_counts.push(max_threads);
    }
    let mut base = 0.0;
    for &t in &thread_counts {
        let mut out = vec![0u8; n];
        let mbs = throughput(&format!("decode threads={t}"), n, 5, || {
            bs.decode_into(&mut out, t).unwrap()
        });
        if t == 1 {
            base = mbs;
        } else if base > 0.0 {
            println!("{:<44}   -> {:.2}x vs scalar", "", mbs / base);
        }
    }

    println!("\n== encode throughput vs threads ==");
    let data = skewed(n, 10.0, 11);
    let scalar_ser = Bitstream::encode(&data, 256 * 1024).serialize();
    for &t in &thread_counts {
        bench(&format!("rans encode 4MiB threads={t}"), 5, || {
            let _ = Bitstream::encode_parallel(&data, 256 * 1024, t);
        });
        // parallel framing must be byte-identical to the scalar path
        assert_eq!(Bitstream::encode_parallel(&data, 256 * 1024, t).serialize(), scalar_ser);
    }

    println!("\n== ANS vs Huffman in the sub-1-bit regime (the paper's motivation) ==");
    let mut rare = vec![0u8; 1 << 20];
    for i in 0..4000 {
        rare[i * 260] = 1 + (i % 7) as u8;
    }
    let h = entropy_of(&rare);
    let bs = Bitstream::encode(&rare, 256 * 1024);
    let huff = Huffman::from_data(&rare);
    println!(
        "H = {h:.3} bits/sym | ANS stores {:.3} bits/sym | Huffman floor {:.3} bits/sym",
        bs.payload.len() as f64 * 8.0 / rare.len() as f64,
        huff.mean_bits(&rare)
    );
}
