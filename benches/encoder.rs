//! Bench: EntQuant per-layer compression (Algorithm 1) — the Table 3(a)
//! "compression runtime" basis, reported as us/parameter so the paper's
//! 70B/<30min claim can be checked by extrapolation.

mod common;

use common::{artifacts_ready, bench};
use entquant::model::loader::synthetic_model;
use entquant::model::Config;
use entquant::quant::Format;
use entquant::rd::{encode_layer, EncodeOpts};
use entquant::store::pipeline::{compress_model, CompressOpts};
use entquant::tensor::{Mat, Rng};

fn heavy(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| (rng.normal() * (rng.normal() * 0.7).exp()) as f32).collect(),
    )
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let iters = if smoke { 1 } else { 3 };
    let rd_iters = if smoke { 10 } else { 60 };
    println!("== per-layer RD optimization (L-BFGS over channel scales) ==");
    for (rows, cols) in [(192, 192), (512, 192), (256, 688)] {
        let w = heavy(rows, cols, 3);
        let params = rows * cols;
        let r = bench(&format!("encode_layer {rows}x{cols} lam=1"), iters, || {
            let opts = EncodeOpts {
                lam: 1.0,
                fmt: Format::F8E4M3,
                max_iters: rd_iters,
                skip_optimization: false,
            };
            let _ = encode_layer(&w, &opts);
        });
        println!(
            "{:<44}   -> {:.3} us/param",
            "",
            r.min_ms * 1e3 / params as f64
        );
    }

    // the tentpole comparison: layer-parallel RD fan-out on the shared
    // pool vs the scalar loop (works without artifacts: synthetic model)
    let max_threads = entquant::parallel::default_threads();
    println!("\n== whole-model pipeline vs threads (synthetic, {max_threads} available) ==");
    let synth = synthetic_model(
        Config {
            name: "bench".into(),
            vocab: 256,
            d_model: 96,
            n_layers: 4,
            n_heads: 4,
            d_ff: 256,
            max_ctx: 64,
        },
        42,
    );
    let mut thread_counts = vec![1usize, 2, 4];
    thread_counts.retain(|&t| t <= max_threads.max(1));
    if !thread_counts.contains(&max_threads) {
        thread_counts.push(max_threads);
    }
    let mut serialized: Vec<Vec<u8>> = Vec::new();
    let mut base_ms = 0.0;
    for &t in &thread_counts {
        let mut last: Option<Vec<u8>> = None;
        let r = bench(&format!("compress synthetic threads={t}"), iters, || {
            let (cm, _) = compress_model(
                &synth,
                &CompressOpts { lam: 1.0, max_iters: 20, threads: t, ..Default::default() },
            )
            .unwrap();
            last = Some(cm.serialize());
        });
        if t == 1 {
            base_ms = r.min_ms;
        } else if base_ms > 0.0 {
            println!("{:<44}   -> {:.2}x vs scalar", "", base_ms / r.min_ms);
        }
        serialized.push(last.expect("bench ran at least once"));
    }
    // any thread count must produce the identical container
    assert!(serialized.windows(2).all(|w| w[0] == w[1]), "threads changed the container bytes");

    if artifacts_ready() {
        println!("\n== whole-model pipeline (M checkpoint) ==");
        let model = entquant::model::load_eqw(&format!("{}/model_M.eqw", entquant::artifacts_dir())).unwrap();
        let params = model.linear_params();
        let t0 = std::time::Instant::now();
        let (_, rep) = compress_model(&model, &CompressOpts { lam: 10.0, ..Default::default() }).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let us_pp = wall * 1e6 / params as f64;
        println!(
            "compress M ({params} params): {wall:.1}s = {us_pp:.3} us/param, H={:.2} bits",
            rep.mean_entropy_bits
        );
        println!(
            "extrapolated 70B on this single core: {:.1} h (paper: <0.5 h on H100 with layer-parallel fan-out)",
            us_pp * 70e9 / 1e6 / 3600.0
        );
    } else {
        println!("(artifacts missing; skipping whole-model pipeline)");
    }
}
