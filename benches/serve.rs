//! Bench: the serve subsystem under a synthetic request trace —
//! scheduler throughput (tokens/s) and p50 time-to-first-token at
//! 1/2/4 shards with stage pipelining on and off, end-to-end on the
//! native executor (compress a synthetic checkpoint, shard it, drive
//! the continuous-batching scheduler), a decode-only series (a full
//! 8-lane batch stepped to context exhaustion — the isolated
//! cross-request pipeline-parallelism measurement, reported as
//! `pipeline_speedup_4shards`), a compressed-KV series (resident cache
//! bytes per lane, compression ratio, and capacity uplift per
//! `--kv-mode`, with steady-state `fresh_allocs` pinned to 0 and the
//! default f8 config asserted >= 3x), plus fault drills (a scripted shard
//! kill mid-trace) that track reroute behavior, the recovery stall of
//! the incremental splice versus the legacy full reopen, the
//! contract→expand rejoin, and the shared-storage memory gauges
//! (`weight_copies`, `resident_compressed_bytes`).  Emits the tracked
//! `BENCH_serve.json` (`BENCH_serve.smoke.json` under `BENCH_SMOKE=1`,
//! which also shrinks the trace; `BENCH_SERVE_JSON` overrides the
//! path).

use entquant::coordinator::{EngineOpts, KvCfg, KvMode, ServingEngine, TailFmt};
use entquant::model::loader::synthetic_model;
use entquant::model::Config;
use entquant::runtime::fault::{FaultPlan, FaultRuntime, FaultScript};
use entquant::runtime::{Manifest, Runtime};
use entquant::serve::{Scheduler, SchedulerOpts, ShardPlan, ShardedEngine};
use entquant::store::container::CompressedModel;
use entquant::store::pipeline::{compress_model, CompressOpts};
use std::sync::Arc;

const SEQ: usize = 24;
const CTX: usize = 48;

fn native_rt(cm: &CompressedModel) -> Runtime {
    Runtime::native(Manifest::synthetic(
        cm.config.clone(),
        vec![(1, SEQ), (2, SEQ), (4, SEQ), (8, SEQ)],
        vec![(1, CTX), (2, CTX), (4, CTX), (8, CTX)],
    ))
}

struct TracePoint {
    shards: usize,
    pipelined: bool,
    tokens: usize,
    wall_s: f64,
    tokens_per_s: f64,
    p50_ttft_ms: f64,
    p99_ttft_ms: f64,
    p999_ttft_ms: f64,
    p50_step_us: f64,
    p99_step_us: f64,
    p999_step_us: f64,
    fused: usize,
    speculative: usize,
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (n_layers, n_requests, max_new) = if smoke { (4, 16, 6) } else { (8, 64, 8) };

    println!("== compressing a synthetic checkpoint ({n_layers} layers) ==");
    let model = synthetic_model(
        Config {
            name: "bench".into(),
            vocab: 64,
            d_model: 32,
            n_layers,
            n_heads: 4,
            d_ff: 48,
            max_ctx: 64,
        },
        71,
    );
    let t0 = std::time::Instant::now();
    let threads = entquant::parallel::default_threads();
    let (cm, rep) = compress_model(
        &model,
        &CompressOpts { lam: 0.3, max_iters: 6, threads, ..Default::default() },
    )
    .expect("compress");
    println!(
        "compressed in {:.1}s: {:.2} effective bits/param",
        t0.elapsed().as_secs_f64(),
        rep.effective_bits_per_param
    );

    println!(
        "\n== scheduler trace: {n_requests} requests, max_new {max_new}, shards 1/2/4, pipelining off/on =="
    );
    let mut points: Vec<TracePoint> = Vec::new();
    for (shards, pipelined) in [(1usize, false), (2, false), (2, true), (4, false), (4, true)] {
        let plan = ShardPlan::balance(&cm, shards);
        let rts: Vec<Runtime> = (0..plan.n_shards()).map(|_| native_rt(&cm)).collect();
        let opts = EngineOpts { stage_pipeline: pipelined, ..Default::default() };
        let engine = ShardedEngine::new(rts, &cm, plan, &opts).expect("shards");
        let sched = Scheduler::new(engine, SchedulerOpts { paused: true, ..Default::default() });
        let ids: Vec<u64> = (0..n_requests as u64)
            .map(|i| {
                let len = 2 + (i as usize * 5) % (SEQ - 4);
                let prompt: Vec<u8> =
                    (0..len).map(|j| ((i as usize * 13 + j * 7) % 64) as u8).collect();
                sched.submit(prompt, max_new).expect_admitted()
            })
            .collect();
        let t0 = std::time::Instant::now();
        sched.resume();
        sched.drain(std::time::Duration::from_secs(600)).expect("drain");
        let wall_s = t0.elapsed().as_secs_f64();
        let m = sched.metrics();
        assert_eq!(m.completed, ids.len(), "trace must complete");
        let tokens_per_s = m.tokens as f64 / wall_s;
        println!(
            "shards={shards} pipelined={pipelined}: {} tokens in {wall_s:.2}s = {tokens_per_s:.1} tok/s, ttft p50/p99/p999 {:.1}/{:.1}/{:.1} ms, step p99 {:.0} us, {} fused admissions ({} speculative)",
            m.tokens,
            m.p50_ttft_ms,
            m.p99_ttft_ms,
            m.p999_ttft_ms,
            m.p99_step_us,
            m.fused_admissions,
            m.speculative_admissions
        );
        points.push(TracePoint {
            shards,
            pipelined,
            tokens: m.tokens,
            wall_s,
            tokens_per_s,
            p50_ttft_ms: m.p50_ttft_ms,
            p99_ttft_ms: m.p99_ttft_ms,
            p999_ttft_ms: m.p999_ttft_ms,
            p50_step_us: m.p50_step_us,
            p99_step_us: m.p99_step_us,
            p999_step_us: m.p999_step_us,
            fused: m.fused_admissions,
            speculative: m.speculative_admissions,
        });
        sched.shutdown().expect("driver shutdown");
    }

    // decode-only series: one full 8-lane batch stepped to context
    // exhaustion through the engine API — no admission, prefill, or
    // queueing in the measurement, so this isolates exactly what
    // cross-request pipeline parallelism accelerates (the acceptance
    // bar: pipelined >= 1.3x sequential at 4 shards)
    println!("\n== decode-only: 8 lanes to context exhaustion, shards 1/2/4, pipelining off/on ==");
    struct DecodePoint {
        shards: usize,
        pipelined: bool,
        tokens: usize,
        wall_s: f64,
        tokens_per_s: f64,
    }
    let decode_reqs: Vec<entquant::coordinator::batcher::Request> = (0..8u64)
        .map(|i| entquant::coordinator::batcher::Request {
            id: i,
            prompt: (0..2 + (i as usize * 5) % (SEQ - 4))
                .map(|j| ((i as usize * 13 + j * 7) % 64) as u8)
                .collect(),
            max_new_tokens: CTX,
        })
        .collect();
    let decode_batch = entquant::coordinator::batcher::pack(&decode_reqs, &[(8, SEQ)]).remove(0);
    let mut decode_points: Vec<DecodePoint> = Vec::new();
    for (shards, pipelined) in [(1usize, false), (2, false), (2, true), (4, false), (4, true)] {
        let plan = ShardPlan::balance(&cm, shards);
        let rts: Vec<Runtime> = (0..plan.n_shards()).map(|_| native_rt(&cm)).collect();
        let opts = EngineOpts { stage_pipeline: pipelined, ..Default::default() };
        let engine = ShardedEngine::new(rts, &cm, plan, &opts).expect("shards");
        let mut st = engine.prefill_state(&decode_batch).expect("prefill");
        let t0 = std::time::Instant::now();
        let mut tokens = 0usize;
        while engine.decode_step(&mut st).expect("decode step") {
            tokens += decode_batch.requests.len();
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let tokens_per_s = tokens as f64 / wall_s;
        println!(
            "decode shards={shards} pipelined={pipelined}: {tokens} tokens in {wall_s:.2}s = {tokens_per_s:.1} tok/s"
        );
        decode_points.push(DecodePoint { shards, pipelined, tokens, wall_s, tokens_per_s });
    }
    let decode_rate = |shards: usize, pipelined: bool| -> f64 {
        decode_points
            .iter()
            .find(|p| p.shards == shards && p.pipelined == pipelined)
            .map_or(0.0, |p| p.tokens_per_s)
    };
    let speedup_4 = {
        let seq = decode_rate(4, false);
        if seq > 0.0 {
            decode_rate(4, true) / seq
        } else {
            0.0
        }
    };
    println!("pipeline speedup at 4 shards: {speedup_4:.2}x");

    // compressed KV-cache series: the same 8-lane batch decoded to
    // context exhaustion per kv mode on a single engine.  At the wall
    // every lane's cache is full (len == CTX), so the sweep reads the
    // steady-state footprint: resident bytes per lane, the compression
    // ratio vs the raw f32 cache, and how many lanes would fit in the
    // memory the raw cache spends on these 8 (capacity uplift).  The
    // ring must absorb every materialization — fresh_allocs is pinned
    // to 0 — and the default QuantTail(F8) config must clear 3x.
    println!("\n== kv cache: 8 lanes to context exhaustion per kv mode ==");
    struct KvPoint {
        mode: &'static str,
        tokens_per_s: f64,
        raw_bytes_per_lane: usize,
        resident_bytes_per_lane: usize,
        compressed_bytes_per_lane: usize,
        compression_ratio: f64,
        lanes_in_raw8_budget: usize,
        fresh_allocs: usize,
    }
    let kv_modes: [(&'static str, KvMode); 4] = [
        ("raw", KvMode::Raw),
        ("lossless", KvMode::LosslessTail),
        ("f8", KvMode::QuantTail(TailFmt::F8)),
        ("bf16", KvMode::QuantTail(TailFmt::Bf16)),
    ];
    let mut kv_points: Vec<KvPoint> = Vec::new();
    for (name, mode) in kv_modes {
        let opts = EngineOpts { kv: KvCfg { mode, ..Default::default() }, ..Default::default() };
        let engine = ServingEngine::new(native_rt(&cm), cm.clone(), opts).expect("engine");
        let mut st = engine.prefill_state(&decode_batch).expect("prefill");
        let t0 = std::time::Instant::now();
        let mut tokens = 0usize;
        while engine.decode_step(&mut st).expect("decode step") {
            tokens += decode_batch.requests.len();
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let b = st.kv_bytes();
        let lanes = decode_batch.requests.len();
        let ratio = b.raw as f64 / b.resident as f64;
        let fresh = engine.kv_fresh_allocs();
        assert_eq!(fresh, 0, "kv mode {name}: steady-state decode must stay on the ring");
        let point = KvPoint {
            mode: name,
            tokens_per_s: tokens as f64 / wall_s,
            raw_bytes_per_lane: b.raw / lanes,
            resident_bytes_per_lane: b.resident / lanes,
            compressed_bytes_per_lane: b.compressed / lanes,
            compression_ratio: ratio,
            lanes_in_raw8_budget: b.raw / (b.resident / lanes),
            fresh_allocs: fresh,
        };
        println!(
            "kv mode={name}: {:.1} tok/s, {} B/lane resident (raw {} B/lane, {:.2}x), {} lanes fit in the raw 8-lane budget",
            point.tokens_per_s,
            point.resident_bytes_per_lane,
            point.raw_bytes_per_lane,
            point.compression_ratio,
            point.lanes_in_raw8_budget
        );
        if mode == KvMode::QuantTail(TailFmt::F8) {
            assert!(
                ratio >= 3.0,
                "QuantTail(F8) at the default window must compress >= 3x (got {ratio:.2}x)"
            );
        }
        kv_points.push(point);
    }

    // fault drills: kill one shard at a scripted decode step mid-trace
    // on a 2-shard stack — the trace must still complete with zero
    // failures.  Run once with the incremental recovery splice (plus an
    // armed rejoin, completing the contract→expand cycle) and once with
    // the legacy full reopen, tracking the recovery stall each pays and
    // the shared-storage gauges.
    struct DrillPoint {
        requests: usize,
        reroutes: usize,
        rejoins: usize,
        recovery_stall_ms: f64,
        spliced_blocks: usize,
        weight_copies: usize,
        resident_compressed_bytes: usize,
        wall_s: f64,
    }
    let run_drill = |splice: bool, rejoin: bool| -> DrillPoint {
        let plan = ShardPlan::balance(&cm, 2);
        let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 4, block: 0 }]);
        let rts: Vec<Runtime> = (0..plan.n_shards())
            .map(|i| {
                native_rt(&cm).with_fault(FaultRuntime::new(
                    Arc::clone(&faults),
                    i,
                    plan.ranges[i].len(),
                ))
            })
            .collect();
        let opts = EngineOpts { splice, ..Default::default() };
        let engine = ShardedEngine::new(rts, &cm, plan, &opts).expect("shards");
        if rejoin {
            engine.arm_rejoin(native_rt(&cm), 2);
        }
        let sched = Scheduler::new(engine, SchedulerOpts { paused: true, ..Default::default() });
        let n_drill = n_requests / 2;
        for i in 0..n_drill as u64 {
            let len = 2 + (i as usize * 5) % (SEQ - 4);
            let prompt: Vec<u8> =
                (0..len).map(|j| ((i as usize * 13 + j * 7) % 64) as u8).collect();
            sched.submit(prompt, max_new).expect_admitted();
        }
        let t0 = std::time::Instant::now();
        sched.resume();
        sched.drain(std::time::Duration::from_secs(600)).expect("drain");
        let wall_s = t0.elapsed().as_secs_f64();
        let m = sched.metrics();
        assert_eq!(m.completed, n_drill, "fault drill must complete every request");
        assert_eq!(m.failed, 0, "fault drill must not fail requests");
        assert_eq!(m.weight_copies, 1, "one logical weight copy, always");
        println!(
            "drill(splice={splice}, rejoin={rejoin}): {} requests survived ({} reroute(s), {} rejoin(s), {:.2} ms recovery stall, {} spliced block(s), weight_copies={}) in {wall_s:.2}s",
            n_drill,
            m.reroutes,
            m.rejoins,
            m.recovery_stall_ms,
            m.recovery_spliced_blocks,
            m.weight_copies
        );
        sched.shutdown().expect("driver shutdown");
        DrillPoint {
            requests: n_drill,
            reroutes: m.reroutes,
            rejoins: m.rejoins,
            recovery_stall_ms: m.recovery_stall_ms,
            spliced_blocks: m.recovery_spliced_blocks,
            weight_copies: m.weight_copies,
            resident_compressed_bytes: m.resident_compressed_bytes,
            wall_s,
        }
    };
    println!("\n== fault drill: scripted shard kill at 2 shards (splice + rejoin) ==");
    let drill = run_drill(true, true);
    println!("\n== fault drill: legacy full reopen (stall comparison) ==");
    let drill_full = run_drill(false, false);

    // tracked trajectory: tokens/s and p50 ttft per shard count
    let mut series = String::new();
    for (i, p) in points.iter().enumerate() {
        if i > 0 {
            series.push_str(",\n");
        }
        series.push_str(&format!(
            concat!(
                "    {{\"shards\": {}, \"stage_pipeline\": {}, \"tokens\": {}, \"wall_s\": {:.3}, \"tokens_per_s\": {:.1}, ",
                "\"p50_ttft_ms\": {:.2}, \"p99_ttft_ms\": {:.2}, \"p999_ttft_ms\": {:.2}, ",
                "\"p50_step_us\": {:.1}, \"p99_step_us\": {:.1}, \"p999_step_us\": {:.1}, ",
                "\"fused_admissions\": {}, \"speculative_admissions\": {}}}"
            ),
            p.shards,
            p.pipelined,
            p.tokens,
            p.wall_s,
            p.tokens_per_s,
            p.p50_ttft_ms,
            p.p99_ttft_ms,
            p.p999_ttft_ms,
            p.p50_step_us,
            p.p99_step_us,
            p.p999_step_us,
            p.fused,
            p.speculative
        ));
    }
    let mut decode_series = String::new();
    for (i, p) in decode_points.iter().enumerate() {
        if i > 0 {
            decode_series.push_str(",\n");
        }
        decode_series.push_str(&format!(
            "    {{\"shards\": {}, \"stage_pipeline\": {}, \"tokens\": {}, \"wall_s\": {:.3}, \"tokens_per_s\": {:.1}}}",
            p.shards, p.pipelined, p.tokens, p.wall_s, p.tokens_per_s
        ));
    }
    let mut kv_series = String::new();
    for (i, p) in kv_points.iter().enumerate() {
        if i > 0 {
            kv_series.push_str(",\n");
        }
        kv_series.push_str(&format!(
            concat!(
                "    {{\"kv_mode\": \"{}\", \"tokens_per_s\": {:.1}, \"raw_bytes_per_lane\": {}, ",
                "\"resident_bytes_per_lane\": {}, \"compressed_bytes_per_lane\": {}, ",
                "\"compression_ratio\": {:.3}, \"lanes_in_raw8_budget\": {}, \"fresh_allocs\": {}}}"
            ),
            p.mode,
            p.tokens_per_s,
            p.raw_bytes_per_lane,
            p.resident_bytes_per_lane,
            p.compressed_bytes_per_lane,
            p.compression_ratio,
            p.lanes_in_raw8_budget,
            p.fresh_allocs
        ));
    }
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"serve\",\n",
            "  \"smoke\": {smoke},\n",
            "  \"requests\": {requests},\n",
            "  \"max_new\": {max_new},\n",
            "  \"trace\": [\n{series}\n  ],\n",
            "  \"decode\": [\n{decode_series}\n  ],\n",
            "  \"kv\": [\n{kv_series}\n  ],\n",
            "  \"pipeline_speedup_4shards\": {speedup_4:.3},\n",
            "  \"memory\": {{\"weight_copies\": {copies}, \"resident_compressed_bytes\": {resident}}},\n",
            "  \"fault_drill\": {{\"shards\": 2, \"requests\": {drill_requests}, \"reroutes\": {drill_reroutes}, \"rejoins\": {drill_rejoins}, \"spliced_blocks\": {drill_spliced}, \"recovery_stall_ms_splice\": {stall_splice:.3}, \"recovery_stall_ms_full\": {stall_full:.3}, \"wall_s\": {drill_wall:.3}}}\n",
            "}}\n"
        ),
        smoke = smoke,
        requests = n_requests,
        max_new = max_new,
        series = series,
        decode_series = decode_series,
        kv_series = kv_series,
        speedup_4 = speedup_4,
        copies = drill.weight_copies,
        resident = drill.resident_compressed_bytes,
        drill_requests = drill.requests,
        drill_reroutes = drill.reroutes,
        drill_rejoins = drill.rejoins,
        drill_spliced = drill.spliced_blocks,
        stall_splice = drill.recovery_stall_ms,
        stall_full = drill_full.recovery_stall_ms,
        drill_wall = drill.wall_s,
    );
    let default_name = if smoke { "BENCH_serve.smoke.json" } else { "BENCH_serve.json" };
    let path = std::env::var("BENCH_SERVE_JSON")
        .unwrap_or_else(|_| format!("{}/{default_name}", env!("CARGO_MANIFEST_DIR")));
    std::fs::write(&path, &json).expect("writing bench json");
    println!("\nwrote {path}");
}
