//! Bench: fast table regenerators (Table 1, Fig A.1/B.1 sweeps) — the
//! no-eval subset that is cheap enough for `cargo bench`.  The full
//! evaluation tables run through the CLI (`entquant table2` etc.).

mod common;

use common::artifacts_ready;
use entquant::entropy;
use entquant::model::load_eqw;
use entquant::store::pipeline::{compress_model, CompressOpts};

fn main() {
    if !artifacts_ready() {
        println!("artifacts missing; run `make artifacts` first");
        return;
    }
    let art = entquant::artifacts_dir();
    let model = load_eqw(&format!("{art}/model_S.eqw")).unwrap();

    println!("== Table 1: unique values (fixed vs EntQuant) ==");
    println!("{:<10} {:>10} {:>14}", "bits", "fixed", "entquant");
    for bits in [4.0f64, 3.0, 2.0] {
        let (cm, _) = compress_model(
            &model,
            &CompressOpts { target_bits: Some(bits), ..Default::default() },
        )
        .unwrap();
        let q = cm.to_qmodel().unwrap();
        let mut uniq = 0usize;
        let mut n = 0usize;
        for b in &q.blocks {
            for l in &b.linears {
                use std::collections::BTreeSet;
                let set: BTreeSet<u32> = l.code_values().data.iter().map(|v| v.to_bits()).collect();
                uniq += set.len();
                n += 1;
            }
        }
        println!("{bits:<10} {:>10} {:>14.2}", 1u64 << (bits as u32), uniq as f64 / n as f64);
    }

    println!("\n== Fig A.1 sweep: lambda -> entropy (S model) ==");
    for lam in [0.1f64, 1.0, 10.0, 100.0, 1000.0] {
        let (cm, rep) = compress_model(&model, &CompressOpts { lam, ..Default::default() }).unwrap();
        // verify the stored stream really achieves the entropy
        let mut total_bits = 0usize;
        let mut syms = 0usize;
        for b in &cm.blocks {
            total_bits += b.bitstream.serialized_len() * 8;
            syms += b.n_symbols();
        }
        println!(
            "lam {lam:>8.1}: H {:.3} bits/param, stored {:.3} bits/param, sparsity {:.3}",
            rep.mean_entropy_bits,
            total_bits as f64 / syms as f64,
            rep.mean_sparsity
        );
        assert!(
            total_bits as f64 / syms as f64 <= rep.mean_entropy_bits + 0.25,
            "coder must track entropy"
        );
    }
    let _ = entropy::entropy_of(&[0u8]);
}
