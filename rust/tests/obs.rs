//! Integration tests for the `obs` tracing subsystem, end-to-end on
//! the native executor:
//!
//! * histogram properties — bucket bounds, merge == concatenated
//!   recording, percentile monotonicity, and agreement with the exact
//!   nearest-rank `serve::metrics::percentile` within the documented
//!   1/32 relative bucket error;
//! * ring wrap/overflow behavior surfaced through the `Tracer`;
//! * the steady-state allocation-free pin on the record path, measured
//!   by a counting global allocator (per-thread, so parallel tests
//!   cannot perturb the count);
//! * a scripted serve run: every request records exactly one terminal
//!   event, spans nest (prefill B/E and lane occupancy balance), and
//!   two runs of the same scripted scenario export byte-identical
//!   traces — the tick domain carries no wall-clock jitter;
//! * the committed sample trace (`rust/tests/data/sample_trace.json`)
//!   pins the Chrome export format byte-for-byte
//!   (`OBS_BLESS_SAMPLE=1` regenerates it after a deliberate change).

use entquant::coordinator::EngineOpts;
use entquant::model::loader::synthetic_model;
use entquant::model::Config;
use entquant::obs::{
    bucket_bounds, bucket_index, export_chrome_events, Event, EventKind, EventRing, Log2Hist,
    N_BUCKETS, Tracer,
};
use entquant::runtime::fault::{FaultPlan, FaultRuntime, FaultScript};
use entquant::runtime::{Manifest, Runtime};
use entquant::serve::metrics::percentile;
use entquant::serve::{Scheduler, SchedulerOpts, ShardPlan, ShardedEngine};
use entquant::store::container::CompressedModel;
use entquant::store::pipeline::{compress_model, CompressOpts};
use std::cell::Cell;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

// ------------------------------------------------- counting allocator

/// Counts heap allocations per thread, so the alloc-free pin below is
/// immune to other test threads allocating concurrently.  The counter
/// is a const-initialised `Cell<u64>` thread-local: no destructor, no
/// lazy init, hence no allocation from inside `alloc` itself.
struct CountingAlloc;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(Cell::get)
}

unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        std::alloc::System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

// ------------------------------------------------------ test fixtures

const SEQ: usize = 16;
const CTX: usize = 28;

fn cm() -> &'static CompressedModel {
    static CM: OnceLock<CompressedModel> = OnceLock::new();
    CM.get_or_init(|| {
        let m = synthetic_model(
            Config {
                name: "T".into(),
                vocab: 64,
                d_model: 16,
                n_layers: 6,
                n_heads: 2,
                d_ff: 24,
                max_ctx: 32,
            },
            51,
        );
        compress_model(&m, &CompressOpts { lam: 0.3, max_iters: 6, ..Default::default() })
            .unwrap()
            .0
    })
}

fn native_rt(model: &CompressedModel) -> Runtime {
    Runtime::native(Manifest::synthetic(
        model.config.clone(),
        vec![(1, SEQ), (2, SEQ), (4, SEQ)],
        vec![(1, CTX), (2, CTX), (4, CTX)],
    ))
}

fn sharded(n: usize) -> ShardedEngine {
    let model = cm().clone();
    let plan = ShardPlan::balance(&model, n);
    let rts: Vec<Runtime> = (0..plan.n_shards()).map(|_| native_rt(&model)).collect();
    ShardedEngine::new(rts, &model, plan, &EngineOpts::default()).unwrap()
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// -------------------------------------------------- histogram properties

#[test]
fn hist_buckets_contain_their_values() {
    let mut seed = 7u64;
    for _ in 0..4096 {
        let v = splitmix64(&mut seed) >> (splitmix64(&mut seed) % 64);
        let i = bucket_index(v);
        assert!(i < N_BUCKETS);
        let (lo, hi) = bucket_bounds(i);
        assert!((lo..=hi).contains(&v), "v={v} outside bucket {i} [{lo}, {hi}]");
    }
}

#[test]
fn hist_merge_equals_concatenated_recording() {
    let mut seed = 11u64;
    let a: Vec<u64> = (0..500).map(|_| splitmix64(&mut seed) % 1_000_000).collect();
    let b: Vec<u64> = (0..300).map(|_| splitmix64(&mut seed) % 50).collect();
    let (ha, hb, hall) = (Log2Hist::new(), Log2Hist::new(), Log2Hist::new());
    for &v in &a {
        ha.record(v);
        hall.record(v);
    }
    for &v in &b {
        hb.record(v);
        hall.record(v);
    }
    let mut merged = ha.snapshot();
    merged.merge(&hb.snapshot());
    assert_eq!(merged, hall.snapshot(), "merge must equal recording both streams");
}

#[test]
fn hist_percentiles_match_nearest_rank_within_bucket_error() {
    let mut seed = 13u64;
    let samples: Vec<u64> = (0..2000).map(|_| splitmix64(&mut seed) % 3_000_000).collect();
    let h = Log2Hist::new();
    for &v in &samples {
        h.record(v);
    }
    let snap = h.snapshot();
    let as_f64: Vec<f64> = samples.iter().map(|&v| v as f64).collect();
    let mut prev = 0u64;
    for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0] {
        let exact = percentile(&as_f64, q);
        let approx = snap.percentile(q);
        // the histogram reports the ranked sample's bucket upper bound:
        // never below the exact nearest-rank value, and within the
        // documented 1/32 relative error above it
        assert!(approx as f64 >= exact, "q={q}: {approx} < exact {exact}");
        assert!(
            approx as f64 <= exact + exact / 32.0 + 1.0,
            "q={q}: {approx} exceeds exact {exact} + 1/32"
        );
        assert!(approx >= prev, "q={q}: percentiles must be monotone");
        prev = approx;
    }
    // the top rank is exact (max-clamped), as is a single sample
    assert_eq!(snap.percentile(1.0), *samples.iter().max().unwrap());
    let one = Log2Hist::new();
    one.record(123_457);
    assert_eq!(one.snapshot().percentile(0.5), 123_457);
}

// --------------------------------------------------- ring via tracer

#[test]
fn tracer_survives_ring_wrap_and_counts_overflow() {
    // ring of 8: drain every few records and nothing is lost across
    // many laps
    let t = Tracer::new(8, 1 << 12);
    for i in 0..100u64 {
        t.record(EventKind::DecodeStep, 0, i, 0);
        if i % 3 == 0 {
            t.drain();
        }
    }
    let ev = t.events();
    assert_eq!(ev.len(), 100);
    assert!(ev.iter().enumerate().all(|(i, e)| e.a == i as u64), "FIFO across laps");
    assert_eq!(t.dropped(), 0);

    // without draining, a full ring drops newest and counts it
    let t = Tracer::new(8, 1 << 12);
    for i in 0..12u64 {
        t.record(EventKind::DecodeStep, 0, i, 0);
    }
    assert_eq!(t.dropped(), 4);
    let ev = t.events();
    assert_eq!(ev.len(), 8, "earliest events are the ones retained");
    assert!(ev.iter().enumerate().all(|(i, e)| e.a == i as u64));
}

#[test]
fn ring_rejects_non_power_of_two() {
    let r = EventRing::new(16);
    assert_eq!(r.capacity(), 16);
    let result = std::panic::catch_unwind(|| EventRing::new(12));
    assert!(result.is_err(), "non-power-of-two capacity must be rejected");
}

// ------------------------------------------------------ alloc-free pin

#[test]
fn record_path_is_allocation_free_in_steady_state() {
    let t = Tracer::new(1 << 10, 1 << 12);
    let h = Log2Hist::new();
    t.set_tick(1);
    // warm-up (first records touch nothing lazily, but keep the pin
    // honest about *steady state*)
    t.record(EventKind::DecodeStep, 0, 0, 0);
    h.record(1);
    let before = thread_allocs();
    for i in 0..512u64 {
        t.set_tick(i);
        t.record(EventKind::DecodeStep, 0, i, i % 7);
        h.record(i * 31);
    }
    let after = thread_allocs();
    assert_eq!(after - before, 0, "record path must not allocate");
}

// ------------------------------------------------- scripted serve trace

/// Run a deterministic scripted scenario — paused scheduler,
/// sequential submits, resume, drain — and return the submitted ids
/// plus the tracer's event stream and both exports.
fn scripted_run(n_requests: u64, max_new: usize) -> (Vec<u64>, Vec<Event>, String, String) {
    let sched = Scheduler::new(sharded(2), SchedulerOpts { paused: true, ..Default::default() });
    let ids: Vec<u64> = (0..n_requests)
        .map(|i| {
            let len = 2 + (i as usize * 5) % (SEQ - 4);
            let prompt: Vec<u8> =
                (0..len).map(|j| ((i as usize * 13 + j * 7) % 64) as u8).collect();
            sched.submit(prompt, max_new).expect_admitted()
        })
        .collect();
    sched.resume();
    sched.drain(Duration::from_secs(600)).expect("drain");
    let tracer = sched.tracer();
    let events = tracer.events();
    let jsonl = tracer.export_jsonl(None);
    let chrome = tracer.export_chrome();
    sched.shutdown().expect("driver shutdown");
    (ids, events, jsonl, chrome)
}

#[test]
fn scripted_trace_has_exactly_one_terminal_event_per_request() {
    let (ids, events, _, _) = scripted_run(5, 4);
    for &id in &ids {
        let terminals: Vec<&Event> =
            events.iter().filter(|e| e.id == id && e.kind.is_terminal()).collect();
        assert_eq!(terminals.len(), 1, "request {id}: exactly one terminal event");
        assert_eq!(terminals[0].kind, EventKind::Done, "scripted run completes normally");
        let submit = events.iter().find(|e| e.id == id && e.kind == EventKind::Submit).unwrap();
        assert!(submit.tick <= terminals[0].tick, "submit precedes the terminal");
        assert_eq!(submit.b, 4, "submit carries max_new");
    }
    // the driver tick counter advanced and was recorded
    assert!(events.iter().any(|e| e.kind == EventKind::DecodeStep && e.tick > 0));
}

#[test]
fn scripted_trace_spans_nest() {
    let (ids, events, _, _) = scripted_run(5, 4);
    for &id in &ids {
        // prefill B/E balance, scanning depth never negative
        let mut depth = 0i64;
        for e in events.iter().filter(|e| e.id == id) {
            match e.kind {
                EventKind::PrefillStart => depth += 1,
                EventKind::PrefillEnd => {
                    depth -= 1;
                    assert!(depth >= 0, "request {id}: PrefillEnd without PrefillStart");
                }
                _ => {}
            }
        }
        assert_eq!(depth, 0, "request {id}: prefill spans must balance");
    }
    // lane occupancy balances per lane, and every occupied lane frees
    let mut lane_depth = std::collections::HashMap::new();
    for e in &events {
        match e.kind {
            EventKind::LaneStart => *lane_depth.entry(e.a).or_insert(0i64) += 1,
            EventKind::LaneEnd => {
                let d = lane_depth.entry(e.a).or_insert(0i64);
                *d -= 1;
                assert!(*d >= 0, "lane {}: LaneEnd without LaneStart", e.a);
            }
            _ => {}
        }
    }
    assert!(lane_depth.values().all(|&d| d == 0), "every lane span must close");
    assert!(!lane_depth.is_empty(), "the scripted run must occupy lanes");
}

#[test]
fn scripted_trace_is_byte_identical_across_runs() {
    let (_, _, jsonl_a, chrome_a) = scripted_run(5, 4);
    let (_, _, jsonl_b, chrome_b) = scripted_run(5, 4);
    assert_eq!(jsonl_a, jsonl_b, "tick-domain JSONL must replay byte-identically");
    assert_eq!(chrome_a, chrome_b, "Chrome export must replay byte-identically");
}

#[test]
fn fault_trace_records_shard_lifecycle_and_requests_survive() {
    let model = cm().clone();
    let plan = ShardPlan::balance(&model, 2);
    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 3, block: 0 }]);
    let rts: Vec<Runtime> = (0..plan.n_shards())
        .map(|i| {
            native_rt(&model)
                .with_fault(FaultRuntime::new(Arc::clone(&faults), i, plan.ranges[i].len()))
        })
        .collect();
    let engine = ShardedEngine::new(rts, &model, plan, &EngineOpts::default()).unwrap();
    let sched = Scheduler::new(engine, SchedulerOpts { paused: true, ..Default::default() });
    let ids: Vec<u64> = (0..4u64)
        .map(|i| {
            let prompt: Vec<u8> = (0..4).map(|j| ((i * 13 + j * 7) % 64) as u8).collect();
            sched.submit(prompt, 6).expect_admitted()
        })
        .collect();
    sched.resume();
    sched.drain(Duration::from_secs(600)).expect("drain");
    let events = sched.tracer().events();
    sched.shutdown().expect("driver shutdown");

    assert!(events.iter().any(|e| e.kind == EventKind::ShardFault), "fault recorded");
    let reroute = events.iter().find(|e| e.kind == EventKind::Reroute).expect("reroute");
    assert_eq!(reroute.a, 1, "shard 1 was the rerouted source");
    assert!(
        events.iter().any(|e| e.kind == EventKind::SpliceStart)
            == events.iter().any(|e| e.kind == EventKind::SpliceEnd),
        "splice spans balance"
    );
    for &id in &ids {
        let terminals: Vec<&Event> =
            events.iter().filter(|e| e.id == id && e.kind.is_terminal()).collect();
        assert_eq!(terminals.len(), 1, "request {id}: exactly one terminal even under faults");
        assert_eq!(terminals[0].kind, EventKind::Done, "requests survive the reroute");
    }
}

// ------------------------------------------------- committed sample pin

/// The committed sample trace pins the Chrome export format: a fixed
/// event stream must render byte-for-byte as
/// `rust/tests/data/sample_trace.json`.  After a deliberate format
/// change, regenerate with `OBS_BLESS_SAMPLE=1 cargo test -q sample`.
#[test]
fn sample_trace_format_is_pinned() {
    let mk = |tick, kind, id, a, b| Event { tick, kind, id, a, b };
    let events = [
        mk(0, EventKind::Submit, 1, 4, 8),
        mk(0, EventKind::Admit, 1, 1, 0),
        mk(0, EventKind::Shed, u64::MAX, 1, 6),
        mk(0, EventKind::PrefillStart, 1, u64::MAX, 0),
        mk(0, EventKind::PrefillEnd, 1, u64::MAX, 0),
        mk(0, EventKind::LaneStart, 1, 0, 0),
        mk(1, EventKind::DecodeStep, 0, 1, 0),
        mk(1, EventKind::FirstToken, 1, 1, 0),
        mk(2, EventKind::ShardFault, 1, 0, 0),
        mk(2, EventKind::Reroute, 1, 1, 0),
        mk(2, EventKind::SpliceStart, 0, 3, 0),
        mk(2, EventKind::SpliceEnd, 0, 3, 0),
        mk(3, EventKind::LaneEnd, 1, 0, 0),
        mk(3, EventKind::Done, 1, 3, 0),
    ];
    let rendered = export_chrome_events(&events);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/sample_trace.json");
    if std::env::var("OBS_BLESS_SAMPLE").as_deref() == Ok("1") {
        std::fs::write(path, &rendered).expect("blessing sample trace");
        return;
    }
    let committed = std::fs::read_to_string(path).expect("committed sample trace");
    assert_eq!(
        rendered, committed,
        "Chrome export format drifted from the committed sample \
         (OBS_BLESS_SAMPLE=1 to regenerate after a deliberate change)"
    );
}
