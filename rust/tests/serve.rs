//! Integration tests for the serve subsystem, end-to-end on the native
//! executor (no artifacts, no PJRT): sharded-vs-single byte identity,
//! a 64-request synthetic trace through the continuous-batching
//! scheduler on 2 shards, fused mid-flight admission, the cancel
//! lifecycle, scripted shard-failure reroutes (decode and prefill),
//! zero-cost speculative admission, and cross-request pipeline
//! parallelism (micro-batched decode vs the sequential walk, with a
//! mid-step fault while micro-batches are in flight).
//!
//! The load-bearing invariant everywhere: a request's generation is
//! byte-identical to a solo `ServingEngine::generate` run, whatever
//! shard count, batch composition, admission order — or shard failure
//! — served it.

use entquant::coordinator::{
    pack, Batch, DecodeState, EngineOpts, KvCfg, KvMode, Request, Residency, ServingEngine,
    TailFmt,
};
use entquant::model::loader::synthetic_model;
use entquant::model::Config;
use entquant::runtime::fault::{FaultPlan, FaultRuntime, FaultScript};
use entquant::runtime::{Manifest, Runtime};
use entquant::serve::{
    Admission, MetricsSnapshot, Scheduler, SchedulerOpts, ShardPlan, ShardedEngine, Status,
    StepEngine, Supervisor, SupervisorOpts,
};
use entquant::store::container::CompressedModel;
use entquant::store::pipeline::{compress_model, CompressOpts};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

const SEQ: usize = 16;
const CTX: usize = 28;

fn cm() -> &'static CompressedModel {
    static CM: OnceLock<CompressedModel> = OnceLock::new();
    CM.get_or_init(|| {
        let m = synthetic_model(
            Config {
                name: "T".into(),
                vocab: 64,
                d_model: 16,
                n_layers: 6,
                n_heads: 2,
                d_ff: 24,
                max_ctx: 32,
            },
            51,
        );
        compress_model(&m, &CompressOpts { lam: 0.3, max_iters: 6, ..Default::default() })
            .unwrap()
            .0
    })
}

fn native_rt(model: &CompressedModel) -> Runtime {
    Runtime::native(Manifest::synthetic(
        model.config.clone(),
        vec![(1, SEQ), (2, SEQ), (4, SEQ)],
        vec![(1, CTX), (2, CTX), (4, CTX)],
    ))
}

fn single_engine() -> ServingEngine {
    let model = cm().clone();
    let rt = native_rt(&model);
    ServingEngine::new(rt, model, EngineOpts::default()).unwrap()
}

fn sharded(n: usize) -> ShardedEngine {
    sharded_opts(n, EngineOpts::default())
}

fn sharded_opts(n: usize, opts: EngineOpts) -> ShardedEngine {
    let model = cm().clone();
    let plan = ShardPlan::balance(&model, n);
    let rts: Vec<Runtime> = (0..plan.n_shards()).map(|_| native_rt(&model)).collect();
    ShardedEngine::new(rts, &model, plan, &opts).unwrap()
}

/// A sharded engine whose per-shard runtimes are armed with a shared
/// fault plan (each shard counts its own decode steps).
fn sharded_with_faults(n: usize, faults: &Arc<FaultPlan>) -> ShardedEngine {
    sharded_with_faults_opts(n, faults, EngineOpts::default())
}

fn sharded_with_faults_opts(n: usize, faults: &Arc<FaultPlan>, opts: EngineOpts) -> ShardedEngine {
    let model = cm().clone();
    let plan = ShardPlan::balance(&model, n);
    let rts: Vec<Runtime> = (0..plan.n_shards())
        .map(|i| {
            native_rt(&model)
                .with_fault(FaultRuntime::new(Arc::clone(faults), i, plan.ranges[i].len()))
        })
        .collect();
    ShardedEngine::new(rts, &model, plan, &opts).unwrap()
}

/// Counts `prefill_state` calls on the way through to the inner
/// engine — how the speculative-admission test proves adoption costs
/// zero extra prefill steps versus the non-speculative scheduler.
struct CountingEngine<E: StepEngine> {
    inner: E,
    prefills: Arc<AtomicUsize>,
}

impl<E: StepEngine> StepEngine for CountingEngine<E> {
    fn prefill_state(&self, batch: &Batch) -> anyhow::Result<DecodeState> {
        self.prefills.fetch_add(1, Ordering::SeqCst);
        self.inner.prefill_state(batch)
    }

    fn decode_step(&self, st: &mut DecodeState) -> anyhow::Result<bool> {
        self.inner.decode_step(st)
    }

    fn prefill_slots(&self) -> Vec<(usize, usize)> {
        self.inner.prefill_slots()
    }

    fn decode_slots(&self) -> Vec<(usize, usize)> {
        self.inner.decode_slots()
    }

    fn fresh_allocs_per_shard(&self) -> Vec<usize> {
        self.inner.fresh_allocs_per_shard()
    }

    fn try_recover(&self) -> bool {
        self.inner.try_recover()
    }
}

/// Deterministic prompt inside the tiny model's vocab (64).
fn req(id: u64, len: usize) -> Request {
    Request {
        id,
        prompt: (0..len.max(1)).map(|i| ((id as usize * 13 + i * 7) % 64) as u8).collect(),
        max_new_tokens: 8,
    }
}

/// Solo reference: the request alone through the monolithic engine.
fn reference(engine: &ServingEngine, r: &Request, max_new: usize) -> Vec<u8> {
    let batch = &pack(std::slice::from_ref(r), &[(1, SEQ)])[0];
    engine.generate(batch, max_new).unwrap().0.remove(0)
}

#[test]
fn sharded_generations_byte_identical_across_shard_counts() {
    let reqs: Vec<Request> = (0..4).map(|i| req(i, 4 + i as usize * 3)).collect();
    let batch = &pack(&reqs, &[(4, SEQ)])[0];
    let engine = single_engine();
    let (want, _) = engine.generate(batch, 8).unwrap();
    for shards in [1usize, 2, 3] {
        let se = sharded(shards);
        assert_eq!(se.n_shards(), shards);
        // two rounds: the second exercises arena recycling end-to-end
        for round in 0..2 {
            let (got, metrics) = se.generate(batch, 8).unwrap();
            assert_eq!(got, want, "shards={shards} round={round}");
            assert_eq!(metrics.decode_tokens, 7);
        }
        let allocs = se.fresh_allocs();
        assert_eq!(allocs.len(), shards);
        assert!(
            allocs.iter().all(|&a| a == 0),
            "shards={shards}: fresh allocs {allocs:?} (arena must stay steady-state)"
        );
    }
}

#[test]
fn trace_of_64_requests_through_scheduler_matches_single_engine() {
    let engine = single_engine();
    let reqs: Vec<Request> = (0..64).map(|i| req(i, 1 + (i as usize * 5) % 14)).collect();
    let max_new = |id: u64| 2 + (id as usize % 7);
    let want: Vec<Vec<u8>> = reqs.iter().map(|r| reference(&engine, r, max_new(r.id))).collect();

    let sched = Scheduler::new(sharded(2), SchedulerOpts { paused: true, ..Default::default() });
    // 56 requests queue up-front; the last 8 arrive mid-trace
    let mut ids: Vec<u64> = reqs[..56]
        .iter()
        .map(|r| sched.submit(r.prompt.clone(), max_new(r.id)).expect_admitted())
        .collect();
    sched.resume();
    std::thread::sleep(Duration::from_millis(5));
    for r in &reqs[56..] {
        ids.push(sched.submit(r.prompt.clone(), max_new(r.id)).expect_admitted());
    }
    sched.drain(Duration::from_secs(300)).unwrap();

    for (i, id) in ids.iter().enumerate() {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done, "request {i}");
        assert_eq!(out, want[i], "request {i} diverged from the single-engine path");
    }
    let m = sched.metrics();
    assert_eq!(m.completed, 64);
    assert_eq!(m.failed, 0);
    assert!(
        m.fused_admissions > 0,
        "continuous admission never engaged over a 64-request trace: {m:?}"
    );
    assert!(
        m.shard_fresh_allocs.iter().all(|&a| a == 0),
        "per-shard arenas must stay steady-state: {:?}",
        m.shard_fresh_allocs
    );
    assert_eq!(m.shard_fresh_allocs.len(), 2);
    assert!(m.p50_ttft_ms >= 0.0 && m.tokens > 0);
    sched.shutdown().unwrap();
}

#[test]
fn mid_trace_request_fuses_before_initial_batch_drains() {
    let engine = single_engine();
    // lane 0 retires after 3 tokens; lanes 1-3 run long
    let first: Vec<(Request, usize)> = vec![
        (req(100, 6), 3),
        (req(101, 5), 12),
        (req(102, 9), 12),
        (req(103, 4), 12),
    ];
    let late = req(200, 7);
    let late_max = 5usize;

    let sched = Scheduler::new(sharded(2), SchedulerOpts { paused: true, ..Default::default() });
    let first_ids: Vec<u64> =
        first.iter().map(|(r, mn)| sched.submit(r.prompt.clone(), *mn).expect_admitted()).collect();
    let late_id = sched.submit(late.prompt.clone(), late_max).expect_admitted();
    sched.resume();
    // soft overlap probe: watch for the late request decoding while an
    // initial request is still in flight (asserted structurally below
    // via the fused-admissions counter, which only counts grafts into a
    // live batch)
    let mut overlap_seen = false;
    let t0 = std::time::Instant::now();
    while t0.elapsed() < Duration::from_secs(60) {
        let late_state = sched.poll(late_id).unwrap();
        if !late_state.1.is_empty() {
            let initial_live = first_ids
                .iter()
                .any(|id| !sched.poll(*id).unwrap().0.is_terminal());
            overlap_seen = overlap_seen || initial_live;
        }
        if late_state.0.is_terminal() {
            break;
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    sched.drain(Duration::from_secs(60)).unwrap();

    let m = sched.metrics();
    assert!(
        m.fused_admissions >= 1,
        "the late request must graft into the in-flight batch: {m:?}"
    );
    if !overlap_seen {
        eprintln!("note: poller missed the live-overlap window (counter still proves fusion)");
    }
    // byte identity for everyone, fused or not
    for ((r, mn), id) in first.iter().zip(&first_ids) {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done);
        assert_eq!(out, reference(&engine, r, *mn), "initial request {id} diverged");
    }
    let (status, out) = sched.poll(late_id).unwrap();
    assert_eq!(status, Status::Done);
    assert_eq!(out, reference(&engine, &late, late_max), "fused request diverged");
    sched.shutdown().unwrap();
}

#[test]
fn cancel_lifecycle_queued_and_mid_decode() {
    let sched =
        Scheduler::new(single_engine(), SchedulerOpts { paused: true, ..Default::default() });
    // a full batch plus one queued victim: cancelling while queued is
    // immediate and the driver must skip it at admission time
    let keep: Vec<u64> =
        (0..4).map(|i| sched.submit(req(300 + i, 5).prompt, 4).expect_admitted()).collect();
    let victim = sched.submit(req(310, 5).prompt, 4).expect_admitted();
    sched.cancel(victim);
    assert_eq!(sched.poll(victim).unwrap().0, Status::Cancelled);
    sched.resume();
    sched.drain(Duration::from_secs(60)).unwrap();
    for id in &keep {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done);
        assert_eq!(out.len(), 4);
    }
    let (status, out) = sched.poll(victim).unwrap();
    assert_eq!(status, Status::Cancelled);
    assert!(out.is_empty(), "a queued cancel must never decode");
    let m = sched.metrics();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.completed, 4);

    // mid-decode cancel (best effort: on a fast machine the request may
    // finish first, which is also a legal outcome)
    let long = sched.submit(req(320, 6).prompt, 12).expect_admitted();
    let t0 = std::time::Instant::now();
    while t0.elapsed() < Duration::from_secs(30) {
        let (status, out) = sched.poll(long).unwrap();
        if status.is_terminal() {
            break;
        }
        if !out.is_empty() {
            sched.cancel(long);
        }
        std::thread::sleep(Duration::from_micros(50));
    }
    sched.drain(Duration::from_secs(60)).unwrap();
    let (status, out) = sched.poll(long).unwrap();
    match status {
        Status::Cancelled => assert!(out.len() < 12, "cancel must stop generation early"),
        Status::Done => assert_eq!(out.len(), 12), // finished before the cancel landed
        other => panic!("unexpected terminal state {other:?}"),
    }
    sched.shutdown().unwrap();
}

#[test]
fn shard_fault_reroutes_and_replayed_step_is_byte_identical() {
    // engine-level pin of the reroute + resumable-step contract: a
    // scripted fault kills shard 1 in the MIDDLE of a decode step
    // (block 1 of 3, so shard 1's caches are partially written), the
    // failed range reroutes onto shard 0, and replaying the very same
    // step on the very same state completes the generation
    // byte-identically to an unfaulted single-engine run.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..2).map(|i| req(500 + i, 6 + i as usize)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    let (want, _) = engine.generate(batch, 8).unwrap();

    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 1 }]);
    let se = sharded_with_faults(2, &faults);
    let mut st = se.prefill_state(batch).unwrap();
    let mut rerouted = 0;
    for _ in 0..7 {
        loop {
            match se.decode_step(&mut st) {
                Ok(true) => break,
                Ok(false) => panic!("context wall before the trace finished"),
                Err(e) => {
                    assert!(se.try_recover(), "reroute must succeed with a survivor: {e:#}");
                    rerouted += 1; // replay the interrupted step verbatim
                }
            }
        }
    }
    assert_eq!(rerouted, 1, "the scripted fault must interrupt exactly one step");
    assert_eq!(faults.fired(), 1);
    assert_eq!(se.reroutes(), 1);
    assert_eq!(se.n_shards(), 1, "the failed shard must be gone");
    let plan = se.plan();
    assert_eq!(plan.ranges, vec![0..cm().blocks.len()], "survivor must own every block");
    for (lane, w) in want.iter().enumerate() {
        assert_eq!(&st.outputs[lane], w, "lane {lane} diverged across the reroute");
    }
}

#[test]
fn scripted_shard_kill_mid_trace_stays_byte_identical() {
    // the acceptance drill: kill a shard at a scripted decode step of a
    // 32-request trace, at 2 and at 4 shards; every final token stream
    // must equal the unfaulted single-engine reference, and the reroute
    // counter must prove the failure path actually ran.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..32).map(|i| req(600 + i, 1 + (i as usize * 5) % 14)).collect();
    let max_new = |id: u64| 2 + (id as usize % 7);
    let want: Vec<Vec<u8>> = reqs.iter().map(|r| reference(&engine, r, max_new(r.id))).collect();
    for shards in [2usize, 4] {
        let faults =
            FaultPlan::scripted(vec![FaultScript { shard: shards - 1, step: 6, block: 0 }]);
        let se = sharded_with_faults(shards, &faults);
        let sched = Scheduler::new(se, SchedulerOpts { paused: true, ..Default::default() });
        let ids: Vec<u64> = reqs
            .iter()
            .map(|r| sched.submit(r.prompt.clone(), max_new(r.id)).expect_admitted())
            .collect();
        sched.resume();
        sched.drain(Duration::from_secs(300)).unwrap();
        for (i, id) in ids.iter().enumerate() {
            let (status, out) = sched.poll(*id).unwrap();
            assert_eq!(status, Status::Done, "shards={shards} request {i}");
            assert_eq!(out, want[i], "shards={shards} request {i} diverged after the reroute");
        }
        let m = sched.metrics();
        assert_eq!(m.completed, 32, "shards={shards}: {m:?}");
        assert_eq!(m.failed, 0, "shards={shards}: {m:?}");
        assert!(m.reroutes >= 1, "shards={shards}: the fault never rerouted: {m:?}");
        assert_eq!(faults.fired(), 1, "shards={shards}: the scripted fault must fire");
        assert_eq!(
            m.shard_fresh_allocs.len(),
            shards - 1,
            "shards={shards}: reroute must contract the shard set"
        );
        sched.shutdown().unwrap();
    }
}

#[test]
fn prefill_fault_reroutes_and_the_batch_replays() {
    // a shard that dies during batch formation (prefill) reroutes too:
    // the group is requeued in order and the prefill replays on the
    // recovered engine — nobody fails, outputs stay byte-identical.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..4).map(|i| req(700 + i, 5 + i as usize)).collect();
    let want: Vec<Vec<u8>> = reqs.iter().map(|r| reference(&engine, r, 6)).collect();
    let faults = FaultPlan::scripted(Vec::new());
    faults.fail_next_prefill(0);
    let se = sharded_with_faults(2, &faults);
    let sched = Scheduler::new(se, SchedulerOpts { paused: true, ..Default::default() });
    let ids: Vec<u64> =
        reqs.iter().map(|r| sched.submit(r.prompt.clone(), 6).expect_admitted()).collect();
    sched.resume();
    sched.drain(Duration::from_secs(120)).unwrap();
    for (i, id) in ids.iter().enumerate() {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done, "request {i}");
        assert_eq!(out, want[i], "request {i} diverged after the prefill reroute");
    }
    let m = sched.metrics();
    assert!(m.reroutes >= 1, "{m:?}");
    assert_eq!(m.failed, 0, "{m:?}");
    assert_eq!(faults.fired(), 1);
    sched.shutdown().unwrap();
}

#[test]
fn speculative_admission_adopts_at_zero_cost() {
    // the queue head prefills into the idle solo slot while every lane
    // is busy, steps in lockstep, and is adopted the moment a lane
    // frees — with ZERO prefills and ZERO catch-up steps at adoption
    // time, and zero extra prefill steps overall versus the
    // non-speculative scheduler.  Everything below is deterministic:
    // the whole trace is queued before `resume`.
    let engine = single_engine();
    let firsts: Vec<(Request, usize)> = vec![
        (req(800, 6), 3), // retires first, freeing a lane
        (req(801, 5), 10),
        (req(802, 9), 10),
        (req(803, 4), 10),
    ];
    let late = req(810, 7);
    let late_max = 5usize;
    let mut outputs_by_mode: Vec<Vec<Vec<u8>>> = Vec::new();
    let mut prefill_counts: Vec<usize> = Vec::new();
    for speculative in [true, false] {
        let prefills = Arc::new(AtomicUsize::new(0));
        let eng = CountingEngine { inner: sharded(2), prefills: Arc::clone(&prefills) };
        let sched = Scheduler::new(
            eng,
            SchedulerOpts { paused: true, speculative, ..Default::default() },
        );
        let ids: Vec<u64> = firsts
            .iter()
            .map(|(r, mn)| sched.submit(r.prompt.clone(), *mn).expect_admitted())
            .collect();
        let late_id = sched.submit(late.prompt.clone(), late_max).expect_admitted();
        sched.resume();
        sched.drain(Duration::from_secs(120)).unwrap();
        let m = sched.metrics();
        assert!(m.fused_admissions >= 1, "speculative={speculative}: no fusion: {m:?}");
        if speculative {
            assert!(m.speculative_admissions >= 1, "never speculated: {m:?}");
            assert_eq!(m.adoption_catchup_steps, 0, "adoption must be zero-cost: {m:?}");
            assert_eq!(m.adoption_prefills, 0, "no prefill at adoption time: {m:?}");
        } else {
            assert_eq!(m.speculative_admissions, 0, "{m:?}");
            assert!(m.adoption_catchup_steps > 0, "non-speculative pays catch-up: {m:?}");
            assert!(m.adoption_prefills >= 1, "{m:?}");
        }
        let mut outs = Vec::new();
        for ((r, mn), id) in firsts.iter().zip(&ids) {
            let (status, out) = sched.poll(*id).unwrap();
            assert_eq!(status, Status::Done, "speculative={speculative}");
            assert_eq!(out, reference(&engine, r, *mn), "speculative={speculative}");
            outs.push(out);
        }
        let (status, out) = sched.poll(late_id).unwrap();
        assert_eq!(status, Status::Done, "speculative={speculative}");
        assert_eq!(out, reference(&engine, &late, late_max), "speculative={speculative}");
        outs.push(out);
        outputs_by_mode.push(outs);
        prefill_counts.push(prefills.load(Ordering::SeqCst));
        sched.shutdown().unwrap();
    }
    assert_eq!(outputs_by_mode[0], outputs_by_mode[1], "modes must agree byte-for-byte");
    assert_eq!(
        prefill_counts[0], prefill_counts[1],
        "speculation must not add prefill steps ({} vs {})",
        prefill_counts[0], prefill_counts[1]
    );
}

#[test]
fn one_weight_copy_at_any_shard_count() {
    // Arc-backed storage: however many shards slice the container (and
    // despite the retained pristine copy), every block exists exactly
    // once in memory, and the deduplicated resident compressed bytes
    // equal the container's own payload.
    for shards in [1usize, 2, 3] {
        let se = sharded(shards);
        assert_eq!(se.weight_copies(), 1, "shards={shards}");
        assert_eq!(
            se.resident_compressed_bytes(),
            cm().compressed_stream_bytes(),
            "shards={shards}"
        );
    }
    // Arc-level pin of the scale dedup: engine consts must VIEW the
    // container's per-layer scale vectors (the strong count rises),
    // never deep-copy them (which would leave it untouched).  A private
    // container, so concurrently running tests cannot race the counts.
    let m = synthetic_model(
        Config {
            name: "dedup".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_ctx: 32,
        },
        52,
    );
    let (model, _) =
        compress_model(&m, &CompressOpts { lam: 0.3, max_iters: 4, ..Default::default() })
            .unwrap();
    let before: Vec<Vec<usize>> = model
        .blocks
        .iter()
        .map(|b| b.layers.iter().map(|l| Arc::strong_count(&l.scales)).collect())
        .collect();
    let plan = ShardPlan::balance(&model, 2);
    let rts: Vec<Runtime> = (0..plan.n_shards())
        .map(|_| {
            Runtime::native(Manifest::synthetic(
                model.config.clone(),
                vec![(1, SEQ), (2, SEQ), (4, SEQ)],
                vec![(1, CTX), (2, CTX), (4, CTX)],
            ))
        })
        .collect();
    let se = ShardedEngine::new(rts, &model, plan, &EngineOpts::default()).unwrap();
    for (b, counts) in model.blocks.iter().zip(&before) {
        for (l, &was) in b.layers.iter().zip(counts) {
            assert!(
                Arc::strong_count(&l.scales) > was,
                "layer {} scales were copied instead of aliased",
                l.name
            );
        }
    }
    drop(se);
}

#[test]
fn rejoin_restores_topology_and_stays_byte_identical() {
    // the contract→expand cycle at the engine level: a scripted fault
    // kills shard 1 of 3 mid-step, the range reroutes onto a survivor,
    // and one full step later the armed replacement rejoins —
    // re-splitting the merged range — all mid-generation, with outputs
    // byte-identical to the unfaulted single-engine reference and
    // exactly one logical weight copy at every stage.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..2).map(|i| req(900 + i, 5 + i as usize)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    let (want, _) = engine.generate(batch, 8).unwrap();

    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 0 }]);
    let se = sharded_with_faults(3, &faults);
    se.arm_rejoin(native_rt(cm()), 1); // 1 full step after the reroute
    assert!(!se.try_rejoin(), "no reroute deficit yet: rejoin must refuse");
    let mut st = se.prefill_state(batch).unwrap();
    let mut rejoined = false;
    for _ in 0..7 {
        loop {
            match se.decode_step(&mut st) {
                Ok(true) => break,
                Ok(false) => panic!("context wall before the trace finished"),
                Err(e) => {
                    assert!(se.try_recover(), "reroute must succeed: {e:#}");
                    assert_eq!(se.weight_copies(), 1, "reroute must not copy weights");
                }
            }
        }
        if se.try_rejoin() {
            rejoined = true;
            assert_eq!(se.weight_copies(), 1, "rejoin must not copy weights");
        }
    }
    assert!(rejoined, "the armed replacement never rejoined");
    assert_eq!(se.rejoins(), 1);
    assert_eq!(se.reroutes(), 1);
    assert_eq!(se.n_shards(), 3, "topology must be restored to its target");
    // the re-split plan is still a contiguous exact cover
    let plan = se.plan();
    let mut expect = 0usize;
    for r in &plan.ranges {
        assert_eq!(r.start, expect);
        assert!(r.end > r.start);
        expect = r.end;
    }
    assert_eq!(expect, cm().blocks.len());
    assert_eq!(se.resident_compressed_bytes(), cm().compressed_stream_bytes());
    for (lane, w) in want.iter().enumerate() {
        assert_eq!(&st.outputs[lane], w, "lane {lane} diverged across contract/expand");
    }
}

#[test]
fn idle_rejoin_waives_the_pacing_delay() {
    // a spare whose step-counted delay can never elapse (the trace
    // drains first) must not starve: the idle variant — which the
    // scheduler uses when nothing is in flight or queued — waives the
    // pacing delay and restores the topology immediately.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..2).map(|i| req(1100 + i, 5 + i as usize)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    let (want, _) = engine.generate(batch, 8).unwrap();

    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 0 }]);
    let se = sharded_with_faults(2, &faults);
    se.arm_rejoin(native_rt(cm()), 1_000_000); // unreachable by step count
    let mut st = se.prefill_state(batch).unwrap();
    for _ in 0..7 {
        loop {
            match se.decode_step(&mut st) {
                Ok(true) => break,
                Ok(false) => panic!("context wall before the trace finished"),
                Err(e) => assert!(se.try_recover(), "reroute must succeed: {e:#}"),
            }
        }
        assert!(!se.try_rejoin(), "the step-paced rejoin must wait out its delay");
    }
    assert_eq!(se.n_shards(), 1, "still contracted while paced");
    assert!(se.try_rejoin_idle(), "an idle rejoin must not starve");
    assert_eq!(se.n_shards(), 2);
    assert_eq!(se.rejoins(), 1);
    for (lane, w) in want.iter().enumerate() {
        assert_eq!(&st.outputs[lane], w, "lane {lane} diverged");
    }
}

#[test]
fn splice_decodes_only_the_absorbed_range_at_container_edges() {
    // the incremental-residency-rebuild contract, pinned by decode
    // counts: under resident and offload modes a reroute decodes ONLY
    // the absorbed range (the survivor's own blocks keep their state),
    // for an absorbed range at the container's front (victim shard 0)
    // and at its back (victim shard 1).
    let engine = single_engine();
    let reqs: Vec<Request> = (0..2).map(|i| req(950 + i, 4 + i as usize * 2)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    let (want, _) = engine.generate(batch, 8).unwrap();
    for residency in [Residency::Bf16Resident, Residency::DiskOffload] {
        for victim in [0usize, 1] {
            let plan = ShardPlan::balance(cm(), 2);
            let absorbed_len = plan.ranges[victim].len();
            let survivor = 1 - victim;
            let survivor_own = plan.ranges[survivor].len();
            let faults =
                FaultPlan::scripted(vec![FaultScript { shard: victim, step: 1, block: 0 }]);
            let dir = std::env::temp_dir()
                .join(format!("eq_splice_test_{residency:?}_{victim}"))
                .to_string_lossy()
                .into_owned();
            let opts = EngineOpts { residency, offload_dir: Some(dir), ..Default::default() };
            let se = sharded_with_faults_opts(2, &faults, opts);
            // construction decodes exactly each shard's own blocks
            assert_eq!(
                se.residency_decodes(),
                vec![plan.ranges[0].len(), plan.ranges[1].len()],
                "residency={residency:?} victim={victim}"
            );
            let mut st = se.prefill_state(batch).unwrap();
            let mut rerouted = 0;
            for _ in 0..7 {
                loop {
                    match se.decode_step(&mut st) {
                        Ok(true) => break,
                        Ok(false) => panic!("context wall before the trace finished"),
                        Err(e) => {
                            assert!(se.try_recover(), "reroute must succeed: {e:#}");
                            rerouted += 1;
                        }
                    }
                }
            }
            assert_eq!(rerouted, 1, "residency={residency:?} victim={victim}");
            // the splice decoded ONLY the absorbed range
            assert_eq!(
                se.residency_decodes(),
                vec![survivor_own + absorbed_len],
                "residency={residency:?} victim={victim}: splice must not re-decode \
                 the survivor's own blocks"
            );
            assert_eq!(se.spliced_blocks(), absorbed_len);
            assert_eq!(se.weight_copies(), 1);
            for (lane, w) in want.iter().enumerate() {
                assert_eq!(
                    &st.outputs[lane], w,
                    "residency={residency:?} victim={victim} lane {lane} diverged"
                );
            }
        }
    }
}

#[test]
fn mid_splice_fault_aborts_recovery_and_leaves_the_engine_usable() {
    // a fault injected INSIDE the recovery splice: try_recover must
    // fail cleanly (no panic), leave the topology untouched, and — the
    // injected faults both being one-shot — the interrupted step must
    // still replay byte-identically on the unrecovered engine.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..2).map(|i| req(970 + i, 6 + i as usize)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    let (want, _) = engine.generate(batch, 8).unwrap();

    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 0 }]);
    faults.fail_next_splice(0); // the survivor's splice probe
    let se = sharded_with_faults(2, &faults);
    let mut st = se.prefill_state(batch).unwrap();
    let mut recovery_refused = false;
    for _ in 0..7 {
        loop {
            match se.decode_step(&mut st) {
                Ok(true) => break,
                Ok(false) => panic!("context wall before the trace finished"),
                Err(_) => {
                    assert!(!se.try_recover(), "the splice fault must abort recovery");
                    recovery_refused = true;
                }
            }
        }
    }
    assert!(recovery_refused, "the scripted faults never fired");
    assert_eq!(faults.fired(), 2, "decode fault + splice fault");
    assert_eq!(se.n_shards(), 2, "failed recovery must leave the topology untouched");
    assert_eq!(se.reroutes(), 0);
    assert_eq!(se.spliced_blocks(), 0);
    for (lane, w) in want.iter().enumerate() {
        assert_eq!(&st.outputs[lane], w, "lane {lane} diverged across the aborted splice");
    }
}

#[test]
fn mid_splice_fault_under_scheduler_fails_requests_then_keeps_serving() {
    // the same aborted recovery through the scheduler: the in-flight
    // batch fails (per-request Failed, never a panic or wrong tokens),
    // and because the engine is left intact the queue keeps serving —
    // later submissions complete byte-identically.
    let engine = single_engine();
    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 0 }]);
    faults.fail_next_splice(0);
    let sched = Scheduler::new(
        sharded_with_faults(2, &faults),
        SchedulerOpts { paused: true, ..Default::default() },
    );
    let doomed: Vec<u64> =
        (0..4).map(|i| sched.submit(req(980 + i, 5).prompt, 8).expect_admitted()).collect();
    sched.resume();
    sched.drain(Duration::from_secs(120)).unwrap();
    for id in &doomed {
        let (status, _) = sched.poll(*id).unwrap();
        assert!(
            matches!(status, Status::Failed(_)),
            "aborted recovery must fail the in-flight request, got {status:?}"
        );
    }
    let m = sched.metrics();
    assert_eq!(m.failed, doomed.len(), "{m:?}");
    assert_eq!(m.reroutes, 0, "{m:?}");
    // both one-shot faults are spent: the engine serves on
    let fresh: Vec<(Request, u64)> = (0..2)
        .map(|i| {
            let r = req(990 + i, 6);
            let id = sched.submit(r.prompt.clone(), 5).expect_admitted();
            (r, id)
        })
        .collect();
    sched.drain(Duration::from_secs(120)).unwrap();
    for (r, id) in &fresh {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done, "the queue must keep serving after the failure");
        assert_eq!(out, reference(&engine, r, 5), "post-failure request diverged");
    }
    sched.shutdown().unwrap();
}

#[test]
fn scripted_contract_rejoin_trace_is_byte_identical_with_one_weight_copy() {
    // the acceptance drill, extended to the full contract→expand cycle:
    // kill a shard at a scripted decode step of a 32-request trace (at
    // 2 and at 4 shards), let the armed replacement rejoin two steps
    // later, and require (a) every final token stream byte-identical to
    // the unfaulted single-engine reference, (b) the topology restored
    // to its target shard count, and (c) the weight_copies gauge
    // pinned at exactly 1 at every observation point of the cycle.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..32).map(|i| req(1000 + i, 1 + (i as usize * 5) % 14)).collect();
    let max_new = |id: u64| 2 + (id as usize % 7);
    let want: Vec<Vec<u8>> = reqs.iter().map(|r| reference(&engine, r, max_new(r.id))).collect();
    for shards in [2usize, 4] {
        let faults =
            FaultPlan::scripted(vec![FaultScript { shard: shards - 1, step: 6, block: 0 }]);
        let se = sharded_with_faults(shards, &faults);
        se.arm_rejoin(native_rt(cm()), 2);
        let sched = Scheduler::new(se, SchedulerOpts { paused: true, ..Default::default() });
        let ids: Vec<u64> = reqs
            .iter()
            .map(|r| sched.submit(r.prompt.clone(), max_new(r.id)).expect_admitted())
            .collect();
        sched.resume();
        // weight_copies == 1 throughout: poll while the trace drains
        let t0 = std::time::Instant::now();
        loop {
            let m = sched.metrics();
            assert_eq!(m.weight_copies, 1, "shards={shards}: weight copy observed: {m:?}");
            if ids.iter().all(|id| sched.poll(*id).unwrap().0.is_terminal()) {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(300), "trace stalled");
            std::thread::sleep(Duration::from_micros(200));
        }
        for (i, id) in ids.iter().enumerate() {
            let (status, out) = sched.poll(*id).unwrap();
            assert_eq!(status, Status::Done, "shards={shards} request {i}");
            assert_eq!(out, want[i], "shards={shards} request {i} diverged across the cycle");
        }
        let m = sched.metrics();
        assert_eq!(m.completed, 32, "shards={shards}: {m:?}");
        assert_eq!(m.failed, 0, "shards={shards}: {m:?}");
        assert!(m.reroutes >= 1, "shards={shards}: the fault never rerouted: {m:?}");
        assert!(m.rejoins >= 1, "shards={shards}: the replacement never rejoined: {m:?}");
        assert_eq!(faults.fired(), 1, "shards={shards}");
        assert_eq!(
            m.shard_fresh_allocs.len(),
            shards,
            "shards={shards}: rejoin must restore the shard count"
        );
        assert_eq!(m.weight_copies, 1, "shards={shards}: {m:?}");
        assert_eq!(
            m.resident_compressed_bytes,
            cm().compressed_stream_bytes(),
            "shards={shards}: resident compressed bytes must stay deduplicated"
        );
        assert!(m.recovery_spliced_blocks >= 1, "shards={shards}: {m:?}");
        sched.shutdown().unwrap();
    }
}

#[test]
fn unknown_ids_and_double_cancel_are_benign() {
    let sched =
        Scheduler::new(sharded(2), SchedulerOpts { paused: true, ..Default::default() });
    assert!(sched.poll(999).is_none());
    sched.cancel(999); // no-op
    let id = sched.submit(req(400, 4).prompt, 3).expect_admitted();
    sched.cancel(id);
    sched.cancel(id); // idempotent
    assert_eq!(sched.poll(id).unwrap().0, Status::Cancelled);
    assert_eq!(sched.metrics().cancelled, 1);
    sched.shutdown().unwrap();
}

/// Snapshot metrics once the driver has swept the lane/queue gauges:
/// they publish at the end of the tick that terminalized the last
/// request — a moment after `drain` observes the statuses.  A leaked
/// lane never settles, so the caller's `== 0` expectations still bite.
fn settled_metrics(sched: &Scheduler) -> MetricsSnapshot {
    let t0 = std::time::Instant::now();
    loop {
        let m = sched.metrics();
        if m.inflight_lanes == 0 && m.queue_depth == 0 {
            return m;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "gauges never settled: {m:?}");
        std::thread::sleep(Duration::from_micros(200));
    }
}

#[test]
fn bounded_queue_sheds_the_burst_and_serves_admitted_work_byte_identically() {
    // the overload-burst acceptance drill, deterministic edition: a
    // paused scheduler (so queue depth grows monotonically) takes a
    // 12-request burst into a depth-4 queue — exactly 4 admit, 8 shed
    // with retry hints >= 1 — and the admitted work then completes
    // byte-identical to the single-engine reference, untouched by the
    // shedding.
    let engine = single_engine();
    let sched = Scheduler::new(
        sharded(2),
        SchedulerOpts { paused: true, max_queue_depth: 4, ..Default::default() },
    );
    let mut admitted: Vec<(Request, u64)> = Vec::new();
    let mut hints: Vec<usize> = Vec::new();
    for i in 0..12u64 {
        let r = req(1500 + i, 3 + i as usize % 5);
        match sched.submit(r.prompt.clone(), 6) {
            Admission::Admitted(id) => admitted.push((r, id)),
            Admission::Shed { retry_after_steps } => hints.push(retry_after_steps),
        }
    }
    assert_eq!(admitted.len(), 4, "exactly the queue bound admits");
    assert_eq!(hints.len(), 8, "everything past the bound sheds");
    assert!(hints.iter().all(|&h| h >= 1), "a shed must carry a usable hint: {hints:?}");
    sched.resume();
    sched.drain(Duration::from_secs(120)).unwrap();
    for (r, id) in &admitted {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done);
        assert_eq!(out, reference(&engine, r, 6), "admitted work diverged under shedding");
    }
    // the queue has drained: the same client retrying now gets in
    let late = req(1520, 4);
    let late_id = sched.submit(late.prompt.clone(), 6).expect_admitted();
    sched.drain(Duration::from_secs(120)).unwrap();
    let (status, out) = sched.poll(late_id).unwrap();
    assert_eq!(status, Status::Done);
    assert_eq!(out, reference(&engine, &late, 6));
    let m = settled_metrics(&sched);
    assert_eq!(m.shed, 8, "{m:?}");
    assert_eq!(m.submitted, 5, "shed requests must not count as submitted: {m:?}");
    assert_eq!(m.completed, 5, "{m:?}");
    sched.shutdown().unwrap();
}

#[test]
fn inflight_token_budget_sheds_independently_of_queue_depth() {
    // the committed-work bound: 8 + 8 tokens fit a 20-token budget, a
    // third 8 does not (shed with a hint), a smaller 4 still does —
    // and the budget frees as requests retire, so after the drain the
    // same 8-token ask admits again.
    let sched = Scheduler::new(
        sharded(2),
        SchedulerOpts { paused: true, max_inflight_tokens: 20, ..Default::default() },
    );
    assert!(!sched.submit(req(1530, 4).prompt, 8).is_shed());
    assert!(!sched.submit(req(1531, 5).prompt, 8).is_shed());
    let over = sched.submit(req(1532, 6).prompt, 8);
    assert!(over.is_shed(), "16 committed + 8 > 20 must shed, got {over:?}");
    assert!(over.retry_after().unwrap() >= 1, "a shed must carry a usable hint");
    assert!(!sched.submit(req(1533, 4).prompt, 4).is_shed(), "a smaller ask still fits");
    sched.resume();
    sched.drain(Duration::from_secs(120)).unwrap();
    assert!(!sched.submit(req(1534, 4).prompt, 8).is_shed(), "retired budgets must free");
    sched.drain(Duration::from_secs(120)).unwrap();
    let m = settled_metrics(&sched);
    assert_eq!(m.completed, 4, "{m:?}");
    assert_eq!(m.shed, 1, "{m:?}");
    sched.shutdown().unwrap();
}

#[test]
fn step_budget_deadlines_expire_requests_with_reference_prefix_outputs() {
    // deadline budgets are tick-counted decode steps, never wall time:
    // admitted together at step 0 with a 3-step budget, an 8-token
    // request cannot finish — every lane expires, each keeping the
    // tokens it earned, byte-for-byte a prefix of the unbudgeted
    // reference.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..4).map(|i| req(1600 + i, 4 + i as usize)).collect();
    let sched = Scheduler::new(
        sharded(2),
        SchedulerOpts { paused: true, step_budget: Some(3), ..Default::default() },
    );
    let ids: Vec<u64> =
        reqs.iter().map(|r| sched.submit(r.prompt.clone(), 8).expect_admitted()).collect();
    sched.resume();
    sched.drain(Duration::from_secs(120)).unwrap();
    for (r, id) in reqs.iter().zip(&ids) {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Expired, "a 3-step budget cannot yield 8 tokens");
        let want = reference(&engine, r, 8);
        assert!(want.starts_with(&out), "an expired output must be a reference prefix");
        assert!(!out.is_empty(), "the budget still buys the first tokens");
        assert!(out.len() < 8, "expiry must precede completion");
    }
    let m = settled_metrics(&sched);
    assert_eq!(m.expired, 4, "{m:?}");
    assert_eq!(m.completed, 0, "{m:?}");
    sched.shutdown().unwrap();
}

#[test]
fn degraded_topology_sheds_new_admissions_below_min_healthy_shards() {
    // graceful degradation, tier 1: with no spare provisioned, a
    // reroute leaves 1 healthy shard below `min_healthy_shards = 2`.
    // Work admitted before the fault still completes byte-identically
    // (in-flight capacity is never sacrificed); every NEW admission is
    // shed with a deterministic retry hint.
    let engine = single_engine();
    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 0 }]);
    let sched = Scheduler::new(
        sharded_with_faults(2, &faults),
        SchedulerOpts { paused: true, min_healthy_shards: 2, ..Default::default() },
    );
    let firsts: Vec<(Request, u64)> = (0..3u64)
        .map(|i| {
            let r = req(1400 + i, 4 + i as usize);
            let id = sched.submit(r.prompt.clone(), 6).expect_admitted();
            (r, id)
        })
        .collect();
    sched.resume();
    sched.drain(Duration::from_secs(120)).unwrap();
    for (r, id) in &firsts {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done, "in-flight work must survive the reroute");
        assert_eq!(out, reference(&engine, r, 6), "in-flight work diverged across the reroute");
    }
    let m = settled_metrics(&sched);
    assert!(m.reroutes >= 1, "the scripted fault never rerouted: {m:?}");
    assert_eq!(m.healthy_shards, 1, "{m:?}");
    assert_eq!(m.degradation_tier, 1, "{m:?}");
    let shed = sched.submit(req(1410, 4).prompt, 6);
    assert!(shed.is_shed(), "tier 1 must shed new admissions, got {shed:?}");
    assert!(shed.retry_after().unwrap() >= 1, "a shed must carry a usable hint");
    assert_eq!(sched.metrics().shed, 1);
    sched.shutdown().unwrap();
}

#[test]
fn supervisor_evicts_backs_off_and_rejoins_from_the_spare_pool() {
    // the recovery supervisor's full lifecycle, driven deterministically
    // at the engine level: a scripted decode fault trips the
    // consecutive-failure threshold (`evict_after = 1`), the supervisor
    // evicts the shard and spends its first pool spare on an immediate
    // rejoin attempt; a splice fault armed AFTER the reroute sabotages
    // that attempt, so the supervisor backs off (tick-counted with
    // seeded jitter — no wall clock anywhere) and the retry lands from
    // the second spare.  The whole drill stays byte-identical to the
    // single-engine reference, and the rejoin's rebalance converges the
    // plan back to the canonical byte-balanced partition.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..2).map(|i| req(1200 + i, 5 + i as usize)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    let (want, _) = engine.generate(batch, 8).unwrap();

    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 0 }]);
    let sup = Supervisor::new(
        sharded_with_faults(2, &faults),
        vec![native_rt(cm()), native_rt(cm())],
        SupervisorOpts { evict_after: 1, ..Default::default() },
    );
    let mut st = sup.prefill_state(batch).unwrap();
    let mut evicted_seen = false;
    for _ in 0..7 {
        loop {
            match sup.decode_step(&mut st) {
                Ok(true) => break,
                Ok(false) => panic!("context wall before the trace finished"),
                Err(e) => {
                    assert!(sup.try_recover(), "evict-threshold reroute must succeed: {e:#}");
                    evicted_seen = true;
                    // armed only now, AFTER the reroute spent its own
                    // splice probe: the supervisor's first rejoin
                    // attempt must fail on the donor's truncate probe
                    // and schedule a backoff
                    faults.fail_next_splice(0);
                }
            }
        }
        sup.try_rejoin();
    }
    assert!(evicted_seen, "the scripted fault never fired");
    assert_eq!(sup.evicted(), 1);
    assert!(sup.backoff_retries() >= 1, "the sabotaged first attempt must back off");
    // the backoff clock is poll-counted: keep polling (the trace has
    // drained, so the idle variant applies) until the capped schedule
    // readmits the attempt and the second pool spare lands it
    let mut polls = 0;
    while sup.engine().n_shards() < 2 {
        assert!(polls < 64, "the backed-off rejoin never landed");
        sup.try_rejoin_idle();
        polls += 1;
    }
    assert_eq!(sup.engine().rejoins(), 1);
    assert_eq!(sup.engine().reroutes(), 1);
    assert_eq!(sup.backoff_retries(), 1, "exactly the sabotaged attempt backed off");
    assert_eq!(sup.shard_health(), (2, 0, 1), "restored health, one eviction on record");
    assert_eq!(sup.weight_copies(), 1, "the drill must never copy weights");
    assert_eq!(faults.fired(), 2, "the decode fault + the sabotaged splice probe");
    // the post-rejoin rebalance converged the plan back to canonical
    assert_eq!(sup.engine().plan().ranges, ShardPlan::balance(cm(), 2).ranges);
    for (lane, w) in want.iter().enumerate() {
        assert_eq!(&st.outputs[lane], w, "lane {lane} diverged across the drill");
    }
}

#[test]
fn scheduler_metrics_surface_supervisor_health_through_a_fault_storm() {
    // the supervisor drill end-to-end THROUGH the scheduler: a
    // supervised engine loses a shard mid-trace, evicts it, and
    // auto-rejoins from the spare pool between decode steps, while the
    // driver sweeps the health gauges into `serve::metrics` — and every
    // request still completes byte-identical to the reference.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..24).map(|i| req(1300 + i, 1 + (i as usize * 5) % 12)).collect();
    let max_new = |id: u64| 2 + (id as usize % 6);
    let want: Vec<Vec<u8>> = reqs.iter().map(|r| reference(&engine, r, max_new(r.id))).collect();

    let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 4, block: 0 }]);
    let sup = Supervisor::new(
        sharded_with_faults(2, &faults),
        vec![native_rt(cm())],
        SupervisorOpts { evict_after: 1, ..Default::default() },
    );
    let sched = Scheduler::new(sup, SchedulerOpts { paused: true, ..Default::default() });
    let ids: Vec<u64> = reqs
        .iter()
        .map(|r| sched.submit(r.prompt.clone(), max_new(r.id)).expect_admitted())
        .collect();
    sched.resume();
    sched.drain(Duration::from_secs(300)).unwrap();
    for (i, id) in ids.iter().enumerate() {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done, "request {i}");
        assert_eq!(out, want[i], "request {i} diverged across evict/rejoin");
    }
    let m = settled_metrics(&sched);
    assert_eq!(m.completed, reqs.len(), "{m:?}");
    assert_eq!(m.failed, 0, "{m:?}");
    assert!(m.reroutes >= 1, "the fault never rerouted: {m:?}");
    assert!(m.rejoins >= 1, "the spare never rejoined: {m:?}");
    assert_eq!(m.evicted_shards, 1, "{m:?}");
    assert_eq!(m.healthy_shards, 2, "post-rejoin health must be fully restored: {m:?}");
    assert_eq!(m.degraded_shards, 0, "{m:?}");
    assert_eq!(m.degradation_tier, 0, "{m:?}");
    assert_eq!(m.weight_copies, 1, "{m:?}");
    assert_eq!(faults.fired(), 1);
    sched.shutdown().unwrap();
}

#[test]
fn pipelined_micro_batched_decode_is_byte_identical_across_shard_counts() {
    // the tentpole pin: with `stage_pipeline` on (the default), decode
    // steps split the batch into per-shard micro-batches streamed
    // through the shard chain — and the re-interleaved token streams
    // must equal BOTH the monolithic sequential walk over the same
    // shards and the solo single-engine reference, at every shard
    // count. Two rounds per engine exercise handoff-buffer recycling.
    let reqs: Vec<Request> = (0..4).map(|i| req(1400 + i, 4 + i as usize * 3)).collect();
    let batch = &pack(&reqs, &[(4, SEQ)])[0];
    let engine = single_engine();
    let (want, want_m) = engine.generate(batch, 8).unwrap();
    for shards in [2usize, 3, 4] {
        let pipelined = sharded(shards);
        let sequential =
            sharded_opts(shards, EngineOpts { stage_pipeline: false, ..Default::default() });
        for round in 0..2 {
            let (got_p, m_p) = pipelined.generate(batch, 8).unwrap();
            let (got_s, m_s) = sequential.generate(batch, 8).unwrap();
            assert_eq!(got_p, want, "pipelined shards={shards} round={round}");
            assert_eq!(got_s, want, "sequential shards={shards} round={round}");
            assert_eq!(m_p.decode_tokens, want_m.decode_tokens, "shards={shards}");
            assert_eq!(m_s.decode_tokens, want_m.decode_tokens, "shards={shards}");
        }
        let allocs = pipelined.fresh_allocs();
        assert!(
            allocs.iter().all(|&a| a == 0),
            "shards={shards}: pipelined fresh allocs {allocs:?} (handoff buffers must recycle)"
        );
    }
}

#[test]
fn pipelined_mid_step_fault_recovers_and_replays_byte_identically() {
    // the acceptance drill at the engine level, on the pipelined path:
    // a scripted fault kills a mid-chain shard while micro-batches are
    // in flight (partial caches written for earlier micro-batches),
    // the range reroutes onto survivors, and replaying the interrupted
    // step verbatim — now micro-batched over the contracted chain —
    // completes byte-identical to the unfaulted single-engine run.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..4).map(|i| req(1450 + i, 4 + i as usize)).collect();
    let batch = &pack(&reqs, &[(4, SEQ)])[0];
    let (want, _) = engine.generate(batch, 8).unwrap();

    let faults = FaultPlan::scripted(vec![FaultScript { shard: 2, step: 6, block: 0 }]);
    let se = sharded_with_faults(4, &faults);
    let mut st = se.prefill_state(batch).unwrap();
    let mut rerouted = 0;
    for _ in 0..7 {
        loop {
            match se.decode_step(&mut st) {
                Ok(true) => break,
                Ok(false) => panic!("context wall before the trace finished"),
                Err(e) => {
                    assert!(se.try_recover(), "reroute must succeed with survivors: {e:#}");
                    rerouted += 1; // replay the interrupted step verbatim
                }
            }
        }
    }
    assert_eq!(rerouted, 1, "the scripted fault must interrupt exactly one step");
    assert_eq!(faults.fired(), 1);
    assert_eq!(se.n_shards(), 3, "the failed shard must be gone");
    for (lane, w) in want.iter().enumerate() {
        assert_eq!(&st.outputs[lane], w, "lane {lane} diverged across the pipelined reroute");
    }
}

#[test]
fn zero_and_one_token_generate_contract_is_pinned_across_engines() {
    // `generate(max_new = 0)` returns one EMPTY output per request and
    // `max_new = 1` exactly the prefill token, identically on the solo
    // and the sharded engine — the scheduler clamps to >= 1 at its
    // single entry point, so the engines must honor the literal value.
    let reqs: Vec<Request> = (0..2).map(|i| req(1500 + i, 5 + i as usize)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    let engine = single_engine();
    let se = sharded(2);
    for max_new in [0usize, 1] {
        let (solo, _) = engine.generate(batch, max_new).unwrap();
        let (shard, _) = se.generate(batch, max_new).unwrap();
        assert_eq!(solo.len(), reqs.len(), "max_new={max_new}");
        assert_eq!(solo, shard, "max_new={max_new}: engines disagree on the contract");
        for (lane, out) in solo.iter().enumerate() {
            assert_eq!(out.len(), max_new, "max_new={max_new} lane={lane}");
        }
    }
}

// ---------------------------------------------- compressed KV cache

/// Engine opts for a packed KV cache: mode plus a deliberately short
/// lossless window (2), so even the 8-token traces here push most
/// rows into the coded tail and across a sealed-chunk boundary.
fn kv_opts(mode: KvMode) -> EngineOpts {
    EngineOpts { kv: KvCfg { mode, window: 2 }, ..Default::default() }
}

fn single_engine_opts(opts: EngineOpts) -> ServingEngine {
    let model = cm().clone();
    let rt = native_rt(&model);
    ServingEngine::new(rt, model, opts).unwrap()
}

#[test]
fn lossless_tail_kv_is_byte_identical_to_raw_across_shard_counts() {
    // the tentpole contract: `LosslessTail` re-codes the cache layout
    // (f32 window + rANS-chunked f32 tail) without quantization, so
    // every token stream must equal the raw-cache reference — on the
    // solo engine and at 1/2/4 shards, pipelined and sequential, with
    // the materialization ring alloc-free in steady state.
    let reqs: Vec<Request> = (0..4).map(|i| req(1700 + i, 4 + i as usize * 3)).collect();
    let batch = &pack(&reqs, &[(4, SEQ)])[0];
    let (want, _) = single_engine().generate(batch, 8).unwrap();

    let solo = single_engine_opts(kv_opts(KvMode::LosslessTail));
    for round in 0..2 {
        let (got, _) = solo.generate(batch, 8).unwrap();
        assert_eq!(got, want, "solo lossless round={round}");
    }
    assert_eq!(solo.kv_fresh_allocs(), 0, "solo kv ring must stay steady-state");

    for shards in [1usize, 2, 4] {
        for stage_pipeline in [true, false] {
            let se = sharded_opts(
                shards,
                EngineOpts { stage_pipeline, ..kv_opts(KvMode::LosslessTail) },
            );
            for round in 0..2 {
                let (got, _) = se.generate(batch, 8).unwrap();
                assert_eq!(
                    got, want,
                    "shards={shards} pipeline={stage_pipeline} round={round}"
                );
            }
            let allocs = se.fresh_allocs();
            assert!(
                allocs.iter().all(|&a| a == 0),
                "shards={shards} pipeline={stage_pipeline}: fresh allocs {allocs:?}"
            );
        }
    }
}

#[test]
fn quant_tail_kv_is_deterministic_across_engines_and_compresses() {
    // `QuantTail` quantizes tail rows, so outputs may legitimately
    // drift from the raw reference — but every engine shape must agree
    // with the solo quantized run bit-for-bit (the quantization points
    // are a pure function of committed row values), and the byte
    // accounting must show the f8 tail actually shrinking the cache.
    let reqs: Vec<Request> = (0..4).map(|i| req(1750 + i, 4 + i as usize * 3)).collect();
    let batch = &pack(&reqs, &[(4, SEQ)])[0];
    for fmt in [TailFmt::F8, TailFmt::Bf16] {
        let solo = single_engine_opts(kv_opts(KvMode::QuantTail(fmt)));
        let (want, _) = solo.generate(batch, 8).unwrap();
        let (again, _) = solo.generate(batch, 8).unwrap();
        assert_eq!(want, again, "{fmt:?}: repeated quantized runs must agree");
        assert_eq!(solo.kv_fresh_allocs(), 0, "{fmt:?}: solo kv ring allocated");
        for shards in [2usize, 4] {
            for stage_pipeline in [true, false] {
                let se = sharded_opts(
                    shards,
                    EngineOpts { stage_pipeline, ..kv_opts(KvMode::QuantTail(fmt)) },
                );
                let (got, _) = se.generate(batch, 8).unwrap();
                assert_eq!(got, want, "{fmt:?} shards={shards} pipeline={stage_pipeline}");
                let allocs = se.fresh_allocs();
                assert!(
                    allocs.iter().all(|&a| a == 0),
                    "{fmt:?} shards={shards} pipeline={stage_pipeline}: {allocs:?}"
                );
            }
        }
        // byte accounting on a live state: the packed layout must be
        // smaller than its raw equivalent, and the coded tail nonempty
        let st = solo.prefill_state(batch).unwrap();
        let b = st.kv_bytes();
        assert!(b.resident < b.raw, "{fmt:?}: resident {} !< raw {}", b.resident, b.raw);
        assert!(b.compressed > 0, "{fmt:?}: no bytes ever reached the coded tail");
    }
}

#[test]
fn packed_kv_survives_mid_step_kill_reroute_and_rejoin() {
    // the fault drill under packed caches, both modes: a scripted
    // fault kills shard 1 of 3 mid-step (partial tail appends already
    // committed for earlier blocks), the range reroutes, the armed
    // replacement rejoins one step later, and the generation finishes
    // byte-identical to the unfaulted solo run with the same kv mode —
    // partial appends must replay verbatim through recovery.
    for mode in [KvMode::LosslessTail, KvMode::QuantTail(TailFmt::F8)] {
        let solo = single_engine_opts(kv_opts(mode));
        let reqs: Vec<Request> = (0..2).map(|i| req(1800 + i, 5 + i as usize)).collect();
        let batch = &pack(&reqs, &[(2, SEQ)])[0];
        let (want, _) = solo.generate(batch, 8).unwrap();
        if mode == KvMode::LosslessTail {
            // lossless must also match the raw-cache reference
            let (raw_want, _) = single_engine().generate(batch, 8).unwrap();
            assert_eq!(want, raw_want, "lossless solo diverged from raw");
        }

        let faults = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 1 }]);
        let se = sharded_with_faults_opts(3, &faults, kv_opts(mode));
        se.arm_rejoin(native_rt(cm()), 1);
        let mut st = se.prefill_state(batch).unwrap();
        let mut rejoined = false;
        for _ in 0..7 {
            loop {
                match se.decode_step(&mut st) {
                    Ok(true) => break,
                    Ok(false) => panic!("context wall before the trace finished"),
                    Err(e) => {
                        assert!(se.try_recover(), "{mode:?}: reroute must succeed: {e:#}");
                    }
                }
            }
            if se.try_rejoin() {
                rejoined = true;
            }
        }
        assert!(rejoined, "{mode:?}: the armed replacement never rejoined");
        assert_eq!(faults.fired(), 1, "{mode:?}: the scripted fault must fire");
        assert_eq!(se.n_shards(), 3, "{mode:?}: topology must be restored");
        for (lane, w) in want.iter().enumerate() {
            assert_eq!(&st.outputs[lane], w, "{mode:?} lane {lane} diverged across recovery");
        }
    }
}

#[test]
fn scheduler_trace_under_lossless_kv_matches_raw_references() {
    // end-to-end through the continuous-batching scheduler with packed
    // lossless caches: fused admission (adopt_lane), batch compaction,
    // and speculative adoption all run against `KvCache::Packed`
    // states, and every output equals the raw-cache solo reference.
    // The driver's per-tick sweep must surface the kv gauges.
    let engine = single_engine();
    let reqs: Vec<Request> = (0..24).map(|i| req(1850 + i, 1 + (i as usize * 5) % 14)).collect();
    let max_new = |id: u64| 2 + (id as usize % 7);
    let want: Vec<Vec<u8>> = reqs.iter().map(|r| reference(&engine, r, max_new(r.id))).collect();

    let se = sharded_opts(2, kv_opts(KvMode::LosslessTail));
    let sched = Scheduler::new(se, SchedulerOpts { paused: true, ..Default::default() });
    let ids: Vec<u64> = reqs
        .iter()
        .map(|r| sched.submit(r.prompt.clone(), max_new(r.id)).expect_admitted())
        .collect();
    sched.resume();
    sched.drain(Duration::from_secs(300)).unwrap();
    for (i, id) in ids.iter().enumerate() {
        let (status, out) = sched.poll(*id).unwrap();
        assert_eq!(status, Status::Done, "request {i}");
        assert_eq!(out, want[i], "request {i} diverged under packed kv");
    }
    let m = sched.metrics();
    assert_eq!(m.completed, 24);
    assert_eq!(m.failed, 0);
    assert!(
        m.shard_fresh_allocs.iter().all(|&a| a == 0),
        "kv ring + arena must stay steady-state: {:?}",
        m.shard_fresh_allocs
    );
    assert!(
        m.kv_peak_resident_bytes > 0,
        "the tick sweep never observed a live packed cache: {m:?}"
    );
    sched.shutdown().unwrap();
}

#[test]
fn ttft_is_the_single_prefill_sample_on_both_engines() {
    // the double-sample regression: ttft_ms must equal prefill_ms
    // after one prefill (one stopwatch read feeds both gauges), on the
    // solo engine and on the sharded engine alike.
    let reqs: Vec<Request> = (0..2).map(|i| req(1600 + i, 6 + i as usize)).collect();
    let batch = &pack(&reqs, &[(2, SEQ)])[0];
    for (name, m) in [
        ("solo", single_engine().prefill_state(batch).unwrap().metrics),
        ("sharded", sharded(2).prefill_state(batch).unwrap().metrics),
    ] {
        assert!(m.prefill_ms > 0.0, "{name}: prefill must take measurable time");
        assert_eq!(
            m.ttft_ms, m.prefill_ms,
            "{name}: ttft must be the one prefill stopwatch sample"
        );
    }
}
