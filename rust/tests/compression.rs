//! Integration tests over the compression stack on the *trained*
//! checkpoints: rate-distortion behaviour, baselines ordering, and the
//! paper's headline qualitative claims at small scale.

use entquant::baselines::{self, Method};
use entquant::eval::perplexity;
use entquant::model::load_eqw;
use entquant::quant::Format;
use entquant::store::pipeline::{compress_model, CompressOpts};

fn ready() -> bool {
    let dir = entquant::artifacts_dir();
    std::path::Path::new(&format!("{dir}/model_S.eqw")).exists()
        && std::path::Path::new(&format!("{dir}/corpus/valid.bin")).exists()
}

#[test]
fn trained_model_ppl_is_low_and_degrades_gracefully() {
    if !ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let model = load_eqw(&format!("{dir}/model_S.eqw")).unwrap();
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let base = perplexity(&model, &valid, 128, 3);
    assert!(base < 3.0, "trained S model should have low PPL on its corpus: {base}");

    // ~4 effective bits: near-lossless (paper Table 2 top group)
    let (cm, rep) = compress_model(
        &model,
        &CompressOpts { target_bits: Some(4.0), ..Default::default() },
    )
    .unwrap();
    let p4 = perplexity(&cm.to_model().unwrap(), &valid, 128, 3);
    assert!(p4 < base * 1.25, "4-bit EntQuant should be near-lossless: {p4} vs {base}");
    assert!(rep.mean_entropy_bits < 4.8);

    // ~2 effective bits: degraded but functional (the paper's headline)
    let (cm2, rep2) = compress_model(
        &model,
        &CompressOpts { target_bits: Some(2.1), ..Default::default() },
    )
    .unwrap();
    let p2 = perplexity(&cm2.to_model().unwrap(), &valid, 128, 3);
    assert!(rep2.mean_entropy_bits < 3.0, "{}", rep2.mean_entropy_bits);
    assert!(p2.is_finite() && p2 < 60.0, "2-bit EntQuant must not collapse: {p2}");
    assert!(p2 > p4, "more compression, more perplexity");
}

#[test]
fn entquant_2bit_beats_hqq_2bit() {
    // the paper's central Table 2 claim
    if !ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let model = load_eqw(&format!("{dir}/model_S.eqw")).unwrap();
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();

    let (cm, _) = compress_model(
        &model,
        &CompressOpts { target_bits: Some(2.1), ..Default::default() },
    )
    .unwrap();
    let p_eq = perplexity(&cm.to_model().unwrap(), &valid, 128, 3);

    let hqq = baselines::apply(&model, &Method::Hqq { bits: 2, group: 64 }, None).unwrap();
    let p_hqq = perplexity(&hqq.model, &valid, 128, 3);

    assert!(
        p_eq < p_hqq,
        "EntQuant@2.1 ({p_eq:.2}) must beat HQQ-2b-g64 ({p_hqq:.2})"
    );
}

#[test]
fn four_bit_methods_all_close_to_base() {
    // paper: "at 4 bits, all methods perform similarly well"
    if !ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let model = load_eqw(&format!("{dir}/model_S.eqw")).unwrap();
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let base = perplexity(&model, &valid, 128, 3);
    for method in [
        Method::Nf4 { group: 64 },
        Method::Hqq { bits: 4, group: 64 },
        Method::Float8Absmax { fmt: Format::F8E4M3 },
    ] {
        let r = baselines::apply(&model, &method, None).unwrap();
        let p = perplexity(&r.model, &valid, 128, 3);
        assert!(p < base * 1.2, "{method:?}: {p} vs base {base}");
    }
}

#[test]
fn compressed_file_roundtrip_on_trained_model() {
    if !ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let model = load_eqw(&format!("{dir}/model_S.eqw")).unwrap();
    let (cm, rep) = compress_model(
        &model,
        &CompressOpts { target_bits: Some(3.0), ..Default::default() },
    )
    .unwrap();
    let path = std::env::temp_dir().join("eq_it_roundtrip.eqz");
    cm.save(path.to_str().unwrap()).unwrap();
    let cm2 = entquant::store::container::CompressedModel::load(path.to_str().unwrap()).unwrap();
    assert_eq!(cm.serialize(), cm2.serialize());
    // the .eqz on disk really is ~bits/8 per linear param + f32 sides
    let meta = std::fs::metadata(&path).unwrap();
    let linear_bytes = rep.effective_bits_per_param / 8.0 * rep.params_compressed as f64;
    let f32_side = (model.embed.data.len()
        + model.head.data.len()
        + model.config.d_model * (2 * model.config.n_layers + 1))
        * 4;
    assert!(
        (meta.len() as f64) < linear_bytes + f32_side as f64 * 1.1 + 64_000.0,
        "file larger than accounted: {} vs {}",
        meta.len(),
        linear_bytes + f32_side as f64
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn superweight_exclusion_improves_int8_at_low_bits() {
    // paper Figure 6: Int8 + SW handling recovers performance
    if !ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let mut model = load_eqw(&format!("{dir}/model_S.eqw")).unwrap();
    entquant::quant::superweight::plant_super_weight(&mut model, 0, 80.0);
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let probe = entquant::quant::superweight::detect(&model, f32::INFINITY);
    let th = probe.activation_maxima.iter().cloned().fold(0.0f32, f32::max) / 2.0;

    let run = |sw: Option<f32>| {
        let (cm, rep) = compress_model(
            &model,
            &CompressOpts {
                target_bits: Some(3.0),
                fmt: Format::Int8,
                superweight_threshold: sw,
                ..Default::default()
            },
        )
        .unwrap();
        (perplexity(&cm.to_model().unwrap(), &valid, 128, 3), rep.excluded_blocks.len())
    };
    let (p_off, n_off) = run(None);
    let (p_on, n_on) = run(Some(th));
    assert_eq!(n_off, 0);
    assert!(n_on >= 1, "super weight must be detected");
    assert!(p_on <= p_off * 1.05, "SW exclusion should not hurt: {p_on} vs {p_off}");
}
