//! Integration tests over the full stack: compress -> store -> PJRT
//! serving engine -> generate, cross-checked against the offline f32
//! reference forward.  Skipped (with a note) when `make artifacts` has
//! not been run.

use entquant::coordinator::{pack, EngineOpts, Request, Residency, ServingEngine};
use entquant::model::{load_eqw, Forward};
use entquant::runtime::Runtime;
use entquant::store::container::CompressedModel;
use entquant::store::pipeline::{compress_model, CompressOpts};

fn artifacts_ready() -> bool {
    let dir = entquant::artifacts_dir();
    std::path::Path::new(&format!("{dir}/manifest.json")).exists()
        && std::path::Path::new(&format!("{dir}/model_M.eqw")).exists()
}

fn compressed_m(lam: f64) -> CompressedModel {
    let dir = entquant::artifacts_dir();
    let model = load_eqw(&format!("{dir}/model_M.eqw")).unwrap();
    let (cm, _) = compress_model(
        &model,
        &CompressOpts { lam, max_iters: 8, ..Default::default() },
    )
    .unwrap();
    cm
}

#[test]
fn engine_prefill_matches_offline_forward() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; run `make artifacts` (skipping)");
        return;
    }
    let dir = entquant::artifacts_dir();
    let cm = compressed_m(0.05);
    let offline = cm.to_model().unwrap();

    let rt = Runtime::new(&dir).unwrap();
    let engine = ServingEngine::new(rt, cm, EngineOpts::default()).unwrap();

    // full-length prompt (no padding) so offline forward is directly comparable
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let prompt = valid[..128].to_vec();
    let batch = &pack(
        &[Request { id: 0, prompt: prompt.clone(), max_new_tokens: 1 }],
        &[(1, 128)],
    )[0];
    assert_eq!(batch.starts[0], 0);

    let mut metrics = entquant::coordinator::Metrics {
        prefill_ms: 0.0,
        decode_ms: 0.0,
        decode_tokens: 0,
        ans_decode_ms: 0.0,
        exec_ms: 0.0,
        ttft_ms: 0.0,
    };
    let (logits, _) = engine.prefill(batch, &mut metrics).unwrap();
    let served = logits.as_f32();
    let vocab = 256usize;

    let fwd = Forward::new(&offline);
    let want = fwd.logits(&prompt);
    // compare the last position's logits
    let got_last = &served[(128 - 1) * vocab..128 * vocab];
    let want_last = want.row(want.rows - 1);
    let spread = want_last.iter().fold(0f32, |a, &v| a.max(v.abs()));
    for j in 0..vocab {
        assert!(
            (got_last[j] - want_last[j]).abs() < 2e-2 * spread.max(1.0),
            "logit {j}: served {} vs offline {}",
            got_last[j],
            want_last[j]
        );
    }
}

#[test]
fn pipelined_and_scalar_decode_agree() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let cm = compressed_m(0.05);
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let reqs = vec![Request { id: 0, prompt: valid[..40].to_vec(), max_new_tokens: 6 }];
    let batch = &pack(&reqs, &[(1, 128)])[0];

    let run = |pipeline: bool| {
        let rt = Runtime::new(&dir).unwrap();
        let engine = ServingEngine::new(
            rt,
            compressed_m(0.05),
            EngineOpts { pipeline, ..Default::default() },
        )
        .unwrap();
        engine.generate(batch, 6).unwrap().0
    };
    assert_eq!(run(true), run(false), "pipeline must not change results");
    let _ = cm;
}

#[test]
fn repeated_generate_is_identical_and_alloc_free() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let rt = Runtime::new(&dir).unwrap();
    let engine = ServingEngine::new(rt, compressed_m(0.05), EngineOpts::default()).unwrap();
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let batch = &pack(
        &[Request { id: 0, prompt: valid[..40].to_vec(), max_new_tokens: 6 }],
        &[(1, 128)],
    )[0];
    let out1 = engine.generate(batch, 6).unwrap().0;
    let out2 = engine.generate(batch, 6).unwrap().0;
    assert_eq!(out1, out2, "arena reuse must not change outputs");
    // steady-state decode must recycle the two arena buffers: no fresh
    // block-sized buffer allocation across either generate call (tiny
    // per-view metadata allocations are out of scope for this counter)
    assert_eq!(engine.decode_arena_fresh_allocs(), 0, "decode path allocated past the arena");
}

#[test]
fn residency_modes_agree_on_outputs() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    let dir = entquant::artifacts_dir();
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let reqs = vec![
        Request { id: 0, prompt: valid[..32].to_vec(), max_new_tokens: 5 },
        Request { id: 1, prompt: valid[50..90].to_vec(), max_new_tokens: 5 },
    ];
    let batch = &pack(&reqs, &[(4, 128)])[0];
    let mut outs = Vec::new();
    for residency in [Residency::EntQuant, Residency::F8Resident, Residency::DiskOffload] {
        let rt = Runtime::new(&dir).unwrap();
        let engine = ServingEngine::new(
            rt,
            compressed_m(0.05),
            EngineOpts { residency, ..Default::default() },
        )
        .unwrap();
        outs.push(engine.generate(batch, 5).unwrap().0);
    }
    assert_eq!(outs[0], outs[1], "EntQuant vs F8Resident");
    assert_eq!(outs[0], outs[2], "EntQuant vs DiskOffload");
}

#[test]
fn generation_is_text_like() {
    if !artifacts_ready() {
        eprintln!("artifacts missing; skipping");
        return;
    }
    // a lightly-compressed trained model should continue corpus text with
    // printable ascii, mostly lowercase words
    let dir = entquant::artifacts_dir();
    let rt = Runtime::new(&dir).unwrap();
    let engine = ServingEngine::new(rt, compressed_m(0.02), EngineOpts::default()).unwrap();
    let valid = std::fs::read(format!("{dir}/corpus/valid.bin")).unwrap();
    let batch = &pack(
        &[Request { id: 0, prompt: valid[..64].to_vec(), max_new_tokens: 16 }],
        &[(1, 128)],
    )[0];
    let (outs, metrics) = engine.generate(batch, 16).unwrap();
    assert_eq!(outs[0].len(), 16);
    let printable = outs[0].iter().filter(|&&b| (32..127).contains(&b)).count();
    assert!(printable >= 14, "output not text-like: {:?}", outs[0]);
    assert!(metrics.ttft_ms > 0.0 && metrics.decode_tokens > 0);
}
