//! Corrupt-container fuzz sweeps and parallel/scalar identity tests.
//!
//! Contract under test (ISSUE 1): a production server exposes `.eqz` /
//! `EQZB` parsing to untrusted bytes, so EVERY mutation — a bit flip in
//! any field or a truncation at any length — must surface as `Err`,
//! never a panic, abort, or silent mis-decode.  And the shared
//! `parallel::Pool` must leave all byte streams invariant: `threads=N`
//! output is identical to `threads=1` for encode, decode, and the whole
//! compression pipeline.
//!
//! Extended to the serve path (ISSUE 4): a container corrupted under
//! one shard of a sharded serving stack must surface as per-request
//! errors (or a reroute), never a panic or a wrong-token completion.

use entquant::ans::Bitstream;
use entquant::coordinator::EngineOpts;
use entquant::model::loader::synthetic_model;
use entquant::model::Config;
use entquant::runtime::{Manifest, Runtime};
use entquant::serve::{Scheduler, SchedulerOpts, ShardPlan, ShardedEngine, Status};
use entquant::store::container::CompressedModel;
use entquant::store::pipeline::{compress_model, CompressOpts};
use entquant::tensor::Rng;
use std::time::Duration;

fn symbols(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| ((rng.normal().abs() * 5.0) as usize).min(255) as u8).collect()
}

fn tiny_model(seed: u64) -> entquant::model::Model {
    synthetic_model(
        Config {
            name: "fuzz".into(),
            vocab: 48,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_ctx: 32,
        },
        seed,
    )
}

// ------------------------------------------------------------ EQZB

#[test]
fn eqzb_every_bit_flip_is_rejected() {
    let data = symbols(3000, 1);
    let ser = Bitstream::encode(&data, 512).serialize();
    // the stream has no trailing bytes, so every byte is load-bearing:
    // header, chunk lens, freq table, or payload — the crc32 (plus the
    // structural cross-checks) must reject every single-bit corruption
    for byte in 0..ser.len() {
        for bit in 0..8 {
            let mut m = ser.clone();
            m[byte] ^= 1 << bit;
            assert!(
                Bitstream::deserialize(&m).is_err(),
                "flip byte {byte} bit {bit} was accepted"
            );
        }
    }
}

#[test]
fn eqzb_every_truncation_is_rejected() {
    let data = symbols(2000, 2);
    let ser = Bitstream::encode(&data, 512).serialize();
    for k in 0..ser.len() {
        assert!(Bitstream::deserialize(&ser[..k]).is_err(), "truncation to {k} was accepted");
    }
}

#[test]
fn eqzb_corrupt_in_memory_fields_error_not_panic() {
    // decode must also survive a Bitstream struct whose fields lie
    // (e.g. assembled from a hostile custom parser rather than our
    // deserialize): exhaustively perturb each field
    let data = symbols(4000, 3);
    let good = Bitstream::encode(&data, 1000);
    let perturbations: Vec<Box<dyn Fn(&mut Bitstream)>> = vec![
        Box::new(|b| b.n_symbols += 1),
        Box::new(|b| b.n_symbols -= 1),
        Box::new(|b| b.n_symbols = usize::MAX),
        Box::new(|b| b.chunk_size = 0),
        Box::new(|b| b.chunk_size += 1),
        Box::new(|b| b.chunk_lens.push(12)),
        Box::new(|b| {
            b.chunk_lens.pop();
        }),
        Box::new(|b| b.chunk_lens[0] = u32::MAX),
        Box::new(|b| b.chunk_lens[2] += 1),
        Box::new(|b| b.payload.truncate(b.payload.len() / 2)),
        Box::new(|b| b.payload.push(0)),
    ];
    for (i, p) in perturbations.iter().enumerate() {
        let mut bs = good.clone();
        p(&mut bs);
        assert!(bs.decode().is_err(), "perturbation {i} decoded successfully");
        let mut buf = vec![0u8; data.len()];
        assert!(bs.decode_into(&mut buf, 2).is_err(), "perturbation {i} decoded (parallel)");
        // the fused decode->f32 path shares every integrity check
        let mut fbuf = vec![0.0f32; data.len()];
        let lut = [1.0f32; 256];
        assert!(bs.decode_fused_into(&mut fbuf, &lut, 2).is_err(), "perturbation {i} (fused)");
    }
    // and the untouched stream still round-trips
    assert_eq!(good.decode().unwrap(), data);
}

// ------------------------------------------------------------ .eqz

#[test]
fn eqz_bit_flip_sweep_is_rejected() {
    let m = tiny_model(4);
    let (cm, _) = compress_model(&m, &CompressOpts { lam: 0.4, ..Default::default() }).unwrap();
    let ser = cm.serialize();
    // one flipped bit per byte (rotating bit position) keeps the sweep
    // fast while still touching every byte of the container
    for byte in 0..ser.len() {
        let mut mutated = ser.clone();
        mutated[byte] ^= 1 << (byte % 8);
        assert!(
            CompressedModel::deserialize(&mutated).is_err(),
            "flip in byte {byte} was accepted"
        );
    }
}

#[test]
fn eqz_truncation_sweep_is_rejected() {
    let m = tiny_model(5);
    let (cm, _) = compress_model(&m, &CompressOpts { lam: 0.4, ..Default::default() }).unwrap();
    let ser = cm.serialize();
    let mut cuts: Vec<usize> = (0..ser.len()).step_by(7).collect();
    cuts.extend([0, 1, 4, 8, 11, 12, ser.len() - 1]);
    for k in cuts {
        assert!(CompressedModel::deserialize(&ser[..k]).is_err(), "truncation to {k} accepted");
    }
    // the untouched container still loads and decodes
    let cm2 = CompressedModel::deserialize(&ser).unwrap();
    cm2.to_qmodel().unwrap();
}

// ------------------------------------------------------------ serve

/// A 4-layer compressed model + the serving pieces around it.
fn serve_model(seed: u64) -> CompressedModel {
    let m = synthetic_model(
        Config {
            name: "fuzz-serve".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 4,
            n_heads: 2,
            d_ff: 24,
            max_ctx: 32,
        },
        seed,
    );
    compress_model(&m, &CompressOpts { lam: 0.3, max_iters: 4, ..Default::default() }).unwrap().0
}

fn serve_rt(cm: &CompressedModel) -> Runtime {
    Runtime::native(Manifest::synthetic(
        cm.config.clone(),
        vec![(1, 12), (2, 12)],
        vec![(1, 20), (2, 20)],
    ))
}

#[test]
fn truncated_container_never_reaches_a_shard() {
    // a truncated .eqz fails the integrity gate at load time — the
    // serving stack never even constructs on corrupt bytes
    let cm = serve_model(8);
    let ser = cm.serialize();
    for k in [ser.len() / 3, ser.len() / 2, ser.len() - 2] {
        assert!(CompressedModel::deserialize(&ser[..k]).is_err(), "truncation to {k} accepted");
    }
}

#[test]
fn bit_flipped_block_under_one_shard_fails_requests_never_panics() {
    // in-memory corruption (past the load-time crc — e.g. a bad DIMM or
    // a hostile custom loader) in a block owned by shard 1 of 2: under
    // EntQuant residency construction succeeds, so the corruption is
    // only discovered on the decode hot path.  The first reroute merges
    // the corrupt range onto the survivor; the survivor hits the same
    // corrupt bitstream; with nobody left to reroute to, every request
    // must surface a per-request `Failed` — no panic, no wrong-token
    // `Done`.
    let mut cm = serve_model(9);
    let plan = ShardPlan::balance(&cm, 2);
    let victim_block = plan.ranges[1].start; // owned by shard 1
    cm.block_mut(victim_block).bitstream.chunk_lens[0] ^= 1;
    let rts: Vec<Runtime> = (0..2).map(|_| serve_rt(&cm)).collect();
    let engine = ShardedEngine::new(rts, &cm, plan, &EngineOpts::default()).unwrap();

    let sched = Scheduler::new(engine, SchedulerOpts { paused: true, ..Default::default() });
    let ids: Vec<u64> = (0..6)
        .map(|i| {
            let prompt: Vec<u8> = (0..4 + i as usize).map(|j| (j % 64) as u8).collect();
            sched.submit(prompt, 4).expect_admitted()
        })
        .collect();
    sched.resume();
    sched.drain(Duration::from_secs(120)).unwrap();
    for id in &ids {
        let (status, out) = sched.poll(*id).unwrap();
        match status {
            Status::Failed(msg) => {
                assert!(out.is_empty(), "a failed request must not ship tokens: {out:?}");
                assert!(!msg.is_empty());
            }
            other => panic!("corrupt shard produced a non-Failed terminal state {other:?}"),
        }
    }
    let m = sched.metrics();
    assert_eq!(m.failed, ids.len(), "{m:?}");
    assert_eq!(m.completed, 0, "nothing may complete against a corrupt block: {m:?}");
    assert!(m.reroutes >= 1, "the first failure must at least attempt the reroute: {m:?}");
    sched.shutdown().unwrap();
}

#[test]
fn bit_flipped_block_under_resident_mode_fails_at_construction() {
    // resident residencies decode at load time, so the same in-memory
    // corruption surfaces as a clean constructor error instead
    let mut cm = serve_model(10);
    let plan = ShardPlan::balance(&cm, 2);
    let victim_block = plan.ranges[1].start;
    let n = cm.blocks[victim_block].bitstream.payload.len();
    cm.block_mut(victim_block).bitstream.payload[n / 2] ^= 0x10;
    let rts: Vec<Runtime> = (0..2).map(|_| serve_rt(&cm)).collect();
    let opts = EngineOpts {
        residency: entquant::coordinator::Residency::F8Resident,
        ..Default::default()
    };
    assert!(ShardedEngine::new(rts, &cm, plan, &opts).is_err());
}

// ------------------------------------------ parallel == scalar

#[test]
fn bitstream_encode_decode_identical_across_thread_counts() {
    let data = symbols(200_000, 6);
    let scalar = Bitstream::encode(&data, 16 * 1024);
    let scalar_ser = scalar.serialize();
    let lut = core::array::from_fn::<f32, 256, _>(|i| i as f32 * 0.25 - 8.0);
    let want_f: Vec<f32> = data.iter().map(|&s| lut[s as usize]).collect();
    for threads in [2usize, 3, 4, 8] {
        let par = Bitstream::encode_parallel(&data, 16 * 1024, threads);
        assert_eq!(par.serialize(), scalar_ser, "encode threads={threads}");
        let mut out = vec![0u8; data.len()];
        par.decode_into(&mut out, threads).unwrap();
        assert_eq!(out, data, "decode threads={threads}");
        // fused decode->f32 must equal the scalar symbols mapped
        // through the LUT, for any thread count / pairing layout
        let mut fout = vec![0.0f32; data.len()];
        par.decode_fused_into(&mut fout, &lut, threads).unwrap();
        assert_eq!(fout, want_f, "fused decode threads={threads}");
    }
}

#[test]
fn compress_model_identical_across_thread_counts() {
    let m = tiny_model(7);
    let opts = |threads| CompressOpts { lam: 0.2, threads, ..Default::default() };
    let (c1, _) = compress_model(&m, &opts(1)).unwrap();
    let ser1 = c1.serialize();
    for threads in [2usize, 4] {
        let (cn, _) = compress_model(&m, &opts(threads)).unwrap();
        assert_eq!(cn.serialize(), ser1, "threads={threads}");
    }
    // and the container itself round-trips bit-exactly
    assert_eq!(CompressedModel::deserialize(&ser1).unwrap().serialize(), ser1);
}
