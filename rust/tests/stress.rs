//! Scheduler stress test: a seeded random workload — arrival times,
//! prompt lengths, `max_new_tokens`, and mid-flight cancels — driven
//! through the sharded continuous-batching scheduler, asserting the
//! lifecycle invariants the serve subsystem promises:
//!
//! * every submitted request terminates exactly once (Done or
//!   Cancelled; never Failed, never stuck);
//! * every completed output is byte-identical to a solo single-engine
//!   reference replay, and every cancelled output is a prefix of it
//!   (cancellation stops generation, it never corrupts it);
//! * no lane leaks: the scheduler's slot accounting
//!   (`inflight_lanes`) returns to 0 once the trace drains;
//! * the metrics ledger balances: completed + cancelled == submitted.
//!
//! Seeded and reproducible: the seed prints at the start of the run
//! and STRESS_SEED overrides it.

use entquant::coordinator::{pack, EngineOpts, Request, ServingEngine};
use entquant::model::loader::synthetic_model;
use entquant::model::Config;
use entquant::runtime::fault::{FaultPlan, FaultRuntime};
use entquant::runtime::{Manifest, Runtime};
use entquant::serve::{Scheduler, SchedulerOpts, ShardPlan, ShardedEngine, Status};
use entquant::store::container::CompressedModel;
use entquant::store::pipeline::{compress_model, CompressOpts};
use entquant::tensor::Rng;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

const SEQ: usize = 16;
const CTX: usize = 28;

fn cm() -> &'static CompressedModel {
    static CM: OnceLock<CompressedModel> = OnceLock::new();
    CM.get_or_init(|| {
        let m = synthetic_model(
            Config {
                name: "stress".into(),
                vocab: 64,
                d_model: 16,
                n_layers: 6,
                n_heads: 2,
                d_ff: 24,
                max_ctx: 32,
            },
            77,
        );
        compress_model(&m, &CompressOpts { lam: 0.3, max_iters: 6, ..Default::default() })
            .unwrap()
            .0
    })
}

fn native_rt(model: &CompressedModel) -> Runtime {
    Runtime::native(Manifest::synthetic(
        model.config.clone(),
        vec![(1, SEQ), (2, SEQ), (4, SEQ)],
        vec![(1, CTX), (2, CTX), (4, CTX)],
    ))
}

fn single_engine() -> ServingEngine {
    let model = cm().clone();
    let rt = native_rt(&model);
    ServingEngine::new(rt, model, EngineOpts::default()).unwrap()
}

fn sharded(n: usize) -> ShardedEngine {
    let model = cm().clone();
    let plan = ShardPlan::balance(&model, n);
    let rts: Vec<Runtime> = (0..plan.n_shards()).map(|_| native_rt(&model)).collect();
    ShardedEngine::new(rts, &model, plan, &EngineOpts::default()).unwrap()
}

/// Solo reference: the request alone through the monolithic engine.
fn reference(engine: &ServingEngine, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let r = Request { id: 0, prompt: prompt.to_vec(), max_new_tokens: max_new };
    let batch = &pack(std::slice::from_ref(&r), &[(1, SEQ)])[0];
    engine.generate(batch, max_new).unwrap().0.remove(0)
}

struct Job {
    prompt: Vec<u8>,
    max_new: usize,
    /// microseconds after the previous arrival
    arrival_gap_us: u64,
    /// cancel after roughly this many microseconds (None = run to
    /// completion)
    cancel_after_us: Option<u64>,
}

/// The seeded workload: mixed prompt lengths, deadlines, bursty
/// arrivals, and a ~25% cancel rate at random times.
fn workload(seed: u64, n: usize) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = 1 + rng.below(SEQ - 2);
            let prompt: Vec<u8> = (0..len).map(|_| rng.below(64) as u8).collect();
            let max_new = 1 + rng.below(8);
            let arrival_gap_us = rng.below(3000) as u64;
            let cancel_after_us =
                if rng.below(4) == 0 { Some(rng.below(20_000) as u64) } else { None };
            Job { prompt, max_new, arrival_gap_us, cancel_after_us }
        })
        .collect()
}

#[test]
fn seeded_random_workload_terminates_exactly_once_and_leaks_nothing() {
    let seed =
        std::env::var("STRESS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE_u64);
    eprintln!("scheduler stress seed: {seed} (override with STRESS_SEED)");
    let n = 40;
    let jobs = workload(seed, n);
    let engine = single_engine();
    let refs: Vec<Vec<u8>> =
        jobs.iter().map(|j| reference(&engine, &j.prompt, j.max_new)).collect();

    let sched = Scheduler::new(sharded(2), SchedulerOpts::default());
    // submit on the seeded arrival schedule; issue cancels at their
    // scheduled delays as we go
    let mut ids: Vec<u64> = Vec::with_capacity(n);
    let mut cancels: Vec<(u64, Instant)> = Vec::new(); // (id, due)
    for job in &jobs {
        std::thread::sleep(Duration::from_micros(job.arrival_gap_us));
        let id = sched.submit(job.prompt.clone(), job.max_new).expect_admitted();
        if let Some(after) = job.cancel_after_us {
            cancels.push((id, Instant::now() + Duration::from_micros(after)));
        }
        ids.push(id);
        let now = Instant::now();
        cancels.retain(|(cid, due)| {
            if *due <= now {
                sched.cancel(*cid);
                false
            } else {
                true
            }
        });
    }
    for (cid, due) in cancels {
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        sched.cancel(cid);
    }
    sched.drain(Duration::from_secs(300)).unwrap();

    // exactly-once termination + byte-fidelity against the reference
    let mut done = 0usize;
    let mut cancelled = 0usize;
    for (i, id) in ids.iter().enumerate() {
        let (status, out) = sched.poll(*id).unwrap();
        match status {
            Status::Done => {
                done += 1;
                assert_eq!(out, refs[i], "request {i} (seed {seed}) diverged from the reference");
            }
            Status::Cancelled => {
                cancelled += 1;
                assert!(
                    out.len() <= refs[i].len() && out[..] == refs[i][..out.len()],
                    "request {i} (seed {seed}): cancelled output is not a reference prefix"
                );
            }
            other => panic!("request {i} (seed {seed}) ended {other:?}"),
        }
    }
    assert_eq!(done + cancelled, n, "seed {seed}: some request terminated oddly");

    // the metrics ledger balances (each request counted exactly once)
    let m = sched.metrics();
    assert_eq!(m.submitted, n, "{m:?}");
    assert_eq!(m.failed, 0, "{m:?}");
    assert_eq!(m.completed, done, "seed {seed}: completed ledger drifted: {m:?}");
    assert_eq!(m.cancelled, cancelled, "seed {seed}: cancelled ledger drifted: {m:?}");
    assert!(m.speculative_admissions <= m.fused_admissions, "{m:?}");
    assert!(m.decode_steps > 0 && m.tokens > 0, "{m:?}");
    assert!(m.p50_ttft_ms >= 0.0 && m.mean_ttft_ms >= 0.0, "{m:?}");

    // no lane leaked: the slot accounting must return to empty and the
    // queue must fully flush (give the driver a beat to publish its
    // final gauges, then require 0)
    let t0 = Instant::now();
    loop {
        let m = sched.metrics();
        if m.inflight_lanes == 0 && m.queue_depth == 0 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "seed {seed}: {} lanes / {} queued still accounted after drain: {m:?}",
            m.inflight_lanes,
            m.queue_depth
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    sched.shutdown().unwrap();
}

#[test]
fn seeded_fault_plan_under_load_never_leaks_or_corrupts() {
    // the seeded fault-plan path end-to-end: random (shard, step,
    // block) coordinates drawn from a seed strike a 2-shard stack under
    // a queued trace.  The first strike reroutes (one survivor), any
    // later strike on the survivor is unrecoverable and must fail
    // cleanly — whatever the coordinates, every request terminates
    // exactly once, Done outputs are byte-identical to the reference,
    // Failed outputs are a reference prefix, and nothing panics.
    let seed =
        std::env::var("STRESS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xFA017_u64);
    eprintln!("seeded-fault stress seed: {seed} (override with STRESS_SEED)");
    let n = 24;
    let jobs = workload(seed ^ 0x9E37, n);
    let engine = single_engine();
    let refs: Vec<Vec<u8>> =
        jobs.iter().map(|j| reference(&engine, &j.prompt, j.max_new)).collect();

    let model = cm().clone();
    let plan = ShardPlan::balance(&model, 2);
    let faults = FaultPlan::seeded(seed, 2, 40, 3, 3);
    let rts: Vec<Runtime> = (0..plan.n_shards())
        .map(|i| {
            native_rt(&model)
                .with_fault(FaultRuntime::new(Arc::clone(&faults), i, plan.ranges[i].len()))
        })
        .collect();
    let se = ShardedEngine::new(rts, &model, plan, &EngineOpts::default()).unwrap();
    let sched = Scheduler::new(se, SchedulerOpts { paused: true, ..Default::default() });
    let ids: Vec<u64> =
        jobs.iter().map(|j| sched.submit(j.prompt.clone(), j.max_new).expect_admitted()).collect();
    sched.resume();
    sched.drain(Duration::from_secs(300)).unwrap();

    let mut counts = (0usize, 0usize); // (done, failed)
    for (i, id) in ids.iter().enumerate() {
        let (status, out) = sched.poll(*id).unwrap();
        match status {
            Status::Done => {
                counts.0 += 1;
                assert_eq!(out, refs[i], "request {i} (seed {seed}) diverged under faults");
            }
            Status::Failed(_) => {
                counts.1 += 1;
                assert!(
                    out.len() <= refs[i].len() && out[..] == refs[i][..out.len()],
                    "request {i} (seed {seed}): failed output is not a reference prefix"
                );
            }
            other => panic!("request {i} (seed {seed}) ended {other:?}"),
        }
    }
    assert_eq!(counts.0 + counts.1, n, "seed {seed}: requests must terminate exactly once");
    let m = sched.metrics();
    assert_eq!(m.completed, counts.0, "{m:?}");
    assert_eq!(m.failed, counts.1, "{m:?}");
    assert!(m.reroutes <= 1, "2 shards allow at most one reroute: {m:?}");
    if faults.fired() == 0 {
        eprintln!("note: seed {seed} scripted no reachable fault (still a valid clean run)");
    }
    sched.shutdown().unwrap();
}

#[test]
fn paused_burst_workload_is_deterministic_across_runs() {
    // same seeded trace, queued fully before resume: two runs must
    // agree byte-for-byte on every output AND on the lifecycle ledger —
    // the scheduler introduces no hidden nondeterminism of its own
    let seed = 0xDEC0DE_u64;
    let jobs = workload(seed, 24);
    let mut all_outputs: Vec<Vec<(Status, Vec<u8>)>> = Vec::new();
    for _run in 0..2 {
        let sched =
            Scheduler::new(sharded(2), SchedulerOpts { paused: true, ..Default::default() });
        let ids: Vec<u64> = jobs
            .iter()
            .map(|j| sched.submit(j.prompt.clone(), j.max_new).expect_admitted())
            .collect();
        sched.resume();
        sched.drain(Duration::from_secs(300)).unwrap();
        all_outputs.push(ids.iter().map(|id| sched.poll(*id).unwrap()).collect());
        sched.shutdown().unwrap();
    }
    assert_eq!(all_outputs[0], all_outputs[1], "seed {seed}: runs diverged");
}
