//! Property tests for `ShardPlan::balance` / `balance_sizes` over
//! randomized block-size distributions and shard counts (seeded — a
//! failing case prints everything needed to replay it; override the
//! base seed with SHARD_PLAN_SEED).
//!
//! Invariants under test, for every distribution:
//! * ranges are contiguous, disjoint, non-empty, and cover all blocks;
//! * the plan uses exactly `min(k, n)` shards;
//! * per-shard bytes sum to the total;
//! * the documented balance bound holds: no shard exceeds the
//!   proportional share by more than the largest single block
//!   (`bytes[i] * k <= total + k * max_size`), hence the max/min
//!   spread is within `total/k + max_size - min_size`.

use entquant::serve::ShardPlan;
use entquant::tensor::Rng;

fn base_seed() -> u64 {
    std::env::var("SHARD_PLAN_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED_2026)
}

/// Assert every plan invariant; `ctx` identifies the failing case.
fn check_plan(sizes: &[usize], k: usize, ctx: &str) {
    let plan = ShardPlan::balance_sizes(sizes, k);
    let n = sizes.len();
    let k_eff = k.max(1).min(n.max(1));
    assert_eq!(plan.n_shards(), k_eff, "{ctx}: wrong shard count");
    assert_eq!(plan.ranges.len(), plan.bytes.len(), "{ctx}");

    // contiguous, disjoint, exhaustive, non-empty (n > 0)
    let mut expect = 0usize;
    for (i, r) in plan.ranges.iter().enumerate() {
        assert_eq!(r.start, expect, "{ctx}: gap/overlap before shard {i}");
        if n > 0 {
            assert!(r.end > r.start, "{ctx}: empty shard {i}");
        }
        expect = r.end;
    }
    assert_eq!(expect, n, "{ctx}: blocks not fully covered");

    // every block maps to exactly one shard
    for b in 0..n {
        let s = plan.shard_of(b).unwrap_or_else(|| panic!("{ctx}: block {b} unowned"));
        assert!(plan.ranges[s].contains(&b), "{ctx}: shard_of({b}) inconsistent");
    }

    // byte accounting
    let total: usize = sizes.iter().sum();
    assert_eq!(plan.bytes.iter().sum::<usize>(), total, "{ctx}: byte totals drifted");
    for (i, r) in plan.ranges.iter().enumerate() {
        assert_eq!(
            plan.bytes[i],
            sizes[r.clone()].iter().sum::<usize>(),
            "{ctx}: shard {i} byte accounting"
        );
    }

    if n == 0 {
        return;
    }
    // the documented balance bound: bytes[i] <= total/k + max_size
    // (integer form to avoid rounding), and the max/min spread bound
    // that follows from it
    let max_size = *sizes.iter().max().unwrap();
    let min_size = *sizes.iter().min().unwrap();
    for (i, &b) in plan.bytes.iter().enumerate() {
        assert!(
            b * k_eff <= total + k_eff * max_size,
            "{ctx}: shard {i} holds {b} bytes > total/k + max ({total}/{k_eff} + {max_size})"
        );
    }
    let max_b = *plan.bytes.iter().max().unwrap();
    let min_b = *plan.bytes.iter().min().unwrap();
    assert!(
        (max_b - min_b) * k_eff <= total + k_eff * (max_size - min_size),
        "{ctx}: spread {max_b}-{min_b} outside the documented bound"
    );
}

#[test]
fn randomized_distributions_hold_every_invariant() {
    let seed = base_seed();
    eprintln!("shard-plan property seed: {seed} (override with SHARD_PLAN_SEED)");
    let mut rng = Rng::new(seed);
    for case in 0..600 {
        let n = 1 + rng.below(64);
        let k = 1 + rng.below(12);
        let dist = rng.below(4);
        let sizes: Vec<usize> = (0..n)
            .map(|_| match dist {
                0 => 1 + rng.below(1000),                        // uniform
                1 => 997,                                        // constant
                2 => 1 + (rng.normal().abs() * 300.0) as usize,  // half-normal
                _ => {
                    // mostly tiny with occasional huge outliers
                    if rng.below(8) == 0 {
                        50_000
                    } else {
                        1 + rng.below(100)
                    }
                }
            })
            .collect();
        let ctx = format!("seed={seed} case={case} n={n} k={k} dist={dist} sizes={sizes:?}");
        check_plan(&sizes, k, &ctx);
    }
}

#[test]
fn adversarial_edges_hold_the_invariants() {
    let seed = base_seed();
    // single block, k huge; all-equal; strictly increasing/decreasing;
    // one dominant block at each end; zero-size blocks mixed in
    let mut dominant_first = vec![1usize; 32];
    dominant_first[0] = 100_000;
    let mut dominant_last = vec![1usize; 32];
    dominant_last[31] = 100_000;
    let cases: Vec<Vec<usize>> = vec![
        vec![7],
        vec![5; 16],
        (1..=32).collect(),
        (1..=32).rev().collect(),
        dominant_first,
        dominant_last,
        vec![0, 0, 10, 0, 10, 0, 0],
        vec![0; 9],
    ];
    for (i, sizes) in cases.iter().enumerate() {
        for k in 1..=(sizes.len() + 2) {
            let ctx = format!("seed={seed} edge-case={i} k={k} sizes={sizes:?}");
            check_plan(sizes, k, &ctx);
        }
    }
}

#[test]
fn empty_size_list_degenerates_to_one_empty_shard() {
    let plan = ShardPlan::balance_sizes(&[], 4);
    assert_eq!(plan.n_shards(), 1);
    assert_eq!(plan.ranges, vec![0..0]);
    assert_eq!(plan.bytes, vec![0]);
}

/// The plan-level contract→expand cycle: random merges followed by
/// `split` of the merged shard keep every plan invariant, and the
/// 2-way split respects the balance bound within the donor's range.
#[test]
fn merge_then_split_round_trips_hold_every_invariant() {
    let seed = base_seed() ^ 0x51DE;
    eprintln!("merge/split property seed: {seed} (override with SHARD_PLAN_SEED)");
    let mut rng = Rng::new(seed);
    for case in 0..300 {
        let n = 2 + rng.below(48);
        let k = 2 + rng.below(6);
        let sizes: Vec<usize> = (0..n).map(|_| 1 + rng.below(1000)).collect();
        let mut plan = ShardPlan::balance_sizes(&sizes, k);
        if plan.n_shards() < 2 {
            continue;
        }
        // contract: a random shard fails onto a random adjacent target
        let failed = rng.below(plan.n_shards());
        let target = if failed == 0 {
            1
        } else if failed == plan.n_shards() - 1 || rng.below(2) == 0 {
            failed - 1
        } else {
            failed + 1
        };
        plan.merge(failed, target);
        let merged = if target > failed { target - 1 } else { target };
        let merged_range = plan.ranges[merged].clone();
        let ctx = format!(
            "seed={seed} case={case} n={n} k={k} failed={failed} target={target} sizes={sizes:?}"
        );
        // expand: split the merged shard back out
        let donor_sizes: Vec<usize> = sizes[merged_range.clone()].to_vec();
        let split = plan.split(merged, &donor_sizes);
        if merged_range.len() < 2 {
            assert!(split.is_none(), "{ctx}: split of a 1-block range must refuse");
            continue;
        }
        let right = split.unwrap_or_else(|| panic!("{ctx}: splittable range refused"));
        assert_eq!(plan.ranges[merged].end, right.start, "{ctx}: split not adjacent");
        assert_eq!(right.end, merged_range.end, "{ctx}: split lost blocks");
        // full invariant sweep on the post-cycle plan: contiguous
        // exact cover + byte accounting
        let mut expect = 0usize;
        for (i, r) in plan.ranges.iter().enumerate() {
            assert_eq!(r.start, expect, "{ctx}: gap/overlap before shard {i}");
            assert!(r.end > r.start, "{ctx}: empty shard {i}");
            expect = r.end;
        }
        assert_eq!(expect, n, "{ctx}: blocks not fully covered");
        let total: usize = sizes.iter().sum();
        assert_eq!(plan.bytes.iter().sum::<usize>(), total, "{ctx}: bytes drifted");
        for (i, r) in plan.ranges.iter().enumerate() {
            assert_eq!(
                plan.bytes[i],
                sizes[r.clone()].iter().sum::<usize>(),
                "{ctx}: shard {i} byte accounting"
            );
        }
        // the 2-way split is balanced within the donor: neither half
        // exceeds the half-share by more than the largest block
        let donor_total: usize = donor_sizes.iter().sum();
        let donor_max = *donor_sizes.iter().max().unwrap();
        for half in [merged, merged + 1] {
            assert!(
                plan.bytes[half] * 2 <= donor_total + 2 * donor_max,
                "{ctx}: split half {half} outside the balance bound"
            );
        }
    }
}

#[test]
fn plans_are_deterministic_for_a_given_input() {
    let mut rng = Rng::new(base_seed() ^ 0xABCD);
    let sizes: Vec<usize> = (0..24).map(|_| 1 + rng.below(500)).collect();
    for k in 1..=8 {
        assert_eq!(
            ShardPlan::balance_sizes(&sizes, k),
            ShardPlan::balance_sizes(&sizes, k),
            "k={k}"
        );
    }
}

/// The recovery supervisor's bookkeeping contract: however far a
/// cascade of reroutes (repeated random `merge`s) drifts the plan from
/// balance, one `rebalance` at the surviving shard count restores the
/// canonical partition — and with it the documented bound
/// `max(bytes) <= total/k + max(sizes)`.  This is the plan-level half
/// of `ShardedEngine::rebalance`, which the rejoin path runs after
/// every topology expansion.
#[test]
fn repeated_merges_then_rebalance_restores_the_balance_bound() {
    let seed = base_seed() ^ 0x4EBA;
    eprintln!("merge^k/rebalance property seed: {seed} (override with SHARD_PLAN_SEED)");
    let mut rng = Rng::new(seed);
    for case in 0..300 {
        let n = 2 + rng.below(48);
        let k = 2 + rng.below(8);
        let sizes: Vec<usize> = (0..n).map(|_| 1 + rng.below(1000)).collect();
        let mut plan = ShardPlan::balance_sizes(&sizes, k);
        // contract repeatedly: up to all-but-one shard fails, each onto
        // an adjacent survivor (left when one exists, right otherwise)
        let merges = rng.below(plan.n_shards());
        for _ in 0..merges {
            let failed = rng.below(plan.n_shards());
            let target = if failed == 0 { 1 } else { failed - 1 };
            plan.merge(failed, target);
        }
        let survivors = plan.n_shards();
        let ctx = format!("seed={seed} case={case} n={n} k={k} merges={merges} sizes={sizes:?}");
        plan.rebalance(&sizes);
        assert_eq!(plan.n_shards(), survivors, "{ctx}: rebalance must keep the shard count");
        // rebalance is canonical: identical to balancing from scratch
        assert_eq!(
            plan,
            ShardPlan::balance_sizes(&sizes, survivors),
            "{ctx}: rebalance is not the canonical partition"
        );
        // and therefore the full invariant sweep holds again, balance
        // bound included, however unbalanced the merged plan had become
        check_plan(&sizes, survivors, &ctx);
        let total: usize = sizes.iter().sum();
        let max_size = *sizes.iter().max().unwrap();
        for (i, &b) in plan.bytes.iter().enumerate() {
            assert!(
                b * survivors <= total + survivors * max_size,
                "{ctx}: shard {i} holds {b} bytes past the restored balance bound"
            );
        }
    }
}
