//! Empirical entropy, histograms and frequency-table normalization —
//! the information-theoretic substrate under both the EntQuant objective
//! (paper eq. 2) and the rANS coder's metadata.

/// Byte histogram.
pub fn histogram(symbols: &[u8]) -> [u64; 256] {
    // Four sub-histograms break the store-to-load dependency chain on the
    // counter increments (§Perf L3).
    let mut h = [[0u64; 256]; 4];
    let mut chunks = symbols.chunks_exact(4);
    for c in chunks.by_ref() {
        h[0][c[0] as usize] += 1;
        h[1][c[1] as usize] += 1;
        h[2][c[2] as usize] += 1;
        h[3][c[3] as usize] += 1;
    }
    for &b in chunks.remainder() {
        h[0][b as usize] += 1;
    }
    let mut out = [0u64; 256];
    for i in 0..256 {
        out[i] = h[0][i] + h[1][i] + h[2][i] + h[3][i];
    }
    out
}

/// Empirical Shannon entropy in bits/symbol (paper eq. 2).
pub fn entropy_bits(hist: &[u64; 256]) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    let mut h = 0.0;
    for &c in hist {
        if c > 0 {
            let p = c as f64 / t;
            h -= p * p.log2();
        }
    }
    h
}

pub fn entropy_of(symbols: &[u8]) -> f64 {
    entropy_bits(&histogram(symbols))
}

/// Number of distinct symbols present.
pub fn unique_symbols(hist: &[u64; 256]) -> usize {
    hist.iter().filter(|&&c| c > 0).count()
}

/// Cross entropy of data under a (normalized) frequency model — the
/// achievable bits/symbol of an entropy coder driven by `freq` (which
/// sums to 2^prob_bits).  Equals `entropy_bits` when the model is exact.
pub fn cross_entropy_bits(hist: &[u64; 256], freq: &[u32; 256], prob_bits: u32) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let m = (1u64 << prob_bits) as f64;
    let mut bits = 0.0;
    for i in 0..256 {
        if hist[i] > 0 {
            assert!(freq[i] > 0, "model assigns zero to present symbol {i}");
            bits += hist[i] as f64 * (m / freq[i] as f64).log2();
        }
    }
    bits / total as f64
}

/// Normalize a histogram to integer frequencies summing to exactly
/// 2^prob_bits with every present symbol >= 1 (the rANS invariant).
/// Largest-remainder method with correction applied to the heaviest
/// symbols (keeps the KL penalty of rounding minimal).
pub fn normalize_freqs(hist: &[u64; 256], prob_bits: u32) -> [u32; 256] {
    let target = 1u32 << prob_bits;
    let total: u64 = hist.iter().sum();
    assert!(total > 0, "cannot normalize empty histogram");
    let present = hist.iter().filter(|&&c| c > 0).count() as u32;
    assert!(present <= target, "alphabet larger than 2^prob_bits");

    let mut freq = [0u32; 256];
    let mut assigned: u32 = 0;
    // first pass: proportional share, floored, min 1 for present symbols
    let mut rema: Vec<(f64, usize)> = Vec::with_capacity(present as usize);
    for i in 0..256 {
        if hist[i] == 0 {
            continue;
        }
        let exact = hist[i] as f64 * target as f64 / total as f64;
        let f = (exact.floor() as u32).max(1);
        freq[i] = f;
        assigned += f;
        rema.push((exact - f as f64, i));
    }
    // distribute the remaining mass to the largest remainders
    if assigned < target {
        rema.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut left = target - assigned;
        let mut idx = 0;
        while left > 0 {
            let (_, i) = rema[idx % rema.len()];
            freq[i] += 1;
            left -= 1;
            idx += 1;
        }
    } else if assigned > target {
        // floors + min-1 overflowed: take back from the heaviest symbols
        let mut over = assigned - target;
        let mut order: Vec<usize> = (0..256).filter(|&i| freq[i] > 0).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(freq[i]));
        let mut idx = 0;
        while over > 0 {
            let i = order[idx % order.len()];
            if freq[i] > 1 {
                freq[i] -= 1;
                over -= 1;
            }
            idx += 1;
        }
    }
    debug_assert_eq!(freq.iter().map(|&f| f as u64).sum::<u64>(), target as u64);
    freq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0, 0, 1, 255, 255, 255, 7]);
        assert_eq!(h[0], 2);
        assert_eq!(h[1], 1);
        assert_eq!(h[255], 3);
        assert_eq!(h[7], 1);
        assert_eq!(h.iter().sum::<u64>(), 7);
    }

    #[test]
    fn entropy_extremes() {
        assert_eq!(entropy_of(&[5u8; 100]), 0.0);
        let uniform: Vec<u8> = (0..=255u8).collect();
        assert!((entropy_of(&uniform) - 8.0).abs() < 1e-12);
        assert_eq!(entropy_of(&[]), 0.0);
    }

    #[test]
    fn entropy_two_symbols() {
        let data: Vec<u8> = (0..100).map(|i| if i < 25 { 0 } else { 1 }).collect();
        let want = -(0.25f64.log2() * 0.25 + 0.75f64.log2() * 0.75);
        assert!((entropy_of(&data) - want).abs() < 1e-12);
    }

    #[test]
    fn normalize_sums_to_target_and_covers_present() {
        let mut rng = Rng::new(9);
        for prob_bits in [10u32, 12, 14] {
            let data: Vec<u8> = (0..5000)
                .map(|_| ((rng.normal().abs() * 20.0) as usize).min(255) as u8)
                .collect();
            let h = histogram(&data);
            let f = normalize_freqs(&h, prob_bits);
            assert_eq!(f.iter().map(|&x| x as u64).sum::<u64>(), 1u64 << prob_bits);
            for i in 0..256 {
                if h[i] > 0 {
                    assert!(f[i] >= 1);
                } else {
                    assert_eq!(f[i], 0);
                }
            }
        }
    }

    #[test]
    fn normalize_handles_many_rare_symbols() {
        // 200 symbols each appearing once + one dominant symbol, small table
        let mut data = vec![7u8; 100_000];
        for i in 0..200 {
            data.push(i as u8);
        }
        let h = histogram(&data);
        let f = normalize_freqs(&h, 10); // only 1024 slots for 201 symbols
        assert_eq!(f.iter().map(|&x| x as u64).sum::<u64>(), 1024);
        assert!(f[7] > 700);
    }

    #[test]
    fn cross_entropy_at_least_entropy() {
        let mut rng = Rng::new(3);
        let data: Vec<u8> = (0..4000)
            .map(|_| ((rng.normal().abs() * 8.0) as usize).min(255) as u8)
            .collect();
        let h = histogram(&data);
        let f = normalize_freqs(&h, 12);
        let he = entropy_bits(&h);
        let ce = cross_entropy_bits(&h, &f, 12);
        assert!(ce >= he - 1e-9, "ce={ce} h={he}");
        assert!(ce < he + 0.05, "normalization penalty too large: {ce} vs {he}");
    }

    #[test]
    fn unique_count() {
        let h = histogram(&[1, 1, 2, 3]);
        assert_eq!(unique_symbols(&h), 3);
    }
}
