//! Compressed per-lane KV cache: the paper's weight machinery
//! (`quant/` f8/bf16 + chunked rANS from `ans/`) applied to decode
//! state, which at serving concurrency — with `weight_copies == 1`
//! pinned — is the resident-bytes ceiling.
//!
//! Layout per lane, per block, per stream (K and V separately):
//!
//! ```text
//!   positions 0 .. len
//!   ├── sealed chunks ──┬── pending ──┬── lossless window ──┤
//!   │ CHUNK_ROWS rows   │ < CHUNK_ROWS│ last min(len, W)    │
//!   │ quantized + rANS  │ quantized   │ raw f32 rows        │
//! ```
//!
//! The split is a pure function of `len`: `window_rows = min(len, W)`,
//! tail rows fill sealed chunks of `CHUNK_ROWS` with the remainder
//! pending.  That determinism is what makes fault replay rewrite a
//! partially-committed step verbatim — re-committing row `pos` after a
//! replay reproduces the exact same chunk boundaries and bytes.
//!
//! At attention time the tail is decoded into a `KvRing` — the same
//! double-buffer `Arc` discipline as the weight `DecodeArena`, with its
//! own counted `fresh_allocs` gauge pinned to zero in steady state — and
//! handed to the executor as `F32View` tensors.  Only row `pos` of the
//! executor's output is re-committed, so decode never persists scratch.
//!
//! `LosslessTail` stores exact f32 bytes (quantizer = identity), which
//! is why it is byte-identical to the `Raw` cache on every path,
//! including `adopt_lane`/`compact` surgery and fault→recover→rejoin.

// commit/materialize mirror the executor calling convention's wide
// argument lists (lane ranges + tensor geometry), same as engine.rs
#![allow(clippy::too_many_arguments)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::ans::kv_chunk::{self, ChunkScratch};
use crate::quant::{bf16, f8e4m3};
use crate::runtime::HostTensor;

/// Rows per sealed tail chunk.  Small enough that a short context still
/// reaches the entropy-coded regime, large enough to amortize the
/// sparse-table header.
pub const CHUNK_ROWS: usize = 16;
/// Default lossless-window length (recent positions kept as raw f32).
pub const DEFAULT_WINDOW: usize = 4;

/// Storage format of tail rows (everything older than the window).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TailFmt {
    /// 4 B/value — exact; `LosslessTail` uses this.
    F32,
    /// 1 B/value f8 E4M3 (RNE, saturating) — the default lossy knob.
    F8,
    /// 2 B/value bfloat16 (RNE).
    Bf16,
}

impl TailFmt {
    pub fn bytes_per_val(self) -> usize {
        match self {
            TailFmt::F32 => 4,
            TailFmt::F8 => 1,
            TailFmt::Bf16 => 2,
        }
    }
}

/// The `EngineOpts` quality knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvMode {
    /// Today's raw owned-f32 cache tensors; no packing anywhere.
    Raw,
    /// Packed layout, f32 tail: byte-identical outputs to `Raw`, still
    /// entropy-coded (rANS over f32 bytes) when the data allows.
    LosslessTail,
    /// Packed layout with a quantized tail.
    QuantTail(TailFmt),
}

impl KvMode {
    /// Tail storage format, or `None` for the raw path.
    pub fn tail_fmt(self) -> Option<TailFmt> {
        match self {
            KvMode::Raw => None,
            KvMode::LosslessTail => Some(TailFmt::F32),
            KvMode::QuantTail(f) => Some(f),
        }
    }

    /// Parse a CLI spelling (`serve --kv-mode`).
    pub fn parse(s: &str) -> Result<KvMode, String> {
        match s {
            "raw" => Ok(KvMode::Raw),
            "lossless" => Ok(KvMode::LosslessTail),
            "f8" => Ok(KvMode::QuantTail(TailFmt::F8)),
            "bf16" => Ok(KvMode::QuantTail(TailFmt::Bf16)),
            _ => Err(format!("unknown kv mode '{s}' (want raw|lossless|f8|bf16)")),
        }
    }
}

/// KV-cache configuration carried by `EngineOpts`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvCfg {
    pub mode: KvMode,
    /// Lossless-window length W (raw f32 rows); clamped to >= 1.
    pub window: usize,
}

impl Default for KvCfg {
    fn default() -> Self {
        KvCfg { mode: KvMode::Raw, window: DEFAULT_WINDOW }
    }
}

/// Resident-byte accounting for the gauges swept per scheduler tick.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvBytes {
    /// What the same cache would occupy as raw f32 `[B,H,C,hd]` pairs.
    pub raw: usize,
    /// Bytes actually resident (window + pending + sealed chunks, or the
    /// full raw tensors in `Raw` mode).
    pub resident: usize,
    /// The entropy-coded subset of `resident` (pending + sealed chunks).
    pub compressed: usize,
}

impl KvBytes {
    pub fn add(&mut self, o: KvBytes) {
        self.raw += o.raw;
        self.resident += o.resident;
        self.compressed += o.compressed;
    }
}

/// One block's cache in `DecodeState`: either the raw owned-f32
/// `(k, v)` tensor pair (today's layout, `KvMode::Raw`) or the packed
/// window+tail layout.  A state is uniform — every block carries the
/// same variant, decided once at prefill from `EngineOpts::kv`.
#[derive(Clone)]
pub enum KvCache {
    Raw(HostTensor, HostTensor),
    Packed(Box<PackedKv>),
}

impl KvCache {
    /// Byte accounting for the per-tick gauges.  Alloc-free.
    // entlint: hot
    pub fn bytes(&self) -> KvBytes {
        match self {
            KvCache::Raw(k, v) => {
                let n = (k.as_f32().len() + v.as_f32().len()) * 4;
                KvBytes { raw: n, resident: n, compressed: 0 }
            }
            KvCache::Packed(p) => p.bytes(),
        }
    }

    pub fn packed(&self) -> Option<&PackedKv> {
        match self {
            KvCache::Raw(..) => None,
            KvCache::Packed(p) => Some(p),
        }
    }

    pub fn packed_mut(&mut self) -> Option<&mut PackedKv> {
        match self {
            KvCache::Raw(..) => None,
            KvCache::Packed(p) => Some(p),
        }
    }
}

/// One stream (K or V) of one lane.
#[derive(Clone)]
struct LaneStream {
    /// Sealed tail chunks, `CHUNK_ROWS` rows each, oldest first.
    chunks: Vec<Vec<u8>>,
    /// Quantized tail rows not yet sealed (< `CHUNK_ROWS` rows).
    pending: Vec<u8>,
    /// Raw f32 recent rows, row-contiguous, `min(len, W)` rows.
    window: Vec<f32>,
}

impl LaneStream {
    fn empty() -> Self {
        LaneStream { chunks: Vec::new(), pending: Vec::new(), window: Vec::new() }
    }
}

/// One lane's K and V streams plus the committed-row count.
#[derive(Clone)]
struct LaneKv {
    k: LaneStream,
    v: LaneStream,
    /// Committed positions are `0..len`.
    len: usize,
}

impl LaneKv {
    fn empty() -> Self {
        LaneKv { k: LaneStream::empty(), v: LaneStream::empty(), len: 0 }
    }
}

/// Quantize one row (layout `[h][hd]`, `row_vals` f32s) onto `out`.
// entlint: hot
fn quantize_row(fmt: TailFmt, row: &[f32], out: &mut Vec<u8>) {
    match fmt {
        TailFmt::F32 => {
            for &x in row {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        TailFmt::F8 => {
            for &x in row {
                out.push(f8e4m3::encode(x));
            }
        }
        TailFmt::Bf16 => {
            for &x in row {
                out.extend_from_slice(&bf16::encode(x).to_le_bytes());
            }
        }
    }
}

/// Dequantize one row from `bytes` into `out` (`row_vals` f32s).
// entlint: hot
fn dequant_row(fmt: TailFmt, bytes: &[u8], out: &mut [f32]) {
    match fmt {
        TailFmt::F32 => {
            for (o, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                *o = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
        }
        TailFmt::F8 => {
            for (o, &b) in out.iter_mut().zip(bytes.iter()) {
                *o = f8e4m3::decode(b);
            }
        }
        TailFmt::Bf16 => {
            for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                *o = bf16::decode(u16::from_le_bytes([b[0], b[1]]));
            }
        }
    }
}

/// Reusable scratch for packed-cache materialize/commit: chunk decode
/// state, a chunk-sized byte buffer, and per-row f32 staging.  One per
/// engine; capacities are sized up front so the steady-state decode
/// path never grows them.
pub struct KvScratch {
    chunk: ChunkScratch,
    bytes: Vec<u8>,
    row: Vec<f32>,
    row_k: Vec<f32>,
    row_v: Vec<f32>,
}

impl KvScratch {
    pub fn new() -> Self {
        KvScratch {
            chunk: ChunkScratch::new(),
            bytes: Vec::new(),
            row: Vec::new(),
            row_k: Vec::new(),
            row_v: Vec::new(),
        }
    }

    /// Pre-size for a row of `row_vals` values (chunk buffer sized for
    /// the widest format, 4 B/value).
    pub fn reserve(&mut self, row_vals: usize) {
        let chunk_cap = CHUNK_ROWS * row_vals * 4;
        if self.bytes.capacity() < chunk_cap {
            self.bytes.reserve(chunk_cap - self.bytes.len());
        }
        if self.row.len() < row_vals {
            self.row.resize(row_vals, 0.0);
        }
        if self.row_k.len() < row_vals {
            self.row_k.resize(row_vals, 0.0);
            self.row_v.resize(row_vals, 0.0);
        }
    }
}

impl Default for KvScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Packed K/V storage for every lane of one block.
#[derive(Clone)]
pub struct PackedKv {
    fmt: TailFmt,
    /// Lossless-window length W (>= 1).
    window: usize,
    h: usize,
    hd: usize,
    /// Context capacity — only byte accounting reads this (materialize
    /// takes the live `ctx` as a parameter); `compact` rescales it.
    ctx: usize,
    lanes: Vec<LaneKv>,
}

impl PackedKv {
    pub fn new(fmt: TailFmt, window: usize, h: usize, hd: usize, ctx: usize, lanes: usize) -> Self {
        PackedKv {
            fmt,
            window: window.max(1),
            h,
            hd,
            ctx,
            lanes: vec![LaneKv::empty(); lanes],
        }
    }

    pub fn fmt(&self) -> TailFmt {
        self.fmt
    }

    pub fn window(&self) -> usize {
        self.window
    }

    pub fn h(&self) -> usize {
        self.h
    }

    pub fn hd(&self) -> usize {
        self.hd
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Committed rows of lane `lane`.
    pub fn lane_len(&self, lane: usize) -> usize {
        self.lanes[lane].len
    }

    fn row_vals(&self) -> usize {
        self.h * self.hd
    }

    fn row_bytes(&self) -> usize {
        self.row_vals() * self.fmt.bytes_per_val()
    }

    /// Geometry + knob compatibility for lane surgery between states.
    pub fn compatible(&self, o: &PackedKv) -> bool {
        self.fmt == o.fmt && self.window == o.window && self.h == o.h && self.hd == o.hd
    }

    /// Commit one row at `pos` for lane `lane`.  `pos == len` appends
    /// (rolling the window / sealing a chunk as needed); `pos < len`
    /// must land inside the lossless window and overwrites in place —
    /// the fault-replay path, which rewrites the same bytes verbatim.
    // entlint: hot
    pub fn commit_row(
        &mut self,
        lane: usize,
        pos: usize,
        row_k: &[f32],
        row_v: &[f32],
    ) -> Result<(), String> {
        let row_vals = self.row_vals();
        let (fmt, window) = (self.fmt, self.window);
        let lk = self
            .lanes
            .get_mut(lane)
            .ok_or_else(|| "kv commit: lane out of range".to_string())?;
        if row_k.len() != row_vals || row_v.len() != row_vals {
            return Err("kv commit: row width mismatch".into());
        }
        if pos > lk.len {
            return Err("kv commit: position gap".into());
        }
        if pos < lk.len {
            // Replay overwrite: the interrupted step re-commits the row
            // it had already written for some lanes.  It is always the
            // newest row, so it sits inside the window by construction.
            let wrows = lk.len.min(window);
            let base = lk.len - wrows;
            if pos < base {
                return Err("kv commit: overwrite below the lossless window".into());
            }
            let at = (pos - base) * row_vals;
            lk.k.window[at..at + row_vals].copy_from_slice(row_k);
            lk.v.window[at..at + row_vals].copy_from_slice(row_v);
            return Ok(());
        }
        let spill = lk.len >= window;
        for (stream, row) in [(&mut lk.k, row_k), (&mut lk.v, row_v)] {
            if spill {
                // Window full: quantize its oldest row onto the tail.
                quantize_row(fmt, &stream.window[..row_vals], &mut stream.pending);
                if stream.pending.len() == CHUNK_ROWS * row_vals * fmt.bytes_per_val() {
                    // entlint: allow(hot-path-alloc-free) — sealing allocates one chunk
                    // container per CHUNK_ROWS commits per stream (amortized, not
                    // per-step); the per-step append path below is alloc-free
                    let mut sealed = Vec::new();
                    kv_chunk::seal_into(&stream.pending, &mut sealed);
                    stream.chunks.push(sealed);
                    stream.pending.clear();
                }
                stream.window.copy_within(row_vals.., 0);
                stream.window.truncate((window - 1) * row_vals);
            }
            stream.window.extend_from_slice(row);
        }
        lk.len += 1;
        Ok(())
    }

    /// Commit row `pos` for `nlanes` lanes (starting at `lane0`) from
    /// executor output tensors laid out `[nlanes, h, ctx, hd]`.
    // entlint: hot
    pub fn commit_from_outputs(
        &mut self,
        k: &[f32],
        v: &[f32],
        lane0: usize,
        nlanes: usize,
        ctx: usize,
        pos: usize,
        scratch: &mut KvScratch,
    ) -> Result<(), String> {
        let (h, hd) = (self.h, self.hd);
        let row_vals = self.row_vals();
        scratch.reserve(row_vals);
        if k.len() < nlanes * h * ctx * hd || v.len() < nlanes * h * ctx * hd {
            return Err("kv commit: output tensor too small".into());
        }
        for li in 0..nlanes {
            for head in 0..h {
                let src = ((li * h + head) * ctx + pos) * hd;
                scratch.row_k[head * hd..head * hd + hd].copy_from_slice(&k[src..src + hd]);
                scratch.row_v[head * hd..head * hd + hd].copy_from_slice(&v[src..src + hd]);
            }
            self.commit_row_from_scratch(lane0 + li, pos, scratch)?;
        }
        Ok(())
    }

    // entlint: hot
    fn commit_row_from_scratch(
        &mut self,
        lane: usize,
        pos: usize,
        scratch: &mut KvScratch,
    ) -> Result<(), String> {
        let row_vals = self.row_vals();
        let row_k = std::mem::take(&mut scratch.row_k);
        let row_v = std::mem::take(&mut scratch.row_v);
        let r = self.commit_row(lane, pos, &row_k[..row_vals], &row_v[..row_vals]);
        scratch.row_k = row_k;
        scratch.row_v = row_v;
        r
    }

    /// Decode lanes `lane0 .. lane0+nlanes` into `dk`/`dv`, each laid
    /// out `[nlanes, h, ctx, hd]` (destination lane index is rebased to
    /// 0).  Rows at positions `>= len` are left untouched: attention
    /// masks them to an exact-zero softmax weight (and the executor
    /// overwrites row `pos` before reading it), so they never reach an
    /// output — skipping the memset keeps the hot path cheap.
    // entlint: hot
    pub fn materialize_into(
        &self,
        dk: &mut [f32],
        dv: &mut [f32],
        lane0: usize,
        nlanes: usize,
        ctx: usize,
        scratch: &mut KvScratch,
    ) -> Result<(), String> {
        let (h, hd) = (self.h, self.hd);
        let row_vals = self.row_vals();
        let row_bytes = self.row_bytes();
        scratch.reserve(row_vals);
        if dk.len() < nlanes * h * ctx * hd || dv.len() < nlanes * h * ctx * hd {
            return Err("kv materialize: destination too small".into());
        }
        if lane0 + nlanes > self.lanes.len() {
            return Err("kv materialize: lane range out of bounds".into());
        }
        for li in 0..nlanes {
            let lk = &self.lanes[lane0 + li];
            if lk.len > ctx {
                return Err("kv materialize: lane longer than context".into());
            }
            let wrows = lk.len.min(self.window);
            let tail = lk.len - wrows;
            for (stream, dst) in [(&lk.k, &mut *dk), (&lk.v, &mut *dv)] {
                // sealed chunks
                for (ci, chunk) in stream.chunks.iter().enumerate() {
                    scratch.bytes.resize(CHUNK_ROWS * row_bytes, 0);
                    kv_chunk::decode_into(chunk, &mut scratch.chunk, &mut scratch.bytes)?;
                    for r in 0..CHUNK_ROWS {
                        dequant_row(
                            self.fmt,
                            &scratch.bytes[r * row_bytes..(r + 1) * row_bytes],
                            &mut scratch.row[..row_vals],
                        );
                        scatter_row(
                            &scratch.row[..row_vals],
                            dst,
                            li,
                            ci * CHUNK_ROWS + r,
                            h,
                            hd,
                            ctx,
                        );
                    }
                }
                // pending (quantized, unsealed) rows
                let chunked = stream.chunks.len() * CHUNK_ROWS;
                for (r, p) in (chunked..tail).enumerate() {
                    dequant_row(
                        self.fmt,
                        &stream.pending[r * row_bytes..(r + 1) * row_bytes],
                        &mut scratch.row[..row_vals],
                    );
                    scatter_row(&scratch.row[..row_vals], dst, li, p, h, hd, ctx);
                }
                // lossless window
                for w in 0..wrows {
                    scatter_row(
                        &stream.window[w * row_vals..(w + 1) * row_vals],
                        dst,
                        li,
                        tail + w,
                        h,
                        hd,
                        ctx,
                    );
                }
            }
        }
        Ok(())
    }

    /// Graft lane `src_lane` of `src` into `dst_lane` here (the
    /// `adopt_lane` path).  Packed lanes are self-contained, so this is
    /// a byte-exact clone of the lane's streams.
    pub fn adopt_lane_from(
        &mut self,
        dst_lane: usize,
        src: &PackedKv,
        src_lane: usize,
    ) -> Result<(), String> {
        if !self.compatible(src) {
            return Err("kv adopt: mode/geometry mismatch".into());
        }
        if dst_lane >= self.lanes.len() || src_lane >= src.lanes.len() {
            return Err("kv adopt: lane out of range".into());
        }
        self.lanes[dst_lane] = src.lanes[src_lane].clone();
        Ok(())
    }

    /// Fill lane `lane` with `rows` committed all-zero rows (the
    /// `compact` padding for unoccupied slots — matches the zero rows a
    /// fresh raw tensor carries at those positions).
    pub fn zero_fill_lane(&mut self, lane: usize, rows: usize) -> Result<(), String> {
        let zrow = vec![0.0f32; self.row_vals()];
        self.lanes[lane] = LaneKv::empty();
        for p in 0..rows {
            self.commit_row(lane, p, &zrow, &zrow)?;
        }
        Ok(())
    }

    /// Byte accounting for the per-tick gauges.  Alloc-free.
    // entlint: hot
    pub fn bytes(&self) -> KvBytes {
        let mut b = KvBytes {
            raw: self.lanes.len() * 2 * self.h * self.ctx * self.hd * 4,
            resident: 0,
            compressed: 0,
        };
        for lk in &self.lanes {
            for stream in [&lk.k, &lk.v] {
                let coded: usize = stream.chunks.iter().map(|c| c.len()).sum::<usize>()
                    + stream.pending.len();
                b.compressed += coded;
                b.resident += coded + stream.window.len() * 4;
            }
        }
        b
    }

    /// Rescale the context capacity (the `compact` path).
    pub fn set_ctx(&mut self, ctx: usize) {
        self.ctx = ctx;
    }
}

/// Scatter one row (layout `[h][hd]`) to position `p` of destination
/// lane `li` in a `[lanes, h, ctx, hd]` tensor.
// entlint: hot
#[inline]
fn scatter_row(row: &[f32], dst: &mut [f32], li: usize, p: usize, h: usize, hd: usize, ctx: usize) {
    for head in 0..h {
        let at = ((li * h + head) * ctx + p) * hd;
        dst[at..at + hd].copy_from_slice(&row[head * hd..head * hd + hd]);
    }
}

/// Double-buffer ring for materialized packed caches — the
/// `DecodeArena` discipline applied to attention state.  One buffer
/// holds both streams of one block's scratch (K at offset 0, V at
/// `half`); consecutive blocks alternate slots, so by the time a slot's
/// turn comes round again its previous tenant's views have been
/// dropped and the buffer recycles with no allocation.
pub struct KvRing {
    slots: [Mutex<Option<Arc<Vec<f32>>>>; 2],
    /// Elements per stream; a buffer holds `2 * half` f32s.
    half: usize,
    /// Fresh allocations forced by a still-referenced slot: 0 in steady
    /// state (the alloc-free tests pin this, same as the decode arena).
    fresh_allocs: AtomicUsize,
}

impl KvRing {
    pub fn new(half: usize) -> Self {
        KvRing {
            slots: [
                Mutex::new(Some(Arc::new(vec![0.0; 2 * half]))),
                Mutex::new(Some(Arc::new(vec![0.0; 2 * half]))),
            ],
            half,
            fresh_allocs: AtomicUsize::new(0),
        }
    }

    /// Elements per stream (the V-stream offset inside a buffer).
    pub fn half(&self) -> usize {
        self.half
    }

    /// Check block `b`'s buffer out for exclusive materialize use;
    /// falls back to a fresh (counted) allocation if the slot's
    /// previous tenant still has live views.
    // entlint: hot
    pub fn acquire(&self, b: usize) -> Arc<Vec<f32>> {
        if let Some(mut arc) = self.slots[b & 1].lock().unwrap().take() {
            if Arc::get_mut(&mut arc).is_some() {
                return arc;
            }
        }
        // Relaxed: independent monotonic gauge (allocation-miss count); no other
        // memory depends on its value
        self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        // entlint: allow(hot-path-alloc-free) — the counted fallback itself: taken only
        // when a slot's previous views are still live, and the steady-state tests pin
        // this to zero occurrences
        Arc::new(vec![0.0; 2 * self.half])
    }

    /// Return a buffer to its slot so the next `acquire` two blocks
    /// later can recycle it.
    // entlint: hot
    pub fn release(&self, b: usize, buf: &Arc<Vec<f32>>) {
        *self.slots[b & 1].lock().unwrap() = Some(Arc::clone(buf));
    }

    pub fn fresh_allocs(&self) -> usize {
        // Relaxed: gauge read for tests/metrics; no ordering contract with the slots
        self.fresh_allocs.load(Ordering::Relaxed)
    }

    /// Grow both slot buffers to at least `half` f32s per stream (a
    /// reroute absorbed a larger block range); no-op when capacity
    /// already suffices, so warm buffers survive unrelated reroutes.
    pub fn ensure_capacity(&mut self, half: usize) {
        if half <= self.half {
            return;
        }
        self.half = half;
        for slot in &self.slots {
            *slot.lock().unwrap() = Some(Arc::new(vec![0.0; 2 * half]));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(seed: usize, vals: usize) -> Vec<f32> {
        (0..vals).map(|i| ((seed * 31 + i * 7) % 100) as f32 * 0.125 - 6.0).collect()
    }

    fn filled(fmt: TailFmt, window: usize, lanes: usize, rows: usize) -> PackedKv {
        let (h, hd, ctx) = (2, 4, 64);
        let mut p = PackedKv::new(fmt, window, h, hd, ctx, lanes);
        for pos in 0..rows {
            for lane in 0..lanes {
                let rk = row(lane * 1000 + pos, h * hd);
                let rv = row(lane * 1000 + pos + 500, h * hd);
                p.commit_row(lane, pos, &rk, &rv).unwrap();
            }
        }
        p
    }

    fn gather_row(dst: &[f32], li: usize, p: usize, h: usize, hd: usize, ctx: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for head in 0..h {
            let at = ((li * h + head) * ctx + p) * hd;
            out.extend_from_slice(&dst[at..at + hd]);
        }
        out
    }

    #[test]
    fn lossless_roundtrips_exactly_across_chunk_boundaries() {
        let (h, hd, ctx) = (2, 4, 64);
        // enough rows for sealed chunks + pending + window
        let rows = CHUNK_ROWS * 2 + 7;
        let p = filled(TailFmt::F32, 4, 2, rows);
        let mut scratch = KvScratch::new();
        let n = 2 * h * ctx * hd;
        let (mut dk, mut dv) = (vec![9.0f32; n], vec![9.0f32; n]);
        p.materialize_into(&mut dk, &mut dv, 0, 2, ctx, &mut scratch).unwrap();
        for lane in 0..2 {
            for pos in 0..rows {
                assert_eq!(
                    gather_row(&dk, lane, pos, h, hd, ctx),
                    row(lane * 1000 + pos, h * hd),
                    "k lane {lane} pos {pos}"
                );
                assert_eq!(
                    gather_row(&dv, lane, pos, h, hd, ctx),
                    row(lane * 1000 + pos + 500, h * hd),
                    "v lane {lane} pos {pos}"
                );
            }
            // untouched beyond len (masked positions; sentinel survives)
            assert_eq!(gather_row(&dk, lane, rows, h, hd, ctx), vec![9.0f32; h * hd]);
        }
    }

    #[test]
    fn quantized_tail_roundtrips_through_its_own_quantizer() {
        let (h, hd, ctx) = (2, 4, 64);
        let rows = CHUNK_ROWS + 5;
        let window = 3;
        for fmt in [TailFmt::F8, TailFmt::Bf16] {
            let p = filled(fmt, window, 1, rows);
            let mut scratch = KvScratch::new();
            let n = h * ctx * hd;
            let (mut dk, mut dv) = (vec![0.0f32; n], vec![0.0f32; n]);
            p.materialize_into(&mut dk, &mut dv, 0, 1, ctx, &mut scratch).unwrap();
            for pos in 0..rows {
                let want_k = row(pos, h * hd);
                let got_k = gather_row(&dk, 0, pos, h, hd, ctx);
                if pos >= rows - window {
                    assert_eq!(got_k, want_k, "window row must be exact, pos {pos}");
                } else {
                    for (g, w) in got_k.iter().zip(&want_k) {
                        let expect = match fmt {
                            TailFmt::F8 => f8e4m3::decode(f8e4m3::encode(*w)),
                            TailFmt::Bf16 => bf16::decode(bf16::encode(*w)),
                            TailFmt::F32 => *w,
                        };
                        assert_eq!(*g, expect, "tail row quantizer roundtrip, pos {pos}");
                    }
                }
            }
            let _ = dv;
        }
    }

    #[test]
    fn replay_overwrite_is_verbatim_and_gaps_error() {
        let rows = CHUNK_ROWS + 3;
        let mut p = filled(TailFmt::F8, 4, 1, rows);
        let before = snapshot_bytes(&p);
        // replay: re-commit the newest row with identical values
        let rk = row(rows - 1, 8);
        let rv = row(rows - 1 + 500, 8);
        p.commit_row(0, rows - 1, &rk, &rv).unwrap();
        assert_eq!(snapshot_bytes(&p), before, "verbatim replay must not change stored bytes");
        // a gap is a contract violation
        assert!(p.commit_row(0, rows + 1, &rk, &rv).is_err());
        // overwriting below the window is one too
        assert!(p.commit_row(0, 0, &rk, &rv).is_err());
    }

    fn snapshot_bytes(p: &PackedKv) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        for lk in &p.lanes {
            for stream in [&lk.k, &lk.v] {
                for c in &stream.chunks {
                    out.push(c.clone());
                }
                out.push(stream.pending.clone());
                let mut w = Vec::new();
                for x in &stream.window {
                    w.extend_from_slice(&x.to_le_bytes());
                }
                out.push(w);
            }
        }
        out
    }

    #[test]
    fn adopt_and_zero_fill_match_expectations() {
        let rows = 9;
        let src = filled(TailFmt::Bf16, 4, 1, rows);
        let mut dst = PackedKv::new(TailFmt::Bf16, 4, 2, 4, 64, 3);
        dst.zero_fill_lane(1, rows).unwrap();
        dst.adopt_lane_from(1, &src, 0).unwrap();
        assert_eq!(dst.lane_len(1), rows);
        let mut scratch = KvScratch::new();
        let n = 3 * 2 * 64 * 4;
        let (mut dk, mut dv) = (vec![0.0f32; n], vec![0.0f32; n]);
        dst.materialize_into(&mut dk, &mut dv, 0, 3, 64, &mut scratch).unwrap();
        // adopted lane reproduces the source's newest (exact) row
        assert_eq!(gather_row(&dk, 1, rows - 1, 2, 4, 64), row(rows - 1, 8));
        let _ = dv;
        // incompatible geometry is rejected
        let other = PackedKv::new(TailFmt::F8, 4, 2, 4, 64, 1);
        assert!(dst.adopt_lane_from(0, &other, 0).is_err());
    }

    #[test]
    fn byte_accounting_shows_compression() {
        let (_h, _hd, ctx) = (2, 4, 64);
        let rows = ctx; // full context
        let p = filled(TailFmt::F8, 4, 1, rows);
        let b = p.bytes();
        assert_eq!(b.raw, 2 * 2 * 64 * 4 * 4);
        assert!(b.resident < b.raw / 3, "f8 tail must be >= 3x smaller: {b:?}");
        assert!(b.compressed > 0 && b.compressed < b.resident);
        // lossless packing never exceeds raw by more than chunk framing
        let pl = filled(TailFmt::F32, 4, 1, rows);
        let bl = pl.bytes();
        assert!(bl.resident <= bl.raw + 64, "{bl:?}");
    }

    #[test]
    fn ring_recycles_buffers_alloc_free() {
        let ring = KvRing::new(128);
        for step in 0..10 {
            for blk in 0..4 {
                let buf = ring.acquire(blk);
                assert_eq!(buf.len(), 256);
                ring.release(blk, &buf);
                let _ = step;
            }
        }
        assert_eq!(ring.fresh_allocs(), 0);
        // a held buffer forces a counted fresh allocation
        let held = ring.acquire(0);
        let fresh = ring.acquire(2); // same slot (2 & 1 == 0)
        ring.release(2, &fresh);
        drop(held);
        assert_eq!(ring.fresh_allocs(), 1);
    }
}
