//! Dynamic batcher: packs FCFS requests into the fixed-shape slots the
//! AOT artifacts were compiled for (vLLM-style slot packing, DESIGN.md
//! §5).  Prompts are LEFT-padded so every request's last real token sits
//! at the slot's final position; the per-request `start` index rides
//! along and masks padding out of attention inside the HLO.

#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
}

#[derive(Clone, Debug)]
pub struct Batch {
    /// (batch, seq) slot this batch is padded to
    pub slot: (usize, usize),
    pub requests: Vec<Request>,
    /// flattened [slot.0 x slot.1] token grid, left-padded with PAD
    pub tokens: Vec<u8>,
    /// first real-token index per lane (lanes beyond requests.len() are
    /// fully padded with start == seq, masking the whole lane out)
    pub starts: Vec<i32>,
}

pub const PAD: u8 = b' ';

/// Pick the slot for a group of `group_len` requests whose longest
/// prompt is `longest` tokens.  Among slots with capacity for the
/// group, prefer the smallest seq that holds the longest prompt
/// un-truncated (ties to the smallest batch); when no seq is long
/// enough, fall back to the largest seq — minimal, deterministic
/// truncation (ties again to the smallest batch).  When no slot has
/// the capacity, the largest-capacity choice under the same seq rules
/// applies and the caller's group simply occupies every lane.
pub fn select_slot(group_len: usize, longest: usize, slots: &[(usize, usize)]) -> (usize, usize) {
    assert!(!slots.is_empty());
    let fitting: Vec<(usize, usize)> =
        slots.iter().copied().filter(|(b, _)| *b >= group_len).collect();
    let pool: &[(usize, usize)] = if fitting.is_empty() { slots } else { &fitting };
    let fits = pool.iter().filter(|(_, s)| *s >= longest).min_by_key(|(b, s)| (*s, *b));
    if let Some(&slot) = fits {
        return slot;
    }
    // every seq truncates: take the longest (then smallest batch)
    *pool.iter().max_by_key(|(b, s)| (*s, usize::MAX - *b)).unwrap()
}

/// Pack requests into batches.  All slots share the same seq in the
/// shipped config but mixed seqs are handled by `select_slot`
/// (smallest seq >= longest prompt in the group, falling back to
/// truncating the prompt's head — oldest context first, like a sliding
/// window).  No requests means no batches — the slot table is not even
/// consulted.
pub fn pack(requests: &[Request], slots: &[(usize, usize)]) -> Vec<Batch> {
    if requests.is_empty() {
        return Vec::new();
    }
    assert!(!slots.is_empty());
    let max_b = slots.iter().map(|s| s.0).max().unwrap();
    let mut batches = Vec::new();
    for group in requests.chunks(max_b) {
        let longest = group.iter().map(|r| r.prompt.len()).max().unwrap_or(0);
        let slot = select_slot(group.len(), longest, slots);
        let (b, s) = slot;
        let mut tokens = vec![PAD; b * s];
        let mut starts = vec![s as i32; b];
        for (lane, req) in group.iter().enumerate() {
            // truncate from the head if the prompt exceeds the slot
            let p = if req.prompt.len() > s { &req.prompt[req.prompt.len() - s..] } else { &req.prompt[..] };
            let start = s - p.len();
            starts[lane] = start as i32;
            tokens[lane * s + start..(lane + 1) * s].copy_from_slice(p);
        }
        batches.push(Batch { slot, requests: group.to_vec(), tokens, starts });
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn req(id: u64, len: usize) -> Request {
        Request { id, prompt: (0..len).map(|i| (40 + (i % 40)) as u8).collect(), max_new_tokens: 8 }
    }

    const SLOTS: &[(usize, usize)] = &[(1, 128), (4, 128)];

    #[test]
    fn single_request_uses_smallest_slot() {
        let b = pack(&[req(1, 10)], SLOTS);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].slot, (1, 128));
        assert_eq!(b[0].starts[0], 118);
    }

    #[test]
    fn five_requests_split_4_plus_1() {
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 20 + i as usize)).collect();
        let b = pack(&reqs, SLOTS);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].slot, (4, 128));
        assert_eq!(b[0].requests.len(), 4);
        assert_eq!(b[1].slot, (1, 128));
        assert_eq!(b[1].requests.len(), 1);
    }

    #[test]
    fn order_preserved_and_exactly_once() {
        let reqs: Vec<Request> = (0..11).map(|i| req(i, 5 + (i as usize * 13) % 100)).collect();
        let batches = pack(&reqs, SLOTS);
        let flat: Vec<u64> = batches.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(flat, (0..11).collect::<Vec<u64>>());
    }

    #[test]
    fn padding_invariants_random_sweep() {
        // proptest-style: random request sets; all invariants hold
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let n = 1 + rng.below(9);
            let reqs: Vec<Request> = (0..n as u64).map(|i| req(i, 1 + rng.below(140))).collect();
            for batch in pack(&reqs, SLOTS) {
                let (b, s) = batch.slot;
                assert!(batch.requests.len() <= b);
                for (lane, r) in batch.requests.iter().enumerate() {
                    let start = batch.starts[lane] as usize;
                    let expect_len = r.prompt.len().min(s);
                    assert_eq!(s - start, expect_len, "lane {lane}");
                    // bytes before start are PAD
                    assert!(batch.tokens[lane * s..lane * s + start].iter().all(|&t| t == PAD));
                    // real suffix matches the (possibly truncated) prompt
                    let p = &r.prompt[r.prompt.len() - expect_len..];
                    assert_eq!(&batch.tokens[lane * s + start..(lane + 1) * s], p);
                }
                // unused lanes fully masked
                for lane in batch.requests.len()..b {
                    assert_eq!(batch.starts[lane], s as i32);
                }
            }
        }
    }

    #[test]
    fn empty_requests_return_no_batches_without_touching_slots() {
        // the slot table must not be consulted (an empty one would
        // panic the assert) — no requests simply means no batches
        assert!(pack(&[], &[]).is_empty());
        assert!(pack(&[], SLOTS).is_empty());
    }

    #[test]
    fn mixed_seq_slots_pick_smallest_seq_that_fits_the_prompt() {
        let slots = &[(4, 64), (4, 256), (1, 256)];
        // fits the short seq: stay there
        let b = pack(&[req(0, 40), req(1, 10)], slots);
        assert_eq!(b[0].slot, (4, 64));
        // longest prompt exceeds 64: the 256 slot with enough lanes wins
        let b = pack(&[req(0, 40), req(1, 100)], slots);
        assert_eq!(b[0].slot, (4, 256));
        assert_eq!(b[0].starts[1], 156);
        assert_eq!(&b[0].tokens[256 + 156..2 * 256], &req(1, 100).prompt[..]);
    }

    #[test]
    fn prompt_longer_than_every_seq_truncates_deterministically() {
        let slots = &[(1, 32), (1, 64)];
        let r = req(7, 100);
        let b1 = pack(&[r.clone()], slots);
        let b2 = pack(&[r.clone()], slots);
        // largest seq wins (least truncation), head dropped, tail kept
        assert_eq!(b1[0].slot, (1, 64));
        assert_eq!(b1[0].starts[0], 0);
        assert_eq!(&b1[0].tokens[..], &r.prompt[100 - 64..]);
        // byte-for-byte repeatable
        assert_eq!(b1[0].tokens, b2[0].tokens);
        assert_eq!(b1[0].starts, b2[0].starts);
    }

    #[test]
    fn select_slot_prefers_fit_then_minimal_truncation() {
        let slots = &[(1, 32), (2, 64), (4, 128)];
        assert_eq!(select_slot(1, 10, slots), (1, 32));
        assert_eq!(select_slot(1, 50, slots), (2, 64));
        assert_eq!(select_slot(3, 10, slots), (4, 128));
        // nothing holds 500 tokens: largest seq, smallest batch on ties
        assert_eq!(select_slot(1, 500, slots), (4, 128));
        // over-capacity group: capacity filter relaxes, seq rules hold
        assert_eq!(select_slot(9, 10, slots), (1, 32));
    }

    #[test]
    fn long_prompt_keeps_most_recent_tokens() {
        let r = req(1, 300);
        let b = pack(&[r.clone()], SLOTS);
        assert_eq!(b[0].starts[0], 0);
        assert_eq!(&b[0].tokens[..], &r.prompt[300 - 128..]);
    }
}
