//! The serving engine — paper Algorithm 2 embedded in a block-wise
//! decode-ahead pipeline (§A.1):
//!
//!   weights live in memory as per-block ANS bitstreams; a decoder
//!   thread inflates block i+1's symbols into one of two reusable code
//!   buffers while the PJRT executable runs block i.  Individual layers
//!   are views into the block buffer (no copies).  After the block's
//!   forward completes the buffer is recycled — exactly the paper's
//!   double-buffer scheme, with a thread standing in for the GPU's
//!   async decompression stream.
//!
//! Weight residency modes (Figure 5's comparison set):
//!   * Bf16Resident — all weights dequantized f32 and resident (baseline)
//!   * F8Resident   — codes+scales resident, no ANS on the hot path
//!                    (the paper's "Float8" Marlin row)
//!   * EntQuant     — bitstreams resident, ANS decode on the fly
//!   * DiskOffload  — weights read from disk per block (the paper's
//!                    "CPU offload" reference point)

// Kernel-module lint posture (see the note in Cargo.toml): index loops mirror
// the reference layouts, the executable calling convention needs wide argument
// lists, and the arena's double-buffer slot type is spelled out once.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::manual_range_contains)]

use super::batcher::Batch;
use super::kv::{KvBytes, KvCache, KvCfg, KvRing, KvScratch, PackedKv};

use crate::obs::Stopwatch;
use crate::runtime::{HostTensor, Runtime};
use crate::store::container::{CompressedBlock, CompressedModel, SharedMat};
use anyhow::{anyhow, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    Bf16Resident,
    F8Resident,
    EntQuant,
    DiskOffload,
}

/// Which pipeline phases this engine serves.  The first shard embeds
/// (prefill and decode), the last applies the final norm + LM head;
/// middle shards run only block phases and materialize neither tensor.
/// A reroute or rejoin can promote a middle shard, so the role is
/// re-settable mid-stream (`ServingEngine::set_role`) — promotion costs
/// an Arc bump, never a copy, because the views alias the container's
/// shared storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRole {
    /// serves `embed_*` (owns the embedding-table view)
    pub first: bool,
    /// serves `head_*` (owns the final-norm + head views)
    pub last: bool,
}

impl Default for ShardRole {
    /// A standalone engine is the whole pipeline.
    fn default() -> Self {
        ShardRole { first: true, last: true }
    }
}

/// The double-buffer arena the §A.1 pipeline promises: two preallocated
/// block-sized f32 code buffers (sized to the largest block), recycled
/// across blocks and across decode steps, so steady-state token
/// generation performs no block-sized decode-buffer allocations (small
/// per-view metadata — dims vectors, the per-block view list — is the
/// only remaining heap traffic).  Buffers hand
/// out as `Arc`s: per-layer `HostTensor` views alias the block buffer,
/// and a slot is reclaimable (strong count back to 1) once the block's
/// forward has dropped its inputs — with the one-ahead pipeline that is
/// always true by the time the slot's turn comes round again, two
/// blocks later.
struct DecodeArena {
    slots: [Mutex<Option<Arc<Vec<f32>>>>; 2],
    max_symbols: usize,
    /// Fresh allocations forced by a still-referenced slot: 0 in steady
    /// state (the alloc-free tests pin this).
    fresh_allocs: AtomicUsize,
}

impl DecodeArena {
    fn new(max_symbols: usize) -> Self {
        DecodeArena {
            slots: [
                Mutex::new(Some(Arc::new(vec![0.0; max_symbols]))),
                Mutex::new(Some(Arc::new(vec![0.0; max_symbols]))),
            ],
            max_symbols,
            fresh_allocs: AtomicUsize::new(0),
        }
    }

    /// Check block `b`'s buffer out of its slot for exclusive decode
    /// use; falls back to a fresh (counted) allocation if the slot's
    /// previous tenant still has live views.
    // entlint: hot
    fn acquire(&self, b: usize) -> Arc<Vec<f32>> {
        if let Some(mut arc) = self.slots[b & 1].lock().unwrap().take() {
            if Arc::get_mut(&mut arc).is_some() {
                return arc;
            }
        }
        // Relaxed: independent monotonic gauge (allocation-miss count); no other
        // memory depends on its value
        self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        // entlint: allow(hot-path-alloc-free) — the counted fallback itself: taken only
        // when a slot's previous views are still live, and the steady-state tests pin
        // this to zero occurrences
        Arc::new(vec![0.0; self.max_symbols])
    }

    /// Return a buffer to its slot so the next `acquire` two blocks
    /// later can recycle it.
    // entlint: hot
    fn release(&self, b: usize, buf: &Arc<Vec<f32>>) {
        *self.slots[b & 1].lock().unwrap() = Some(Arc::clone(buf));
    }

    fn fresh_allocs(&self) -> usize {
        // Relaxed: gauge read for tests/metrics; no ordering contract with the slots
        self.fresh_allocs.load(Ordering::Relaxed)
    }

    /// Grow both slot buffers to at least `max_symbols` f32s (a splice
    /// absorbed a larger block).  The arena object — and with it the
    /// fresh-alloc ledger — survives, so the alloc-free steady-state
    /// accounting spans reroutes; a no-op when capacity already
    /// suffices, which keeps the splice path from touching the warm
    /// buffers at all.
    fn ensure_capacity(&mut self, max_symbols: usize) {
        if max_symbols <= self.max_symbols {
            return;
        }
        self.max_symbols = max_symbols;
        for slot in &self.slots {
            *slot.lock().unwrap() = Some(Arc::new(vec![0.0; max_symbols]));
        }
    }
}

/// Precomputed per-block constant tensors (scales + norms).
struct BlockConsts {
    scales: Vec<HostTensor>,
    norm_attn: HostTensor,
    norm_mlp: HostTensor,
}

#[derive(Clone)]
pub struct EngineOpts {
    pub residency: Residency,
    /// overlap ANS decode of block i+1 with compute of block i
    pub pipeline: bool,
    pub decode_threads: usize,
    /// scratch dir for DiskOffload mode
    pub offload_dir: Option<String>,
    /// which pipeline phases this engine serves (shards override)
    pub role: ShardRole,
    /// reroute reopen strategy: `true` (default) splices only the
    /// absorbed block range into the live engine state; `false` forces
    /// the legacy full rebuild (every block re-decoded under
    /// resident/offload modes) — kept for the recovery-stall bench
    /// comparison in `benches/serve.rs`.
    pub splice: bool,
    /// cross-request pipeline parallelism: at shard counts > 1, a
    /// sharded decode step splits the batch into per-shard micro-batches
    /// and streams them through the shard chain (shard *i* computes
    /// micro-batch *b* while shard *i+1* computes micro-batch *b−1*).
    /// `false` forces the sequential shard walk — kept for the
    /// pipelined-vs-sequential series in `benches/serve.rs`.
    pub stage_pipeline: bool,
    /// KV-cache storage knob: `Raw` keeps today's owned-f32 tensors;
    /// `LosslessTail`/`QuantTail` pack everything older than the
    /// lossless window into (quantized +) rANS-coded chunks, decoded
    /// into a recycled ring at attention time.  `LosslessTail` is
    /// byte-identical to `Raw` on every path.
    pub kv: KvCfg,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            residency: Residency::EntQuant,
            pipeline: true,
            decode_threads: 1,
            offload_dir: None,
            role: ShardRole::default(),
            splice: true,
            stage_pipeline: true,
            kv: KvCfg::default(),
        }
    }
}

/// Runtime program names, precomputed per (phase, batch, slot) from the
/// manifest's slot tables so the prefill/decode hot loops never pay a
/// per-call `format!` allocation.  Slots are finite and fixed for the
/// life of a runtime, so the maps are built once at engine
/// construction.
struct ProgNames {
    embed_p: HashMap<(usize, usize), String>,
    block_p: HashMap<(usize, usize), String>,
    head_p: HashMap<(usize, usize), String>,
    embed_d: HashMap<usize, String>,
    block_d: HashMap<(usize, usize), String>,
    head_d: HashMap<usize, String>,
}

impl ProgNames {
    fn new(manifest: &crate::runtime::Manifest) -> ProgNames {
        let mut n = ProgNames {
            embed_p: HashMap::new(),
            block_p: HashMap::new(),
            head_p: HashMap::new(),
            embed_d: HashMap::new(),
            block_d: HashMap::new(),
            head_d: HashMap::new(),
        };
        for &(b, s) in &manifest.prefill_slots {
            n.embed_p.insert((b, s), format!("embed_p_b{b}_s{s}"));
            n.block_p.insert((b, s), format!("block_p_b{b}_s{s}"));
            n.head_p.insert((b, s), format!("head_p_b{b}_s{s}"));
        }
        for &(b, c) in &manifest.decode_slots {
            n.embed_d.entry(b).or_insert_with(|| format!("embed_d_b{b}"));
            n.block_d.insert((b, c), format!("block_d_b{b}_c{c}"));
            n.head_d.entry(b).or_insert_with(|| format!("head_d_b{b}"));
        }
        n
    }

    fn get<'a, K: std::hash::Hash + Eq + std::fmt::Debug>(
        map: &'a HashMap<K, String>,
        key: K,
        what: &str,
    ) -> Result<&'a str> {
        map.get(&key)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("no {what} program for slot {key:?} in the manifest"))
    }

    fn embed_p(&self, slot: (usize, usize)) -> Result<&str> {
        Self::get(&self.embed_p, slot, "embed_p")
    }

    fn block_p(&self, slot: (usize, usize)) -> Result<&str> {
        Self::get(&self.block_p, slot, "block_p")
    }

    fn head_p(&self, slot: (usize, usize)) -> Result<&str> {
        Self::get(&self.head_p, slot, "head_p")
    }

    fn embed_d(&self, b: usize) -> Result<&str> {
        Self::get(&self.embed_d, b, "embed_d")
    }

    fn block_d(&self, b: usize, ctx: usize) -> Result<&str> {
        Self::get(&self.block_d, (b, ctx), "block_d")
    }

    fn head_d(&self, b: usize) -> Result<&str> {
        Self::get(&self.head_d, b, "head_d")
    }
}

#[derive(Clone)]
pub struct Metrics {
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub decode_tokens: usize,
    pub ans_decode_ms: f64,
    pub exec_ms: f64,
    pub ttft_ms: f64,
}

impl Metrics {
    pub fn zero() -> Metrics {
        Metrics {
            prefill_ms: 0.0,
            decode_ms: 0.0,
            decode_tokens: 0,
            ans_decode_ms: 0.0,
            exec_ms: 0.0,
            ttft_ms: 0.0,
        }
    }

    /// Decode throughput; 0.0 for zero-token or zero-duration runs
    /// (instead of NaN/inf from the naive division).
    pub fn tokens_per_s_decode(&self, batch: usize) -> f64 {
        let tokens = (self.decode_tokens * batch) as f64;
        if tokens <= 0.0 || self.decode_ms <= 0.0 {
            return 0.0;
        }
        tokens / (self.decode_ms / 1e3)
    }
}

pub struct ServingEngine {
    rt: Runtime,
    cm: CompressedModel,
    consts: Vec<BlockConsts>,
    /// zero-copy views over the container's shared tensors, populated
    /// per `opts.role`: `embed` on first shards, `head`/`norm_final` on
    /// last shards, none on middle shards
    embed: Option<HostTensor>,
    head: Option<HostTensor>,
    norm_final: Option<HostTensor>,
    /// resident code tensors (F8Resident / Bf16Resident modes)
    resident_codes: Option<Vec<Vec<HostTensor>>>,
    /// double-buffer code arena (EntQuant mode only)
    arena: Option<DecodeArena>,
    /// width descriptor for this engine's scoped decode fan-outs (each
    /// shard carries its own, so per-shard decode width is independent;
    /// workers themselves are scoped per call, not long-lived)
    pool: crate::parallel::Pool,
    opts: EngineOpts,
    value_table: [f32; 256],
    offload_paths: Vec<String>,
    /// per-(phase, batch, slot) program names, precomputed so the hot
    /// loops never allocate a name
    names: ProgNames,
    /// blocks ANS-decoded for load-time residency (construction plus
    /// every splice) — the splice tests pin that a reroute decodes only
    /// the absorbed range
    residency_decodes: Cell<usize>,
    /// blocks absorbed through `reopen_blocks` (the
    /// `recovery_spliced_blocks` gauge)
    spliced: Cell<usize>,
    /// persistent per-block code buffers for the micro-batched
    /// (stage-pipelined) sharded decode: each pipelined step
    /// ANS-decodes every block ONCE into these and reuses the views
    /// across micro-batches, bypassing the two-slot arena (which cannot
    /// hold all blocks of a shard live at once without counted fresh
    /// allocations).  Lazily sized on first use, recycled across steps,
    /// cleared whenever the block set changes (splice/truncate/reopen).
    stage_codes: RefCell<Vec<Arc<Vec<f32>>>>,
    /// double-buffer ring for materialized packed KV caches (None under
    /// `KvMode::Raw`); sized once from the manifest's decode slots,
    /// which reroutes never change
    kv_ring: Option<KvRing>,
    /// chunk-decode + row staging scratch for the packed KV paths
    kv_scratch: RefCell<KvScratch>,
}

impl ServingEngine {
    pub fn new(rt: Runtime, cm: CompressedModel, opts: EngineOpts) -> Result<Self> {
        let cfg = &rt.manifest.config;
        anyhow::ensure!(
            cm.config.d_model == cfg.d_model && cm.config.n_layers == cfg.n_layers,
            "compressed model does not match serving artifacts ({} vs {})",
            cm.config.name,
            cfg.name
        );
        let value_table = cm.fmt.value_table();
        let consts = build_consts(&cm);
        // role-gated zero-copy views: an Arc bump each, backed by the
        // container's shared storage — middle shards hold none at all
        let (embed, head, norm_final) = build_role_views(&cm, opts.role);
        // §A.1 double buffering: EntQuant serving recycles two
        // block-sized code buffers across blocks and decode steps
        let arena = build_arena(&cm, &opts);
        let pool = crate::parallel::Pool::new(opts.decode_threads);
        let names = ProgNames::new(&rt.manifest);
        let (resident_codes, offload_paths, decodes) =
            build_residency(&cm, &opts, &value_table, pool.threads(), resolve_offload_dir(&opts))?;
        // packed-KV materialization ring: one slot pair sized for the
        // largest decode slot's [b, h, ctx, hd] stream — decode slots
        // are manifest-level, so reroutes never need to regrow it
        let kv_ring = opts.kv.mode.tail_fmt().map(|_| {
            let max_bc = names.block_d.keys().map(|&(b, c)| b * c).max().unwrap_or(0);
            KvRing::new(max_bc * cfg.n_heads * cfg.head_dim())
        });
        let mut kv_scratch = KvScratch::new();
        kv_scratch.reserve(cfg.n_heads * cfg.head_dim());
        Ok(ServingEngine {
            rt,
            cm,
            consts,
            embed,
            head,
            norm_final,
            resident_codes,
            arena,
            pool,
            opts,
            value_table,
            offload_paths,
            names,
            residency_decodes: Cell::new(decodes),
            spliced: Cell::new(0),
            stage_codes: RefCell::new(Vec::new()),
            kv_ring,
            kv_scratch: RefCell::new(kv_scratch),
        })
    }

    /// Fresh allocations forced on the packed-KV materialization ring
    /// (0 in steady state, same contract as the decode arena; 0 when
    /// the ring doesn't exist under `KvMode::Raw`).
    pub fn kv_fresh_allocs(&self) -> usize {
        self.kv_ring.as_ref().map_or(0, |r| r.fresh_allocs())
    }

    /// Run `f` with this engine's packed-KV scratch buffers.  The
    /// pipelined shard walk materializes/commits packed lanes outside
    /// `decode_blocks*`, and reusing the engine's scratch keeps that
    /// path on the same alloc-free budget as the in-engine one.
    pub(crate) fn with_kv_scratch<R>(&self, f: impl FnOnce(&mut KvScratch) -> R) -> R {
        f(&mut self.kv_scratch.borrow_mut())
    }

    /// Re-aim this engine's pipeline role (reroutes and rejoins promote
    /// or demote shards mid-stream).  Costs an Arc bump per view, never
    /// a tensor copy.
    pub fn set_role(&mut self, role: ShardRole) {
        self.opts.role = role;
        let (embed, head, norm_final) = build_role_views(&self.cm, role);
        self.embed = embed;
        self.head = head;
        self.norm_final = norm_final;
    }

    pub fn role(&self) -> ShardRole {
        self.opts.role
    }

    /// Blocks ANS-decoded for load-time residency so far (construction
    /// plus splices; always 0 under EntQuant, which decodes on the hot
    /// path instead).
    pub fn residency_decodes(&self) -> usize {
        self.residency_decodes.get()
    }

    /// Blocks absorbed through `reopen_blocks` since construction.
    pub fn spliced_blocks(&self) -> usize {
        self.spliced.get()
    }

    /// Re-open a block `range` of the full container on this live
    /// engine — the shard-failure reroute primitive.  The absorbed
    /// blocks join this engine's own (`at_front` when the range
    /// precedes them in global block order, so the merged set stays a
    /// contiguous global range).  Block storage is shared with the
    /// container (`Arc` bumps — no compressed bytes are copied), and
    /// the reopen is an incremental **splice**: only the absorbed
    /// range's consts are built, only the absorbed blocks are decoded
    /// under resident/offload modes, and the double-buffer arena — with
    /// its fresh-alloc ledger — is kept (grown only if an absorbed
    /// block is larger than every current one).  Residency state for
    /// untouched blocks is preserved verbatim, which is what shrinks
    /// the recovery stall from O(merged set) to O(absorbed range).
    ///
    /// Everything fallible runs against temporaries before anything is
    /// committed, so a failed reopen (e.g. a corrupt absorbed bitstream
    /// under a resident mode, or an injected splice fault) leaves the
    /// engine serving its old range untouched.
    ///
    /// `opts.splice = false` forces the legacy full rebuild (every
    /// structure rebuilt, every block re-decoded) — kept for the
    /// recovery-stall comparison in `benches/serve.rs`.
    pub fn reopen_blocks(
        &mut self,
        full: &CompressedModel,
        range: std::ops::Range<usize>,
        at_front: bool,
    ) -> Result<()> {
        anyhow::ensure!(
            range.end <= full.blocks.len(),
            "reopen_blocks: range {range:?} outside container of {} blocks",
            full.blocks.len()
        );
        anyhow::ensure!(
            full.config == self.cm.config,
            "reopen_blocks: container config mismatch ({} vs {})",
            full.config.name,
            self.cm.config.name
        );
        anyhow::ensure!(
            full.fmt == self.cm.fmt,
            "reopen_blocks: quant format mismatch (absorbed blocks would dequantize \
             through the wrong value table)"
        );
        // scripted mid-splice faults (tests/drills) are taken before
        // any state is touched — a faulted splice must leave the engine
        // exactly as it was
        self.rt.fault_probe("splice_reopen")?;
        let absorbed: Vec<Arc<CompressedBlock>> = full.blocks[range].to_vec();
        let n_abs = absorbed.len();
        let n_old = self.cm.blocks.len();
        let mut blocks = Vec::with_capacity(n_old + n_abs);
        if at_front {
            blocks.extend(absorbed);
            blocks.extend(self.cm.blocks.iter().cloned());
        } else {
            blocks.extend(self.cm.blocks.iter().cloned());
            blocks.extend(absorbed);
        }
        let cm = CompressedModel {
            config: self.cm.config.clone(),
            fmt: self.cm.fmt,
            embed: self.cm.embed.clone(),
            head: self.cm.head.clone(),
            norm_final: Arc::clone(&self.cm.norm_final),
            blocks,
        };
        if !self.opts.splice {
            return self.reopen_full(cm, n_abs);
        }
        // --- build the absorbed range's state (all fallible work
        // happens here, against temporaries)
        let abs_local = if at_front { 0..n_abs } else { n_old..n_old + n_abs };
        let abs_consts = build_consts_range(&cm, abs_local.clone());
        let threads = self.pool.threads();
        let table = &self.value_table;
        let mut abs_resident: Vec<Vec<HostTensor>> = Vec::new();
        let mut abs_paths: Vec<String> = Vec::new();
        let mut decodes = 0usize;
        match self.opts.residency {
            Residency::Bf16Resident | Residency::F8Resident => {
                for b in abs_local.clone() {
                    let codes = decode_codes(&cm, table, None, b, threads);
                    abs_resident.push(codes.map_err(|e| anyhow!(e))?);
                    decodes += 1;
                }
            }
            Residency::DiskOffload => {
                // a FRESH directory per splice, keyed by the monotone
                // spliced-block counter (block COUNTS can shrink again
                // when a rejoin truncates the donor, so they would not
                // be unique): the live engine's current files are never
                // touched, so a failed splice leaves them serving
                let dir =
                    format!("{}/splice_{}", resolve_offload_dir(&self.opts), self.spliced.get());
                std::fs::create_dir_all(&dir)?;
                for b in abs_local.clone() {
                    abs_paths.push(write_offload_block(&cm, b, table, threads, &dir)?);
                    decodes += 1;
                }
            }
            Residency::EntQuant => {}
        }
        // --- commit (infallible from here): splice absorbed state in,
        // preserving every untouched block's state and the warm arena
        if at_front {
            self.consts.splice(0..0, abs_consts);
            if let Some(rc) = self.resident_codes.as_mut() {
                rc.splice(0..0, abs_resident);
            }
            self.offload_paths.splice(0..0, abs_paths);
        } else {
            self.consts.extend(abs_consts);
            if let Some(rc) = self.resident_codes.as_mut() {
                rc.extend(abs_resident);
            }
            self.offload_paths.extend(abs_paths);
        }
        if let Some(arena) = self.arena.as_mut() {
            arena.ensure_capacity(cm.blocks.iter().map(|b| b.n_symbols()).max().unwrap_or(0));
        }
        self.cm = cm;
        self.stage_codes.borrow_mut().clear(); // block set changed
        self.residency_decodes.set(self.residency_decodes.get() + decodes);
        self.spliced.set(self.spliced.get() + n_abs);
        Ok(())
    }

    /// The legacy reroute reopen: rebuild every load-time structure for
    /// the merged set (full residency re-decode, fresh arena).  Only
    /// reachable via `opts.splice = false`; the bench uses it to track
    /// the recovery stall the splice saves.
    fn reopen_full(&mut self, cm: CompressedModel, n_abs: usize) -> Result<()> {
        let consts = build_consts(&cm);
        let arena = build_arena(&cm, &self.opts);
        // fresh directory per reopen, keyed by the monotone spliced
        // counter for the same uniqueness reason as the splice path
        let offload_dir =
            format!("{}/reopen_{}", resolve_offload_dir(&self.opts), self.spliced.get());
        let (resident_codes, offload_paths, decodes) =
            build_residency(&cm, &self.opts, &self.value_table, self.pool.threads(), offload_dir)?;
        self.cm = cm;
        self.consts = consts;
        self.arena = arena;
        self.resident_codes = resident_codes;
        self.offload_paths = offload_paths;
        self.stage_codes.borrow_mut().clear(); // block set changed
        self.residency_decodes.set(self.residency_decodes.get() + decodes);
        self.spliced.set(self.spliced.get() + n_abs);
        Ok(())
    }

    /// Release this engine's trailing blocks, keeping local indices
    /// `0..keep` — the donor half of a rejoin: the replacement shard
    /// opens the released range from the shared container, and this
    /// engine simply forgets it.  State for kept blocks (consts,
    /// resident codes, offload files, the warm arena) is untouched;
    /// released offload files are removed best-effort.
    pub fn truncate_blocks(&mut self, keep: usize) -> Result<()> {
        anyhow::ensure!(
            keep >= 1 && keep <= self.cm.blocks.len(),
            "truncate_blocks: keep {keep} of {} blocks",
            self.cm.blocks.len()
        );
        if keep == self.cm.blocks.len() {
            return Ok(());
        }
        // scripted mid-release faults (the supervisor's backoff drills)
        // are taken before any state is touched — same contract as the
        // reopen probe: a faulted release leaves the engine as it was
        self.rt.fault_probe("splice_truncate")?;
        self.cm.blocks.truncate(keep);
        self.stage_codes.borrow_mut().clear(); // block set changed
        self.consts.truncate(keep);
        if let Some(rc) = self.resident_codes.as_mut() {
            rc.truncate(keep);
        }
        if keep < self.offload_paths.len() {
            for p in self.offload_paths.drain(keep..) {
                let _ = std::fs::remove_file(p);
            }
        }
        Ok(())
    }

    /// Release this engine's LEADING blocks, keeping local indices
    /// `n..len` — the mirror of `truncate_blocks` for a donor whose
    /// range shrinks from the left during a general rebalance.  State
    /// for kept blocks is untouched; released offload files are removed
    /// best-effort.
    pub fn drop_front_blocks(&mut self, n: usize) -> Result<()> {
        anyhow::ensure!(
            n < self.cm.blocks.len(),
            "drop_front_blocks: drop {n} of {} blocks",
            self.cm.blocks.len()
        );
        if n == 0 {
            return Ok(());
        }
        self.rt.fault_probe("splice_truncate")?; // see truncate_blocks
        self.cm.blocks.drain(..n);
        self.stage_codes.borrow_mut().clear(); // block set changed
        self.consts.drain(..n);
        if let Some(rc) = self.resident_codes.as_mut() {
            rc.drain(..n);
        }
        let take = n.min(self.offload_paths.len());
        for p in self.offload_paths.drain(..take) {
            let _ = std::fs::remove_file(p);
        }
        Ok(())
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn compressed(&self) -> &CompressedModel {
        &self.cm
    }

    /// The shard-local decode pool (width == `opts.decode_threads`).
    pub fn pool(&self) -> &crate::parallel::Pool {
        &self.pool
    }

    /// Context length of the decode slot for batch size `b`.
    pub fn decode_ctx(&self, b: usize) -> Result<usize> {
        self.rt
            .manifest
            .decode_slots
            .iter()
            .find(|(db, _)| *db == b)
            .map(|(_, c)| *c)
            .ok_or_else(|| anyhow!("no decode slot for batch {b}"))
    }

    /// ANS-decode one block straight to f32 code tensors (fused path);
    /// EntQuant serving routes through the double-buffer arena, the
    /// load-time resident/offload decodes allocate exactly-sized
    /// buffers.
    fn decode_block_codes(&self, b: usize) -> Result<Vec<HostTensor>> {
        decode_codes(&self.cm, &self.value_table, self.arena.as_ref(), b, self.pool.threads())
            .map_err(|e| anyhow!(e))
    }

    fn offload_block_codes(&self, b: usize) -> Result<Vec<HostTensor>> {
        let path = self
            .offload_paths
            .get(b)
            .ok_or_else(|| anyhow!("no offload file for block {b}"))?;
        let bytes = std::fs::read(path)?;
        parse_offload_codes(&bytes, &self.cm.blocks[b])
            .map_err(|e| anyhow!("offload file {path}: {e}"))
    }

    /// Fresh decode-buffer allocations forced past the arena — 0 in
    /// steady state (the alloc-free serving tests pin this).
    pub fn decode_arena_fresh_allocs(&self) -> usize {
        self.arena.as_ref().map_or(0, DecodeArena::fresh_allocs)
    }

    /// Fetch block codes according to the residency mode.
    fn fetch_block(&self, b: usize) -> Result<(Vec<HostTensor>, f64)> {
        let t0 = Stopwatch::start(); // metrics timing only; never branches decode
        let codes = match self.opts.residency {
            Residency::Bf16Resident | Residency::F8Resident => {
                self.resident_codes.as_ref().unwrap()[b].clone()
            }
            Residency::EntQuant => self.decode_block_codes(b)?,
            Residency::DiskOffload => self.offload_block_codes(b)?,
        };
        Ok((codes, t0.elapsed_ms()))
    }

    /// Run all blocks of one phase with the decode-ahead pipeline.
    /// `run_block(b, codes) -> Result<()>` mutates the caller's state.
    fn run_pipelined<F>(&self, ans_ms: &mut f64, mut run_block: F) -> Result<()>
    where
        F: FnMut(usize, &[HostTensor]) -> Result<()>,
    {
        let n = self.cm.blocks.len();
        if !self.opts.pipeline || self.opts.residency != Residency::EntQuant {
            for b in 0..n {
                let (codes, ms) = self.fetch_block(b)?;
                *ans_ms += ms;
                run_block(b, &codes)?;
            }
            return Ok(());
        }
        // decode-ahead (paper A.1 double buffering): the parallel
        // subsystem's one-ahead worker inflates block b+1's chunks
        // across `decode_threads` pool workers while the calling thread
        // executes block b
        let cm: &CompressedModel = &self.cm;
        let table = &self.value_table;
        let arena = self.arena.as_ref();
        let threads = self.pool.threads();
        crate::parallel::decode_ahead(
            n,
            move |b| {
                let t0 = Stopwatch::start(); // metrics timing only; never branches decode
                let codes = decode_codes(cm, table, arena, b, threads)?;
                Ok((codes, t0.elapsed_ms()))
            },
            |b, (codes, ms): (Vec<HostTensor>, f64)| {
                *ans_ms += ms; // decode wall (overlapped with prior exec)
                run_block(b, &codes).map_err(|e| format!("{e:#}"))
            },
        )
        .map_err(|e| anyhow!("decode pipeline: {e}"))
    }

    fn block_inputs(
        &self,
        b: usize,
        x: HostTensor,
        codes: &[HostTensor],
        extra: Vec<HostTensor>,
    ) -> Vec<HostTensor> {
        let mut inputs = Vec::with_capacity(1 + 7 + 7 + 2 + extra.len());
        inputs.push(x);
        inputs.extend(codes.iter().cloned());
        inputs.extend(self.consts[b].scales.iter().cloned());
        inputs.push(self.consts[b].norm_attn.clone());
        inputs.push(self.consts[b].norm_mlp.clone());
        inputs.extend(extra);
        inputs
    }

    /// The embed-table view — `Err` on a middle shard, which holds
    /// none (see `ShardRole`).
    fn embed_view(&self) -> Result<&HostTensor> {
        self.embed
            .as_ref()
            .ok_or_else(|| anyhow!("engine has no embed role (middle shard runs blocks only)"))
    }

    /// Embed one packed batch's tokens (prefill stage 1 of 3).
    pub(crate) fn embed_prefill(&self, batch: &Batch) -> Result<HostTensor> {
        let (b, s) = batch.slot;
        let tokens = HostTensor::i32(batch.tokens.iter().map(|&t| t as i32).collect(), &[b, s]);
        Ok(self
            .rt
            .call(self.names.embed_p((b, s))?, &[tokens, self.embed_view()?.clone()])?
            .remove(0))
    }

    /// Run this engine's blocks over prefill activations (stage 2 of 3;
    /// a shard runs exactly its own contiguous block range here),
    /// returning the outgoing activations and per-block [B,H,S,hd]
    /// caches.
    pub(crate) fn prefill_blocks(
        &self,
        x0: HostTensor,
        starts: &HostTensor,
        slot: (usize, usize),
        metrics: &mut Metrics,
    ) -> Result<(HostTensor, Vec<(HostTensor, HostTensor)>)> {
        let exec_name = self.names.block_p(slot)?;
        let mut x = x0;
        let mut caches: Vec<(HostTensor, HostTensor)> = Vec::with_capacity(self.cm.blocks.len());
        let mut ans_ms = 0.0;
        self.run_pipelined(&mut ans_ms, |blk, codes| {
            let t1 = Stopwatch::start(); // metrics timing only; never branches decode
            let inputs = self.block_inputs(blk, x.clone(), codes, vec![starts.clone()]);
            let mut out = self.rt.call(exec_name, &inputs)?;
            x = out.remove(0);
            let k = out.remove(0);
            let v = out.remove(0);
            caches.push((k, v));
            metrics.exec_ms += t1.elapsed_ms();
            Ok(())
        })?;
        metrics.ans_decode_ms += ans_ms;
        Ok((x, caches))
    }

    /// The head + final-norm views — `Err` on non-last shards.
    fn head_views(&self) -> Result<(&HostTensor, &HostTensor)> {
        match (&self.norm_final, &self.head) {
            (Some(n), Some(h)) => Ok((n, h)),
            _ => Err(anyhow!("engine has no head role (non-last shard runs blocks only)")),
        }
    }

    /// Final norm + LM head over prefill activations (stage 3 of 3).
    pub(crate) fn head_prefill(&self, x: HostTensor, slot: (usize, usize)) -> Result<HostTensor> {
        let (norm, head) = self.head_views()?;
        Ok(self.rt.call(self.names.head_p(slot)?, &[x, norm.clone(), head.clone()])?.remove(0))
    }

    /// Prefill one packed batch: returns (full logits [B,S,V], caches).
    pub fn prefill(&self, batch: &Batch, metrics: &mut Metrics) -> Result<(HostTensor, Vec<(HostTensor, HostTensor)>)> {
        let (b, _s) = batch.slot;
        let t0 = Stopwatch::start(); // metrics timing only; never branches decode
        let x = self.embed_prefill(batch)?;
        let starts = HostTensor::i32(batch.starts.clone(), &[b]);
        let (x, caches) = self.prefill_blocks(x, &starts, batch.slot, metrics)?;
        let logits = self.head_prefill(x, batch.slot)?;
        // one stopwatch sample feeds both gauges: ttft IS the first
        // prefill's wall time (the first token is greedy-picked from
        // these logits with no further compute), and later catch-up /
        // speculative prefill groups accumulating into the same
        // `Metrics` must not overwrite it — first-token time happens
        // once per request
        let prefill_ms = t0.elapsed_ms();
        metrics.prefill_ms += prefill_ms;
        if metrics.ttft_ms == 0.0 {
            metrics.ttft_ms = prefill_ms;
        }
        Ok((logits, caches))
    }

    /// Embed one decode step's tokens.
    pub(crate) fn embed_decode(&self, next: &[i32], b: usize) -> Result<HostTensor> {
        let toks = HostTensor::i32(next.to_vec(), &[b, 1]);
        Ok(self.rt.call(self.names.embed_d(b)?, &[toks, self.embed_view()?.clone()])?.remove(0))
    }

    /// Run this engine's blocks for one decode step, updating the
    /// caller's cache slice in place (a shard passes exactly its own
    /// cache range).
    pub(crate) fn decode_blocks(
        &self,
        x0: HostTensor,
        caches: &mut [KvCache],
        pos: i32,
        starts: &HostTensor,
        slot_b: usize,
        ctx: usize,
        metrics: &mut Metrics,
    ) -> Result<HostTensor> {
        anyhow::ensure!(
            caches.len() == self.cm.blocks.len(),
            "decode_blocks: {} caches for {} blocks",
            caches.len(),
            self.cm.blocks.len()
        );
        let block_name = self.names.block_d(slot_b, ctx)?;
        let rt = &self.rt;
        let consts = &self.consts;
        let mut x = x0;
        let mut ans_ms = 0.0;
        self.run_pipelined(&mut ans_ms, |blk, codes| {
            let t1 = Stopwatch::start(); // metrics timing only; never branches decode
            let mut inputs = Vec::with_capacity(21);
            // the executor copies its inputs, so the activation and the
            // (k, v) pair move in instead of deep-cloning per block
            inputs.push(std::mem::replace(&mut x, HostTensor::empty()));
            inputs.extend(codes.iter().cloned());
            inputs.extend(consts[blk].scales.iter().cloned());
            inputs.push(consts[blk].norm_attn.clone());
            inputs.push(consts[blk].norm_mlp.clone());
            let ring_buf = attach_kv(
                &mut caches[blk],
                &mut inputs,
                self.kv_ring.as_ref(),
                &mut self.kv_scratch.borrow_mut(),
                blk,
                slot_b,
                ctx,
            )?;
            inputs.push(HostTensor::scalar_i32(pos));
            inputs.push(starts.clone());
            let mut out = match rt.call(block_name, &inputs) {
                Ok(out) => out,
                Err(e) => {
                    // a replayed step must find the caches it started
                    // with: move the raw pair back out of the inputs /
                    // hand the ring buffer home
                    restore_kv_after_error(
                        &mut caches[blk],
                        &mut inputs,
                        self.kv_ring.as_ref(),
                        blk,
                        ring_buf,
                    );
                    return Err(e);
                }
            };
            x = out.remove(0);
            let kn = out.remove(0);
            let vn = out.remove(0);
            let committed = commit_kv(
                &mut caches[blk],
                kn,
                vn,
                pos as usize,
                slot_b,
                ctx,
                &mut self.kv_scratch.borrow_mut(),
            );
            if let (Some(buf), Some(ring)) = (&ring_buf, self.kv_ring.as_ref()) {
                ring.release(blk, buf);
            }
            committed?;
            metrics.exec_ms += t1.elapsed_ms();
            Ok(())
        })?;
        metrics.ans_decode_ms += ans_ms;
        Ok(x)
    }

    /// Fetch every block's codes for one stage-pipelined decode step,
    /// returning per-block layer views plus the fetch wall time.  Under
    /// EntQuant the ANS decode lands in the persistent per-block stage
    /// buffers (allocated on first use, recycled across steps) instead
    /// of the two-slot arena: the pipelined step runs this shard's
    /// whole block range once per micro-batch, so all blocks' views
    /// must stay live at once — cycling them through two arena slots
    /// would force a counted fresh allocation per block and break the
    /// alloc-free steady state the arena tests pin.  Other residencies
    /// go through their normal `fetch_block` path; either way the
    /// per-STEP fetch cost matches the monolithic walk exactly (one
    /// fetch per block per step, reused across micro-batches).
    pub(crate) fn stage_block_codes(&self) -> Result<(Vec<Vec<HostTensor>>, f64)> {
        let t0 = Stopwatch::start(); // metrics timing only; never branches decode
        let n = self.cm.blocks.len();
        let mut all = Vec::with_capacity(n);
        if self.opts.residency != Residency::EntQuant {
            for b in 0..n {
                let (codes, _) = self.fetch_block(b)?;
                all.push(codes);
            }
            return Ok((all, t0.elapsed_ms()));
        }
        let mut bufs = self.stage_codes.borrow_mut();
        if bufs.len() != n {
            *bufs =
                self.cm.blocks.iter().map(|cb| Arc::new(vec![0.0f32; cb.n_symbols()])).collect();
        }
        for (b, buf) in bufs.iter_mut().enumerate() {
            let cb = &self.cm.blocks[b];
            let n_sym = cb.n_symbols();
            // exclusive by construction: the previous step's views all
            // dropped when its executor calls completed; a still-held
            // view (never on the serving path) forces a fresh buffer
            if Arc::get_mut(buf).map_or(true, |d| d.len() < n_sym) {
                *buf = Arc::new(vec![0.0f32; n_sym]);
            }
            let dst = Arc::get_mut(buf).expect("fresh stage buffer is exclusively held");
            let threads = self.pool.threads();
            self.cm
                .decode_block_fused_into(b, &mut dst[..n_sym], &self.value_table, threads)
                .map_err(|e| anyhow!("stage decode of block {b}: {e:#}"))?;
            let mut views = Vec::with_capacity(cb.layers.len());
            for ((off, len), l) in cb.layer_offsets().into_iter().zip(&cb.layers) {
                views.push(HostTensor::f32_view(Arc::clone(buf), off, len, &[l.rows, l.cols]));
            }
            all.push(views);
        }
        Ok((all, t0.elapsed_ms()))
    }

    /// `decode_blocks` over pre-fetched per-block codes — the
    /// stage-pipelined path decodes once per step via
    /// `stage_block_codes` and replays the views for every micro-batch.
    /// The executor calls, their input layout, and the cache handling
    /// are identical to `decode_blocks`; byte-identity between the two
    /// walks is what the micro-batched serve tests pin.
    pub(crate) fn decode_blocks_with_codes(
        &self,
        x0: HostTensor,
        codes: &[Vec<HostTensor>],
        caches: &mut [KvCache],
        pos: i32,
        starts: &HostTensor,
        slot_b: usize,
        ctx: usize,
        metrics: &mut Metrics,
    ) -> Result<HostTensor> {
        anyhow::ensure!(
            caches.len() == self.cm.blocks.len() && codes.len() == self.cm.blocks.len(),
            "decode_blocks_with_codes: {} caches / {} code sets for {} blocks",
            caches.len(),
            codes.len(),
            self.cm.blocks.len()
        );
        let block_name = self.names.block_d(slot_b, ctx)?;
        let mut x = x0;
        for blk in 0..self.cm.blocks.len() {
            let t1 = Stopwatch::start(); // metrics timing only; never branches decode
            let mut inputs = Vec::with_capacity(21);
            // the executor copies its inputs, so the activation and the
            // (k, v) pair move in instead of deep-cloning per block
            inputs.push(std::mem::replace(&mut x, HostTensor::empty()));
            inputs.extend(codes[blk].iter().cloned());
            inputs.extend(self.consts[blk].scales.iter().cloned());
            inputs.push(self.consts[blk].norm_attn.clone());
            inputs.push(self.consts[blk].norm_mlp.clone());
            let ring_buf = attach_kv(
                &mut caches[blk],
                &mut inputs,
                self.kv_ring.as_ref(),
                &mut self.kv_scratch.borrow_mut(),
                blk,
                slot_b,
                ctx,
            )?;
            inputs.push(HostTensor::scalar_i32(pos));
            inputs.push(starts.clone());
            let mut out = match self.rt.call(block_name, &inputs) {
                Ok(out) => out,
                Err(e) => {
                    // a replayed step must find the caches it started
                    // with: move the raw pair back out of the inputs /
                    // hand the ring buffer home
                    restore_kv_after_error(
                        &mut caches[blk],
                        &mut inputs,
                        self.kv_ring.as_ref(),
                        blk,
                        ring_buf,
                    );
                    return Err(e);
                }
            };
            x = out.remove(0);
            let kn = out.remove(0);
            let vn = out.remove(0);
            let committed = commit_kv(
                &mut caches[blk],
                kn,
                vn,
                pos as usize,
                slot_b,
                ctx,
                &mut self.kv_scratch.borrow_mut(),
            );
            if let (Some(buf), Some(ring)) = (&ring_buf, self.kv_ring.as_ref()) {
                ring.release(blk, buf);
            }
            committed?;
            metrics.exec_ms += t1.elapsed_ms();
        }
        Ok(x)
    }

    /// Final norm + LM head for one decode step.
    pub(crate) fn head_decode(&self, x: HostTensor, b: usize) -> Result<HostTensor> {
        let (norm, head) = self.head_views()?;
        Ok(self.rt.call(self.names.head_d(b)?, &[x, norm.clone(), head.clone()])?.remove(0))
    }

    /// Prefill a batch into a step-wise `DecodeState`: caches expanded
    /// to the decode slot's context, every lane's first greedy token
    /// recorded.  The scheduler interleaves request admission between
    /// `decode_step` calls on the returned state.
    pub fn prefill_state(&self, batch: &Batch) -> Result<DecodeState> {
        let cfg = &self.rt.manifest.config;
        let ctx = self.decode_ctx(batch.slot.0)?;
        let mut metrics = Metrics::zero();
        // `prefill` samples one stopwatch for both prefill_ms and
        // ttft_ms (first prefill only) — no second sample here
        let (logits, prefill_caches) = self.prefill(batch, &mut metrics)?;
        Ok(state_from_prefill(batch, &logits, &prefill_caches, cfg, ctx, &self.opts.kv, metrics))
    }

    /// One greedy decode step for every lane of `st`.  Returns `false`
    /// (without stepping) once the decode context is exhausted.
    ///
    /// **Resumable**: a step that fails partway (a block errored after
    /// earlier blocks already wrote their caches at `pos`) may simply
    /// be replayed on the same state.  `next`/`outputs`/`pos` only
    /// advance in `apply_decode_logits` at the very end, and a replayed
    /// block rewrites the identical cache row at `pos` (every
    /// computation is deterministic in its inputs, which are unchanged
    /// on replay) — so replay-after-partial-failure is byte-identical
    /// to a clean step.  The serve reroute path leans on this.
    pub fn decode_step(&self, st: &mut DecodeState) -> Result<bool> {
        if st.pos >= st.ctx {
            return Ok(false);
        }
        let (b, _s) = st.batch.slot;
        let cfg = &self.rt.manifest.config;
        let t0 = Stopwatch::start(); // metrics timing only; never branches decode
        let x = self.embed_decode(&st.next, b)?;
        let starts = HostTensor::i32(st.batch.starts.clone(), &[b]);
        let pos = st.pos as i32;
        let x = self.decode_blocks(x, &mut st.caches, pos, &starts, b, st.ctx, &mut st.metrics)?;
        let logits = self.head_decode(x, b)?;
        apply_decode_logits(st, &logits, cfg.vocab, t0);
        Ok(true)
    }

    /// Greedy-generate `max_new` tokens for a packed batch (the
    /// monolithic one-shot path, now a thin loop over `prefill_state` +
    /// `decode_step`).
    pub fn generate(&self, batch: &Batch, max_new: usize) -> Result<(Vec<Vec<u8>>, Metrics)> {
        let mut st = self.prefill_state(batch)?;
        for _ in 0..max_new.saturating_sub(1) {
            if !self.decode_step(&mut st)? {
                break;
            }
        }
        Ok((truncate_outputs(st.outputs, batch.requests.len(), max_new), st.metrics))
    }

    /// Approximate resident weight bytes for this residency mode (the
    /// Figure F.3 peak-memory series).
    pub fn resident_weight_bytes(&self) -> usize {
        let linear_f32: usize = self.cm.blocks.iter().map(|b| b.n_symbols() * 4).sum();
        let streams: usize = self.cm.blocks.iter().map(|b| b.bitstream.serialized_len()).sum();
        let buffer = self.cm.blocks.iter().map(|b| b.n_symbols() * 4).max().unwrap_or(0);
        match self.opts.residency {
            Residency::Bf16Resident | Residency::F8Resident => linear_f32,
            Residency::EntQuant => streams + 2 * buffer, // bitstreams + double buffer
            Residency::DiskOffload => buffer,
        }
    }
}

/// The in-flight state of a decoding batch, extracted from the former
/// monolithic `generate` loop so a scheduler can interleave work
/// between steps: per-block decode caches, each lane's next token and
/// generated bytes, and the shared write position.
///
/// Positions are batch-global (the AOT decode executable takes one
/// `pos` scalar), so every lane in a state is step-synchronized;
/// continuous batching aligns a newcomer by running it solo until its
/// `pos` catches up, then grafting it in with `adopt_lane`.  Because
/// every per-lane computation in the executors is lane-independent
/// with a fixed reduction order, lane surgery never perturbs the other
/// lanes' token trajectories — the serve equivalence tests pin this.
pub struct DecodeState {
    pub batch: Batch,
    /// per-block decode caches: raw owned [B, H, C, hd] (k, v) tensor
    /// pairs, or the packed window+tail layout — uniform across blocks,
    /// decided at prefill from `EngineOpts::kv`
    pub caches: Vec<KvCache>,
    /// next token per lane (the most recently generated one)
    pub next: Vec<i32>,
    /// generated bytes per lane (index-aligned with lanes, not
    /// `batch.requests`; unoccupied lanes accumulate garbage that the
    /// caller ignores)
    pub outputs: Vec<Vec<u8>>,
    /// cache write position of the next decode step
    pub pos: usize,
    /// decode-slot context length (steps stop at `pos == ctx`)
    pub ctx: usize,
    pub metrics: Metrics,
}

impl DecodeState {
    pub fn lanes(&self) -> usize {
        self.batch.slot.0
    }

    pub fn seq(&self) -> usize {
        self.batch.slot.1
    }

    /// KV byte accounting summed over every block — alloc-free, swept
    /// per tick into the `kv_*` serve gauges.
    // entlint: hot
    pub fn kv_bytes(&self) -> KvBytes {
        let mut b = KvBytes::default();
        for c in &self.caches {
            b.add(c.bytes());
        }
        b
    }

    /// Graft a single-lane state (same seq, same `pos`) into `lane`:
    /// cache rows, start, next token, outputs, and the request itself
    /// all move across.  `lane` must be an existing lane — either one
    /// whose request retired, or the first lane past the occupied ones.
    pub fn adopt_lane(&mut self, src: DecodeState, lane: usize) -> Result<()> {
        anyhow::ensure!(src.batch.slot.0 == 1, "adopt_lane: source must be single-lane");
        anyhow::ensure!(
            src.batch.slot.1 == self.batch.slot.1,
            "adopt_lane: seq mismatch ({} vs {})",
            src.batch.slot.1,
            self.batch.slot.1
        );
        anyhow::ensure!(
            src.pos == self.pos,
            "adopt_lane: position mismatch ({} vs {})",
            src.pos,
            self.pos
        );
        anyhow::ensure!(lane < self.lanes(), "adopt_lane: lane {lane} outside the slot");
        anyhow::ensure!(
            lane <= self.batch.requests.len(),
            "adopt_lane: lane {lane} would leave a gap"
        );
        anyhow::ensure!(
            src.caches.len() == self.caches.len(),
            "adopt_lane: block count mismatch ({} vs {})",
            src.caches.len(),
            self.caches.len()
        );
        for (dst, srcc) in self.caches.iter_mut().zip(&src.caches) {
            match (dst, srcc) {
                (KvCache::Raw(dk, dv), KvCache::Raw(sk, sv)) => {
                    copy_cache_lane(dk, lane, sk, 0)?;
                    copy_cache_lane(dv, lane, sv, 0)?;
                }
                (KvCache::Packed(dp), KvCache::Packed(sp)) => {
                    dp.adopt_lane_from(lane, sp, 0).map_err(|e| anyhow!("adopt_lane: {e}"))?;
                }
                _ => anyhow::bail!("adopt_lane: kv mode mismatch between states"),
            }
        }
        let req = src
            .batch
            .requests
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("adopt_lane: source carries no request"))?;
        self.batch.starts[lane] = src.batch.starts[0];
        let s = self.batch.slot.1;
        self.batch.tokens[lane * s..(lane + 1) * s].copy_from_slice(&src.batch.tokens[..s]);
        if lane == self.batch.requests.len() {
            self.batch.requests.push(req);
        } else {
            self.batch.requests[lane] = req;
        }
        self.next[lane] = src.next[0];
        self.outputs[lane] = src.outputs.into_iter().next().unwrap_or_default();
        Ok(())
    }

    /// Re-pack the kept lanes into a (usually smaller) slot with decode
    /// context `new_ctx` — the scheduler's slot-downgrade path once
    /// lanes retire.  Keeps `pos`, so the trajectory of every kept lane
    /// continues unchanged.
    pub fn compact(
        &self,
        keep: &[usize],
        new_slot: (usize, usize),
        new_ctx: usize,
    ) -> Result<DecodeState> {
        let (nb, ns) = new_slot;
        anyhow::ensure!(ns == self.seq(), "compact: seq mismatch ({ns} vs {})", self.seq());
        anyhow::ensure!(keep.len() <= nb, "compact: {} lanes into a {nb}-slot", keep.len());
        anyhow::ensure!(
            self.pos <= new_ctx,
            "compact: position {} past new context {new_ctx}",
            self.pos
        );
        for &l in keep {
            anyhow::ensure!(
                l < self.lanes() && l < self.batch.requests.len(),
                "compact: lane {l} not occupied"
            );
        }
        let mut caches = Vec::with_capacity(self.caches.len());
        for cache in &self.caches {
            match cache {
                KvCache::Raw(k, v) => {
                    let dims = k.dims();
                    anyhow::ensure!(dims.len() == 4, "compact: cache must be 4-d, got {dims:?}");
                    let (h, hd) = (dims[1], dims[3]);
                    let mut nk =
                        HostTensor::f32(vec![0.0; nb * h * new_ctx * hd], &[nb, h, new_ctx, hd]);
                    let mut nv =
                        HostTensor::f32(vec![0.0; nb * h * new_ctx * hd], &[nb, h, new_ctx, hd]);
                    for (dst, &src) in keep.iter().enumerate() {
                        copy_cache_lane(&mut nk, dst, k, src)?;
                        copy_cache_lane(&mut nv, dst, v, src)?;
                    }
                    caches.push(KvCache::Raw(nk, nv));
                }
                KvCache::Packed(p) => {
                    let mut np =
                        PackedKv::new(p.fmt(), p.window(), p.h(), p.hd(), new_ctx, nb);
                    for (dst, &src) in keep.iter().enumerate() {
                        np.adopt_lane_from(dst, p, src).map_err(|e| anyhow!("compact: {e}"))?;
                    }
                    // unoccupied lanes: `pos` committed zero rows — the
                    // packed analogue of the raw path's fresh zeroed
                    // tensor at every readable position
                    for lane in keep.len()..nb {
                        np.zero_fill_lane(lane, self.pos)
                            .map_err(|e| anyhow!("compact zero-fill: {e}"))?;
                    }
                    caches.push(KvCache::Packed(Box::new(np)));
                }
            }
        }
        // unoccupied lanes: fully masked (start == seq) with a benign
        // token 0 — lane independence keeps them inert
        let mut starts = vec![ns as i32; nb];
        let mut next = vec![0i32; nb];
        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); nb];
        let mut tokens = vec![super::batcher::PAD; nb * ns];
        let mut requests = Vec::with_capacity(keep.len());
        for (dst, &src) in keep.iter().enumerate() {
            starts[dst] = self.batch.starts[src];
            next[dst] = self.next[src];
            outputs[dst] = self.outputs[src].clone();
            tokens[dst * ns..(dst + 1) * ns]
                .copy_from_slice(&self.batch.tokens[src * ns..(src + 1) * ns]);
            requests.push(self.batch.requests[src].clone());
        }
        Ok(DecodeState {
            batch: Batch { slot: new_slot, requests, tokens, starts },
            caches,
            next,
            outputs,
            pos: self.pos,
            ctx: new_ctx,
            metrics: self.metrics.clone(),
        })
    }
}

/// Build a `DecodeState` from prefill outputs: caches expanded to the
/// decode context, every lane's first greedy token recorded.  Shared by
/// the single engine and the shard pipeline so the greedy-pick /
/// bookkeeping semantics can never diverge between them.
pub(crate) fn state_from_prefill(
    batch: &Batch,
    logits: &HostTensor,
    prefill_caches: &[(HostTensor, HostTensor)],
    cfg: &crate::model::Config,
    ctx: usize,
    kv: &KvCfg,
    metrics: Metrics,
) -> DecodeState {
    let (b, s) = batch.slot;
    let (h, hd) = (cfg.n_heads, cfg.head_dim());
    let caches = match kv.mode.tail_fmt() {
        None => expand_prefill_caches(prefill_caches, b, h, hd, s, ctx)
            .into_iter()
            .map(|(k, v)| KvCache::Raw(k, v))
            .collect(),
        Some(fmt) => pack_prefill_caches(prefill_caches, b, h, hd, s, ctx, fmt, kv.window),
    };
    // greedy pick from the last prefill position
    let vsize = cfg.vocab;
    let lf = logits.as_f32();
    let next: Vec<i32> = (0..b)
        .map(|bi| {
            let row = &lf[(bi * s + (s - 1)) * vsize..(bi * s + s) * vsize];
            argmax(row) as i32
        })
        .collect();
    let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); b];
    for (bi, o) in outputs.iter_mut().enumerate() {
        o.push(next[bi] as u8);
    }
    DecodeState { batch: batch.clone(), caches, next, outputs, pos: s, ctx, metrics }
}

/// The one `generate` output contract, shared by the single engine and
/// the shard pipeline so it can never drift between them: per-request
/// outputs, each capped at `max_new` tokens.  `max_new == 0` therefore
/// yields empty outputs even though the prefill already greedy-picked a
/// first token — callers wanting at least the prefill token ask for
/// `max_new >= 1` (the scheduler's submit path clamps exactly so, and
/// documents why).
pub(crate) fn truncate_outputs(
    outputs: Vec<Vec<u8>>,
    n_requests: usize,
    max_new: usize,
) -> Vec<Vec<u8>> {
    outputs
        .into_iter()
        .take(n_requests)
        .map(|mut o| {
            o.truncate(max_new);
            o
        })
        .collect()
}

/// Fold one decode step's logits into the state (greedy pick, output
/// append, counters, position advance) — the other half shared between
/// the single engine and the shard pipeline.
pub(crate) fn apply_decode_logits(
    st: &mut DecodeState,
    logits: &HostTensor,
    vsize: usize,
    t0: Stopwatch,
) {
    let b = st.batch.slot.0;
    let lf = logits.as_f32();
    for bi in 0..b {
        st.next[bi] = argmax(&lf[bi * vsize..(bi + 1) * vsize]) as i32;
    }
    for (bi, o) in st.outputs.iter_mut().enumerate() {
        o.push(st.next[bi] as u8);
    }
    st.metrics.decode_tokens += 1;
    st.metrics.decode_ms += t0.elapsed_ms();
    st.pos += 1;
}

/// Expand prefill caches [B,H,S,hd] into decode caches [B,H,C,hd]
/// (positions past S stay zero until decode steps write them).
pub(crate) fn expand_prefill_caches(
    prefill: &[(HostTensor, HostTensor)],
    b: usize,
    h: usize,
    hd: usize,
    s: usize,
    ctx: usize,
) -> Vec<(HostTensor, HostTensor)> {
    let expand = |t: &HostTensor| {
        let src = t.as_f32();
        let mut dst = vec![0.0f32; b * h * ctx * hd];
        for bi in 0..b {
            for hi in 0..h {
                for si in 0..s {
                    let so = ((bi * h + hi) * s + si) * hd;
                    let d0 = ((bi * h + hi) * ctx + si) * hd;
                    dst[d0..d0 + hd].copy_from_slice(&src[so..so + hd]);
                }
            }
        }
        HostTensor::f32(dst, &[b, h, ctx, hd])
    };
    prefill.iter().map(|(k, v)| (expand(k), expand(v))).collect()
}

/// Copy one lane of a [B,H,C,hd] cache tensor into another (contexts
/// may differ; the overlapping prefix is copied, which covers every
/// position at or below the write cursor).
pub(crate) fn copy_cache_lane(
    dst: &mut HostTensor,
    dst_lane: usize,
    src: &HostTensor,
    src_lane: usize,
) -> Result<()> {
    let dd: Vec<usize> = dst.dims().to_vec();
    let sd: Vec<usize> = src.dims().to_vec();
    anyhow::ensure!(
        dd.len() == 4 && sd.len() == 4 && dd[1] == sd[1] && dd[3] == sd[3],
        "cache lane copy: incompatible shapes {dd:?} vs {sd:?}"
    );
    anyhow::ensure!(
        dst_lane < dd[0] && src_lane < sd[0],
        "cache lane copy: lane out of range ({dst_lane} of {}, {src_lane} of {})",
        dd[0],
        sd[0]
    );
    let (h, hd) = (dd[1], dd[3]);
    let (dc, sc) = (dd[2], sd[2]);
    let c = dc.min(sc);
    let sdata = src.as_f32();
    let data = match dst {
        HostTensor::F32 { data, .. } => data,
        _ => anyhow::bail!("cache lane copy: destination must be an owned f32 tensor"),
    };
    for head in 0..h {
        for p in 0..c {
            let doff = ((dst_lane * h + head) * dc + p) * hd;
            let soff = ((src_lane * h + head) * sc + p) * hd;
            data[doff..doff + hd].copy_from_slice(&sdata[soff..soff + hd]);
        }
    }
    Ok(())
}

/// Indices of the (k, v) cache pair in the 21-input decode executable
/// calling convention (`[x, 7 codes, 7 scales, norm_attn, norm_mlp,
/// kc, vc, pos, starts]`) — the error path pulls the moved raw pair
/// back out of the input vector by these.
const KV_INPUT_AT: usize = 17;

/// Attach block `blk`'s (k, v) executor inputs from its cache:
/// `Raw` moves the owned pair in (zero-copy — `restore_kv_after_error`
/// moves it back if the call fails), `Packed` decodes window + tail
/// into the materialization ring and attaches Arc-backed views.
/// Returns the ring buffer to release after the call, if one was
/// acquired.
// entlint: hot
fn attach_kv(
    cache: &mut KvCache,
    inputs: &mut Vec<HostTensor>,
    ring: Option<&KvRing>,
    scratch: &mut KvScratch,
    blk: usize,
    slot_b: usize,
    ctx: usize,
) -> Result<Option<Arc<Vec<f32>>>> {
    debug_assert_eq!(inputs.len(), KV_INPUT_AT);
    match cache {
        KvCache::Raw(..) => {
            let placeholder = KvCache::Raw(HostTensor::empty(), HostTensor::empty());
            let (kc, vc) = match std::mem::replace(cache, placeholder) {
                KvCache::Raw(k, v) => (k, v),
                KvCache::Packed(_) => unreachable!("matched Raw above"),
            };
            inputs.push(kc);
            inputs.push(vc);
            Ok(None)
        }
        KvCache::Packed(p) => {
            let ring = ring.ok_or_else(|| anyhow!("packed kv cache but no ring (kv mode Raw)"))?;
            let (h, hd) = (p.h(), p.hd());
            let n = slot_b * h * ctx * hd;
            let half = ring.half();
            anyhow::ensure!(n <= half, "kv ring too small: {n} > {half}");
            let mut buf = ring.acquire(blk);
            let materialized = {
                let data = Arc::get_mut(&mut buf).expect("acquired ring buffer is exclusive");
                let (dk, dv) = data.split_at_mut(half);
                p.materialize_into(&mut dk[..n], &mut dv[..n], 0, slot_b, ctx, scratch)
            };
            if let Err(e) = materialized {
                ring.release(blk, &buf);
                return Err(anyhow!("kv materialize (block {blk}): {e}"));
            }
            let dims = [slot_b, h, ctx, hd];
            inputs.push(HostTensor::f32_view(Arc::clone(&buf), 0, n, &dims));
            inputs.push(HostTensor::f32_view(Arc::clone(&buf), half, n, &dims));
            Ok(Some(buf))
        }
    }
}

/// Undo `attach_kv` after a failed executor call so fault replay finds
/// the caches it started with: the raw pair moves back out of the
/// input vector; a ring buffer goes home to its slot.
fn restore_kv_after_error(
    cache: &mut KvCache,
    inputs: &mut Vec<HostTensor>,
    ring: Option<&KvRing>,
    blk: usize,
    ring_buf: Option<Arc<Vec<f32>>>,
) {
    if let Some(buf) = ring_buf {
        if let Some(r) = ring {
            r.release(blk, &buf);
        }
        return;
    }
    if inputs.len() < KV_INPUT_AT + 2 {
        return; // attach never ran; nothing was moved
    }
    let mut pair = inputs.drain(KV_INPUT_AT..KV_INPUT_AT + 2);
    if let (Some(kc), Some(vc)) = (pair.next(), pair.next()) {
        drop(pair);
        *cache = KvCache::Raw(kc, vc);
    }
}

/// Fold one block's executor outputs back into its cache: `Raw`
/// replaces the owned tensors; `Packed` extracts and commits only row
/// `pos` (appending, or overwriting verbatim on a replayed step).
// entlint: hot
fn commit_kv(
    cache: &mut KvCache,
    k_new: HostTensor,
    v_new: HostTensor,
    pos: usize,
    slot_b: usize,
    ctx: usize,
    scratch: &mut KvScratch,
) -> Result<()> {
    match cache {
        KvCache::Raw(k, v) => {
            *k = k_new;
            *v = v_new;
            Ok(())
        }
        KvCache::Packed(p) => p
            .commit_from_outputs(k_new.as_f32(), v_new.as_f32(), 0, slot_b, ctx, pos, scratch)
            .map_err(|e| anyhow!("kv commit at pos {pos}: {e}")),
    }
}

/// Pack prefill caches [B,H,S,hd] into the window+tail layout with
/// rows `0..s` committed per lane — the packed analogue of
/// `expand_prefill_caches` (positions past `s` simply don't exist yet;
/// decode steps append them).
pub(crate) fn pack_prefill_caches(
    prefill: &[(HostTensor, HostTensor)],
    b: usize,
    h: usize,
    hd: usize,
    s: usize,
    ctx: usize,
    fmt: super::kv::TailFmt,
    window: usize,
) -> Vec<KvCache> {
    let mut row_k = vec![0.0f32; h * hd];
    let mut row_v = vec![0.0f32; h * hd];
    prefill
        .iter()
        .map(|(k, v)| {
            let (kf, vf) = (k.as_f32(), v.as_f32());
            let mut p = PackedKv::new(fmt, window, h, hd, ctx, b);
            for pos in 0..s {
                for lane in 0..b {
                    for head in 0..h {
                        let so = ((lane * h + head) * s + pos) * hd;
                        row_k[head * hd..head * hd + hd].copy_from_slice(&kf[so..so + hd]);
                        row_v[head * hd..head * hd + hd].copy_from_slice(&vf[so..so + hd]);
                    }
                    p.commit_row(lane, pos, &row_k, &row_v)
                        .expect("in-order prefill rows are always in-contract");
                }
            }
            KvCache::Packed(Box::new(p))
        })
        .collect()
}

/// A zero-copy `HostTensor` view over a container's shared matrix.
fn shared_view(m: &SharedMat) -> HostTensor {
    HostTensor::f32_view(Arc::clone(&m.data), 0, m.rows * m.cols, &[m.rows, m.cols])
}

/// Role-gated views over the container's shared tensors: (embed, head,
/// norm_final).  Each is an Arc bump into the single shared storage;
/// middle shards (`first == last == false`) materialize none.
fn build_role_views(
    cm: &CompressedModel,
    role: ShardRole,
) -> (Option<HostTensor>, Option<HostTensor>, Option<HostTensor>) {
    let embed = role.first.then(|| shared_view(&cm.embed));
    let head = role.last.then(|| shared_view(&cm.head));
    let norm_final = role.last.then(|| {
        HostTensor::f32_view(
            Arc::clone(&cm.norm_final),
            0,
            cm.norm_final.len(),
            &[cm.norm_final.len()],
        )
    });
    (embed, head, norm_final)
}

/// Per-block constant tensors (scales + norms) for every block of
/// `cm` — engine construction and the full-reopen path.
fn build_consts(cm: &CompressedModel) -> Vec<BlockConsts> {
    build_consts_range(cm, 0..cm.blocks.len())
}

/// Per-block constant tensors for a sub-range of `cm`'s blocks — the
/// splice path builds consts for the absorbed range only.
fn build_consts_range(cm: &CompressedModel, range: std::ops::Range<usize>) -> Vec<BlockConsts> {
    let mut consts = Vec::with_capacity(range.len());
    for cb in &cm.blocks[range] {
        // view, not clone: every shard's consts alias the container's
        // Arc-backed scale vectors — the last weight-derived per-shard
        // copies (the `weight_copies == 1` tests pin the sharing)
        let scales = cb
            .layers
            .iter()
            .map(|l| HostTensor::f32_view(Arc::clone(&l.scales), 0, l.scales.len(), &[l.rows]))
            .collect();
        consts.push(BlockConsts {
            scales,
            norm_attn: HostTensor::f32(cb.norm_attn.clone(), &[cb.norm_attn.len()]),
            norm_mlp: HostTensor::f32(cb.norm_mlp.clone(), &[cb.norm_mlp.len()]),
        });
    }
    consts
}

/// The EntQuant double-buffer arena, sized to the largest block of
/// `cm`; `None` for every other residency mode.
fn build_arena(cm: &CompressedModel, opts: &EngineOpts) -> Option<DecodeArena> {
    match opts.residency {
        Residency::EntQuant => Some(DecodeArena::new(
            cm.blocks.iter().map(|b| b.n_symbols()).max().unwrap_or(0),
        )),
        _ => None,
    }
}

/// The resolved disk-offload directory for `opts` (the default mirrors
/// the historic temp-dir fallback).  Shared with `serve::shard`'s
/// per-shard directory derivation so the fallback can never drift.
pub(crate) fn resolve_offload_dir(opts: &EngineOpts) -> String {
    opts.offload_dir.clone().unwrap_or_else(|| {
        std::env::temp_dir().join("eq_offload").to_string_lossy().into_owned()
    })
}

/// Load-time residency data for `cm` under `opts`: resident code
/// tensors (Bf16/F8 modes) or disk-offload files written into
/// `offload_dir` (DiskOffload), decoded fresh without an arena.  The
/// returned count is how many blocks were ANS-decoded (the splice
/// tests compare it against the absorbed-range size).  Shared by
/// engine construction and the full-reopen path; the splice path
/// decodes its absorbed range inline instead.
fn build_residency(
    cm: &CompressedModel,
    opts: &EngineOpts,
    value_table: &[f32; 256],
    threads: usize,
    offload_dir: String,
) -> Result<(Option<Vec<Vec<HostTensor>>>, Vec<String>, usize)> {
    match opts.residency {
        Residency::Bf16Resident | Residency::F8Resident => {
            let mut all = Vec::with_capacity(cm.blocks.len());
            for b in 0..cm.blocks.len() {
                let codes =
                    decode_codes(cm, value_table, None, b, threads).map_err(|e| anyhow!(e))?;
                all.push(codes);
            }
            let n = all.len();
            Ok((Some(all), Vec::new(), n))
        }
        Residency::DiskOffload => {
            let dir = offload_dir;
            std::fs::create_dir_all(&dir)?;
            let mut paths = Vec::with_capacity(cm.blocks.len());
            for b in 0..cm.blocks.len() {
                paths.push(write_offload_block(cm, b, value_table, threads, &dir)?);
            }
            let n = paths.len();
            Ok((None, paths, n))
        }
        Residency::EntQuant => Ok((None, Vec::new(), 0)),
    }
}

/// Decode block `b` of `cm` and write its f32 codes as an offload file
/// under `dir`, returning the path — one block's worth of the
/// DiskOffload load-time work, shared by construction and the splice.
fn write_offload_block(
    cm: &CompressedModel,
    b: usize,
    value_table: &[f32; 256],
    threads: usize,
    dir: &str,
) -> Result<String> {
    let codes = decode_codes(cm, value_table, None, b, threads).map_err(|e| anyhow!(e))?;
    let path = format!("{dir}/block_{b}.f32");
    let mut bytes = Vec::new();
    for t in &codes {
        for &v in t.as_f32() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(&path, bytes)?;
    Ok(path)
}

/// ANS-decode one block of `cm` straight to f32 code tensors — the
/// fused bitstream->LUT path, with no intermediate block-sized symbol
/// buffer.  With an arena the block buffer comes from the double-buffer
/// slots and the per-layer tensors are zero-copy views into it; without
/// (load-time resident/offload decode) a fresh exactly-sized buffer
/// backs the views.  Free function (not a method) so the decode-ahead
/// worker can run it without capturing `&ServingEngine` (whose
/// executable cache is a single-threaded `RefCell`).
// entlint: hot
fn decode_codes(
    cm: &CompressedModel,
    value_table: &[f32; 256],
    arena: Option<&DecodeArena>,
    b: usize,
    threads: usize,
// entlint: allow(hot-path-alloc-free) — cold error branch (bad block index)
) -> std::result::Result<Vec<HostTensor>, String> {
    let cb = cm.blocks.get(b).ok_or_else(|| format!("block {b} out of range"))?;
    let n = cb.n_symbols();
    let mut buf = match arena {
        // entlint: allow(hot-path-alloc-free) — non-arena fallback (load-time resident / offload decode); the serving arena path never takes this branch, pinned by decode_arena_fresh_allocs == 0
        Some(a) => a.acquire(b),
        None => Arc::new(vec![0.0f32; n]),
    };
    // exclusive by construction: acquire() only hands out buffers whose
    // previous views have all been dropped (or a fresh allocation)
    let dst = Arc::get_mut(&mut buf).expect("arena buffer is exclusively held");
    // entlint: allow(hot-path-alloc-free) — cold error branch (arena buffer too small)
    let decoded = if dst.len() < n {
        Err(format!("arena buffer holds {} f32s, block {b} needs {n}", dst.len()))
    } else {
        // entlint: allow(hot-path-alloc-free) — cold error branch (decode failure formatting)
        cm.decode_block_fused_into(b, &mut dst[..n], value_table, threads)
            .map_err(|e| format!("{e:#}"))
    };
    // release on every path so an error never strands the slot empty
    if let Some(a) = arena {
        a.release(b, &buf);
    }
    // entlint: allow(hot-path-alloc-free) — per-block views vector, bounded by layers.len() (7 views); the block-sized symbol buffer is what the arena eliminates
    decoded?;
    let mut out = Vec::with_capacity(cb.layers.len());
    for ((off, len), l) in cb.layer_offsets().into_iter().zip(&cb.layers) {
        out.push(HostTensor::f32_view(Arc::clone(&buf), off, len, &[l.rows, l.cols]));
    }
    Ok(out)
}

/// Parse one block's disk-offloaded f32 codes.  The file length is
/// checked once against the block's symbol count — a truncated or
/// padded offload file is an `Err`, not a slice panic — and each layer
/// decodes in bulk via `chunks_exact` instead of per-element indexing.
fn parse_offload_codes(
    bytes: &[u8],
    cb: &CompressedBlock,
) -> std::result::Result<Vec<HostTensor>, String> {
    let want = cb
        .n_symbols()
        .checked_mul(4)
        .ok_or_else(|| "block byte size overflows".to_string())?;
    if bytes.len() != want {
        return Err(format!("{} bytes, want {want} (truncated or corrupt)", bytes.len()));
    }
    let mut out = Vec::with_capacity(cb.layers.len());
    let mut off = 0usize;
    for l in &cb.layers {
        let n = l.rows * l.cols;
        let data: Vec<f32> = bytes[off..off + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        off += 4 * n;
        out.push(HostTensor::f32(data, &[l.rows, l.cols]));
    }
    Ok(out)
}

pub(crate) fn argmax(x: &[f32]) -> usize {
    let mut best = 0usize;
    for i in 1..x.len() {
        if x[i] > x[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{pack, Request};
    use crate::coordinator::kv::{KvMode, TailFmt};
    use crate::model::loader::synthetic_model;
    use crate::model::Config;
    use crate::runtime::Manifest;
    use crate::store::pipeline::{compress_model, CompressOpts};

    fn tiny_compressed() -> CompressedModel {
        let m = synthetic_model(
            Config {
                name: "T".into(),
                vocab: 64,
                d_model: 16,
                n_layers: 3,
                n_heads: 2,
                d_ff: 24,
                max_ctx: 32,
            },
            23,
        );
        compress_model(&m, &CompressOpts { lam: 0.3, ..Default::default() }).unwrap().0
    }

    /// Native-executor runtime over the tiny model's config: prefill
    /// seq 16, decode ctx 24, batch sizes 1/2/4.
    fn native_rt(cm: &CompressedModel) -> Runtime {
        Runtime::native(Manifest::synthetic(
            cm.config.clone(),
            vec![(1, 16), (2, 16), (4, 16)],
            vec![(1, 24), (2, 24), (4, 24)],
        ))
    }

    fn native_engine() -> ServingEngine {
        let cm = tiny_compressed();
        let rt = native_rt(&cm);
        ServingEngine::new(rt, cm, EngineOpts::default()).unwrap()
    }

    /// Prompt bytes stay inside the tiny model's vocab (64).
    fn req(id: u64, len: usize) -> Request {
        Request {
            id,
            prompt: (0..len).map(|i| ((id as usize * 11 + i * 7) % 64) as u8).collect(),
            max_new_tokens: 8,
        }
    }

    #[test]
    fn arena_decode_matches_owned_and_is_alloc_free() {
        let cm = tiny_compressed();
        let lut = cm.fmt.value_table();
        let max = cm.blocks.iter().map(|b| b.n_symbols()).max().unwrap();
        let arena = DecodeArena::new(max);
        // two consecutive passes over all blocks = two generate steps;
        // views drop at the end of each block, like the forward does
        for pass in 0..2 {
            for b in 0..cm.blocks.len() {
                let owned = decode_codes(&cm, &lut, None, b, 1).unwrap();
                let view = decode_codes(&cm, &lut, Some(&arena), b, 2).unwrap();
                assert_eq!(owned.len(), view.len());
                for (o, v) in owned.iter().zip(&view) {
                    assert_eq!(o.as_f32(), v.as_f32(), "pass={pass} block={b}");
                    assert_eq!(o.dims(), v.dims());
                }
            }
        }
        assert_eq!(arena.fresh_allocs(), 0, "steady-state decode must reuse the arena");
    }

    #[test]
    fn arena_survives_held_views_with_counted_fallback() {
        let cm = tiny_compressed();
        let lut = cm.fmt.value_table();
        let max = cm.blocks.iter().map(|b| b.n_symbols()).max().unwrap();
        let arena = DecodeArena::new(max);
        // hold block 0's views across its slot's next turn: the arena
        // must fall back to a fresh buffer (counted), never clobber
        let held = decode_codes(&cm, &lut, Some(&arena), 0, 1).unwrap();
        let snapshot: Vec<Vec<f32>> = held.iter().map(|t| t.as_f32().to_vec()).collect();
        let again = decode_codes(&cm, &lut, Some(&arena), 0, 1).unwrap();
        assert_eq!(arena.fresh_allocs(), 1);
        for ((h, s), a) in held.iter().zip(&snapshot).zip(&again) {
            assert_eq!(h.as_f32(), &s[..], "held view was clobbered");
            assert_eq!(h.as_f32(), a.as_f32());
        }
    }

    #[test]
    fn offload_parse_rejects_truncated_and_padded_files() {
        let cm = tiny_compressed();
        let cb = &cm.blocks[0];
        let lut = cm.fmt.value_table();
        let codes = decode_codes(&cm, &lut, None, 0, 1).unwrap();
        let mut bytes = Vec::new();
        for t in &codes {
            for &v in t.as_f32() {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        let parsed = parse_offload_codes(&bytes, cb).unwrap();
        for (p, c) in parsed.iter().zip(&codes) {
            assert_eq!(p.as_f32(), c.as_f32());
        }
        assert!(parse_offload_codes(&bytes[..bytes.len() - 1], cb).is_err());
        assert!(parse_offload_codes(&bytes[..4], cb).is_err());
        let mut padded = bytes.clone();
        padded.extend_from_slice(&[0u8; 4]);
        assert!(parse_offload_codes(&padded, cb).is_err());
    }

    #[test]
    fn native_generate_is_deterministic_and_alloc_free() {
        let engine = native_engine();
        let reqs = [req(0, 10), req(1, 5)];
        let batch = &pack(&reqs, &[(2, 16)])[0];
        let (o1, m) = engine.generate(batch, 6).unwrap();
        let (o2, _) = engine.generate(batch, 6).unwrap();
        assert_eq!(o1, o2, "repeated generate must be byte-identical");
        assert_eq!(o1.len(), 2);
        assert!(o1.iter().all(|o| o.len() == 6), "{:?}", o1);
        assert_eq!(m.decode_tokens, 5);
        assert!(m.ttft_ms > 0.0);
        assert_eq!(engine.decode_arena_fresh_allocs(), 0, "arena must absorb all decodes");
    }

    #[test]
    fn step_api_matches_generate_and_stops_at_ctx() {
        let engine = native_engine();
        let batch = &pack(&[req(2, 8)], &[(1, 16)])[0];
        let (want, _) = engine.generate(batch, 6).unwrap();
        let mut st = engine.prefill_state(batch).unwrap();
        for _ in 0..5 {
            assert!(engine.decode_step(&mut st).unwrap());
        }
        assert_eq!(st.outputs[0], want[0], "step API must reproduce generate");
        // drive to the context wall: 24 - 16 - 5 = 3 more steps, then false
        for _ in 0..3 {
            assert!(engine.decode_step(&mut st).unwrap());
        }
        assert!(!engine.decode_step(&mut st).unwrap(), "ctx exhausted");
        assert_eq!(st.outputs[0].len(), 1 + 8);
        // generate with a huge budget hits the same wall
        let (capped, _) = engine.generate(batch, 1000).unwrap();
        assert_eq!(capped[0], st.outputs[0]);
    }

    #[test]
    fn adopt_lane_matches_joint_prefill() {
        let engine = native_engine();
        let (r0, r1) = (req(3, 9), req(4, 12));
        // reference: both lanes prefilled together
        let joint = &pack(&[r0.clone(), r1.clone()], &[(2, 16)])[0];
        let (want, _) = engine.generate(joint, 7).unwrap();
        // adopted: r0 starts alone in the 2-slot, r1 arrives solo and
        // is grafted into lane 1 before any step runs
        let main_batch = &pack(&[r0], &[(2, 16)])[0];
        let mut main = engine.prefill_state(main_batch).unwrap();
        let solo_batch = &pack(&[r1], &[(1, 16)])[0];
        let solo = engine.prefill_state(solo_batch).unwrap();
        main.adopt_lane(solo, 1).unwrap();
        for _ in 0..6 {
            assert!(engine.decode_step(&mut main).unwrap());
        }
        assert_eq!(main.outputs[0], want[0], "resident lane perturbed by adoption");
        assert_eq!(main.outputs[1], want[1], "adopted lane diverged from joint prefill");
    }

    #[test]
    fn adopt_lane_rejects_misaligned_positions() {
        let engine = native_engine();
        let mut main = engine.prefill_state(&pack(&[req(5, 6)], &[(2, 16)])[0]).unwrap();
        let mut solo = engine.prefill_state(&pack(&[req(6, 6)], &[(1, 16)])[0]).unwrap();
        engine.decode_step(&mut solo).unwrap(); // solo now one step ahead
        assert!(main.adopt_lane(solo, 1).is_err());
    }

    #[test]
    fn compact_preserves_trajectories() {
        let engine = native_engine();
        let reqs = [req(7, 10), req(8, 4)];
        let joint = &pack(&reqs, &[(4, 16)])[0];
        let (want, _) = engine.generate(joint, 7).unwrap();
        let mut st = engine.prefill_state(joint).unwrap();
        for _ in 0..2 {
            engine.decode_step(&mut st).unwrap();
        }
        // drop to the 2-slot mid-flight; trajectories must continue
        let mut small = st.compact(&[0, 1], (2, 16), engine.decode_ctx(2).unwrap()).unwrap();
        for _ in 0..4 {
            engine.decode_step(&mut small).unwrap();
        }
        assert_eq!(small.outputs[0], want[0]);
        assert_eq!(small.outputs[1], want[1]);
        // kept-lane reordering works too (lane 1 alone)
        let mut one = st.compact(&[1], (1, 16), engine.decode_ctx(1).unwrap()).unwrap();
        for _ in 0..4 {
            engine.decode_step(&mut one).unwrap();
        }
        assert_eq!(one.outputs[0], want[1]);
    }

    /// Engine over the same tiny model with a packed-KV config.
    fn native_engine_kv(kv: KvCfg) -> ServingEngine {
        let cm = tiny_compressed();
        let rt = native_rt(&cm);
        ServingEngine::new(rt, cm, EngineOpts { kv, ..Default::default() }).unwrap()
    }

    #[test]
    fn lossless_tail_is_byte_identical_to_raw() {
        let raw = native_engine();
        let batch = &pack(&[req(3, 9), req(4, 12)], &[(2, 16)])[0];
        let (want, _) = raw.generate(batch, 7).unwrap();
        let kv = native_engine_kv(KvCfg { mode: KvMode::LosslessTail, window: 2 });
        let (got, _) = kv.generate(batch, 7).unwrap();
        assert_eq!(got, want, "lossless tail must not change a single token");
        assert_eq!(kv.kv_fresh_allocs(), 0, "packed decode must stay on the ring");
    }

    #[test]
    fn quant_tail_modes_run_deterministically_with_surgery() {
        for fmt in [TailFmt::F8, TailFmt::Bf16] {
            let engine =
                native_engine_kv(KvCfg { mode: KvMode::QuantTail(fmt), window: 2 });
            let (r0, r1) = (req(3, 9), req(4, 12));
            let joint = &pack(&[r0.clone(), r1.clone()], &[(2, 16)])[0];
            let (want, _) = engine.generate(joint, 7).unwrap();
            let (again, _) = engine.generate(joint, 7).unwrap();
            assert_eq!(want, again, "{fmt:?}: repeated runs must agree");

            // lane surgery on packed caches: a solo-prefilled lane has a
            // byte-identical packed stream to the joint-prefilled one
            // (prefill is lane-independent and chunk/window boundaries
            // are pure functions of len), so adoption must reproduce
            // the joint trajectory exactly.
            let mut main = engine.prefill_state(&pack(&[r0.clone()], &[(2, 16)])[0]).unwrap();
            let solo = engine.prefill_state(&pack(&[r1.clone()], &[(1, 16)])[0]).unwrap();
            main.adopt_lane(solo, 1).unwrap();
            for _ in 0..6 {
                assert!(engine.decode_step(&mut main).unwrap());
            }
            assert_eq!(main.outputs[0], want[0], "{fmt:?}: resident lane perturbed");
            assert_eq!(main.outputs[1], want[1], "{fmt:?}: adopted lane diverged");

            // compact mid-flight: packed lanes re-seat into the smaller
            // slot with their sealed chunks and windows intact
            let wide = &pack(&[r0, r1], &[(4, 16)])[0];
            let (wide_want, _) = engine.generate(wide, 7).unwrap();
            let mut st = engine.prefill_state(wide).unwrap();
            for _ in 0..2 {
                engine.decode_step(&mut st).unwrap();
            }
            let bytes = st.kv_bytes();
            assert!(
                bytes.resident < bytes.raw,
                "{fmt:?}: quantized tail must shrink the cache ({} vs {})",
                bytes.resident,
                bytes.raw
            );
            let mut small =
                st.compact(&[0, 1], (2, 16), engine.decode_ctx(2).unwrap()).unwrap();
            for _ in 0..4 {
                engine.decode_step(&mut small).unwrap();
            }
            assert_eq!(small.outputs[0], wide_want[0], "{fmt:?}: compact lane 0");
            assert_eq!(small.outputs[1], wide_want[1], "{fmt:?}: compact lane 1");
            assert_eq!(engine.kv_fresh_allocs(), 0, "{fmt:?}: ring must absorb decode");
        }
    }

    #[test]
    fn middle_role_engine_refuses_embed_and_head() {
        let cm = tiny_compressed();
        let rt = native_rt(&cm);
        let opts =
            EngineOpts { role: ShardRole { first: false, last: false }, ..Default::default() };
        let engine = ServingEngine::new(rt, cm, opts).unwrap();
        let batch = &pack(&[req(9, 6)], &[(1, 16)])[0];
        let Err(e) = engine.prefill_state(batch) else {
            panic!("a middle shard must not embed");
        };
        assert!(format!("{e:#}").contains("embed role"), "{e:#}");
    }

    #[test]
    fn role_promotion_restores_the_full_pipeline() {
        // a middle-role engine promoted to first+last serves exactly
        // like a from-birth full engine — promotion is an Arc bump over
        // the container's shared tensors, so nothing can drift
        let cm = tiny_compressed();
        let rt = native_rt(&cm);
        let opts =
            EngineOpts { role: ShardRole { first: false, last: false }, ..Default::default() };
        let mut engine = ServingEngine::new(rt, cm, opts).unwrap();
        engine.set_role(ShardRole::default());
        let batch = &pack(&[req(1, 8)], &[(1, 16)])[0];
        let (got, _) = engine.generate(batch, 6).unwrap();
        let (want, _) = native_engine().generate(batch, 6).unwrap();
        assert_eq!(got, want, "promoted engine diverged from a full-role engine");
    }

    #[test]
    fn zero_token_metrics_are_zero_not_nan() {
        let m = Metrics {
            prefill_ms: 1.0,
            decode_ms: 0.0,
            decode_tokens: 0,
            ans_decode_ms: 0.0,
            exec_ms: 0.0,
            ttft_ms: 1.0,
        };
        assert_eq!(m.tokens_per_s_decode(4), 0.0);
        // tokens but an (impossible) zero duration must not be inf
        let m2 = Metrics { decode_tokens: 10, ..m };
        assert_eq!(m2.tokens_per_s_decode(4), 0.0);
    }

    #[test]
    fn nonzero_metrics_compute_rate() {
        let m = Metrics {
            prefill_ms: 0.0,
            decode_ms: 500.0,
            decode_tokens: 50,
            ans_decode_ms: 0.0,
            exec_ms: 0.0,
            ttft_ms: 0.0,
        };
        assert!((m.tokens_per_s_decode(2) - 200.0).abs() < 1e-9);
    }
}

