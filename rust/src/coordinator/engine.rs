//! The serving engine — paper Algorithm 2 embedded in a block-wise
//! decode-ahead pipeline (§A.1):
//!
//!   weights live in memory as per-block ANS bitstreams; a decoder
//!   thread inflates block i+1's symbols into one of two reusable code
//!   buffers while the PJRT executable runs block i.  Individual layers
//!   are views into the block buffer (no copies).  After the block's
//!   forward completes the buffer is recycled — exactly the paper's
//!   double-buffer scheme, with a thread standing in for the GPU's
//!   async decompression stream.
//!
//! Weight residency modes (Figure 5's comparison set):
//!   * Bf16Resident — all weights dequantized f32 and resident (baseline)
//!   * F8Resident   — codes+scales resident, no ANS on the hot path
//!                    (the paper's "Float8" Marlin row)
//!   * EntQuant     — bitstreams resident, ANS decode on the fly
//!   * DiskOffload  — weights read from disk per block (the paper's
//!                    "CPU offload" reference point)

use super::batcher::Batch;

use crate::runtime::{HostTensor, Runtime};
use crate::store::container::{CompressedBlock, CompressedModel};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    Bf16Resident,
    F8Resident,
    EntQuant,
    DiskOffload,
}

/// The double-buffer arena the §A.1 pipeline promises: two preallocated
/// block-sized f32 code buffers (sized to the largest block), recycled
/// across blocks and across decode steps, so steady-state token
/// generation performs no block-sized decode-buffer allocations (small
/// per-view metadata — dims vectors, the per-block view list — is the
/// only remaining heap traffic).  Buffers hand
/// out as `Arc`s: per-layer `HostTensor` views alias the block buffer,
/// and a slot is reclaimable (strong count back to 1) once the block's
/// forward has dropped its inputs — with the one-ahead pipeline that is
/// always true by the time the slot's turn comes round again, two
/// blocks later.
struct DecodeArena {
    slots: [Mutex<Option<Arc<Vec<f32>>>>; 2],
    max_symbols: usize,
    /// Fresh allocations forced by a still-referenced slot: 0 in steady
    /// state (the alloc-free tests pin this).
    fresh_allocs: AtomicUsize,
}

impl DecodeArena {
    fn new(max_symbols: usize) -> Self {
        DecodeArena {
            slots: [
                Mutex::new(Some(Arc::new(vec![0.0; max_symbols]))),
                Mutex::new(Some(Arc::new(vec![0.0; max_symbols]))),
            ],
            max_symbols,
            fresh_allocs: AtomicUsize::new(0),
        }
    }

    /// Check block `b`'s buffer out of its slot for exclusive decode
    /// use; falls back to a fresh (counted) allocation if the slot's
    /// previous tenant still has live views.
    fn acquire(&self, b: usize) -> Arc<Vec<f32>> {
        if let Some(mut arc) = self.slots[b & 1].lock().unwrap().take() {
            if Arc::get_mut(&mut arc).is_some() {
                return arc;
            }
        }
        self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        Arc::new(vec![0.0; self.max_symbols])
    }

    /// Return a buffer to its slot so the next `acquire` two blocks
    /// later can recycle it.
    fn release(&self, b: usize, buf: &Arc<Vec<f32>>) {
        *self.slots[b & 1].lock().unwrap() = Some(Arc::clone(buf));
    }

    fn fresh_allocs(&self) -> usize {
        self.fresh_allocs.load(Ordering::Relaxed)
    }
}

/// Precomputed per-block constant tensors (scales + norms).
struct BlockConsts {
    scales: Vec<HostTensor>,
    norm_attn: HostTensor,
    norm_mlp: HostTensor,
}

pub struct EngineOpts {
    pub residency: Residency,
    /// overlap ANS decode of block i+1 with compute of block i
    pub pipeline: bool,
    pub decode_threads: usize,
    /// scratch dir for DiskOffload mode
    pub offload_dir: Option<String>,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { residency: Residency::EntQuant, pipeline: true, decode_threads: 1, offload_dir: None }
    }
}

pub struct Metrics {
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub decode_tokens: usize,
    pub ans_decode_ms: f64,
    pub exec_ms: f64,
    pub ttft_ms: f64,
}

impl Metrics {
    /// Decode throughput; 0.0 for zero-token or zero-duration runs
    /// (instead of NaN/inf from the naive division).
    pub fn tokens_per_s_decode(&self, batch: usize) -> f64 {
        let tokens = (self.decode_tokens * batch) as f64;
        if tokens <= 0.0 || self.decode_ms <= 0.0 {
            return 0.0;
        }
        tokens / (self.decode_ms / 1e3)
    }
}

pub struct ServingEngine {
    rt: Runtime,
    cm: Arc<CompressedModel>,
    consts: Vec<BlockConsts>,
    embed: HostTensor,
    head: HostTensor,
    norm_final: HostTensor,
    /// resident code tensors (F8Resident / Bf16Resident modes)
    resident_codes: Option<Vec<Vec<HostTensor>>>,
    /// double-buffer code arena (EntQuant mode only)
    arena: Option<DecodeArena>,
    opts: EngineOpts,
    value_table: [f32; 256],
    offload_paths: Vec<String>,
}

impl ServingEngine {
    pub fn new(rt: Runtime, cm: CompressedModel, opts: EngineOpts) -> Result<Self> {
        let cfg = &rt.manifest.config;
        anyhow::ensure!(
            cm.config.d_model == cfg.d_model && cm.config.n_layers == cfg.n_layers,
            "compressed model does not match serving artifacts ({} vs {})",
            cm.config.name,
            cfg.name
        );
        let value_table = cm.fmt.value_table();
        let mut consts = Vec::with_capacity(cm.blocks.len());
        for cb in &cm.blocks {
            let scales = cb
                .layers
                .iter()
                .map(|l| HostTensor::f32(l.scales.clone(), &[l.rows]))
                .collect();
            consts.push(BlockConsts {
                scales,
                norm_attn: HostTensor::f32(cb.norm_attn.clone(), &[cb.norm_attn.len()]),
                norm_mlp: HostTensor::f32(cb.norm_mlp.clone(), &[cb.norm_mlp.len()]),
            });
        }
        let embed = HostTensor::f32(cm.embed.data.clone(), &[cm.embed.rows, cm.embed.cols]);
        let head = HostTensor::f32(cm.head.data.clone(), &[cm.head.rows, cm.head.cols]);
        let norm_final = HostTensor::f32(cm.norm_final.clone(), &[cm.norm_final.len()]);

        // §A.1 double buffering: EntQuant serving recycles two
        // block-sized code buffers across blocks and decode steps
        let arena = match opts.residency {
            Residency::EntQuant => Some(DecodeArena::new(
                cm.blocks.iter().map(|b| b.n_symbols()).max().unwrap_or(0),
            )),
            _ => None,
        };
        let cm = Arc::new(cm);
        let mut engine = ServingEngine {
            rt,
            cm,
            consts,
            embed,
            head,
            norm_final,
            resident_codes: None,
            arena,
            opts,
            value_table,
            offload_paths: Vec::new(),
        };
        match engine.opts.residency {
            Residency::Bf16Resident | Residency::F8Resident => {
                // decode once at load time; codes stay resident
                let mut all = Vec::new();
                for b in 0..engine.cm.blocks.len() {
                    all.push(engine.decode_block_codes(b)?);
                }
                engine.resident_codes = Some(all);
            }
            Residency::DiskOffload => {
                let dir = engine
                    .opts
                    .offload_dir
                    .clone()
                    .unwrap_or_else(|| std::env::temp_dir().join("eq_offload").to_string_lossy().into_owned());
                std::fs::create_dir_all(&dir)?;
                for b in 0..engine.cm.blocks.len() {
                    let codes = engine.decode_block_codes(b)?;
                    let path = format!("{dir}/block_{b}.f32");
                    let mut bytes = Vec::new();
                    for t in &codes {
                        for &v in t.as_f32() {
                            bytes.extend_from_slice(&v.to_le_bytes());
                        }
                    }
                    std::fs::write(&path, bytes)?;
                    engine.offload_paths.push(path);
                }
            }
            Residency::EntQuant => {}
        }
        Ok(engine)
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn compressed(&self) -> &CompressedModel {
        &self.cm
    }

    /// ANS-decode one block straight to f32 code tensors (fused path);
    /// EntQuant serving routes through the double-buffer arena, the
    /// load-time resident/offload decodes allocate exactly-sized
    /// buffers.
    fn decode_block_codes(&self, b: usize) -> Result<Vec<HostTensor>> {
        decode_codes(&self.cm, &self.value_table, self.arena.as_ref(), b, self.opts.decode_threads)
            .map_err(|e| anyhow!(e))
    }

    fn offload_block_codes(&self, b: usize) -> Result<Vec<HostTensor>> {
        let path = self
            .offload_paths
            .get(b)
            .ok_or_else(|| anyhow!("no offload file for block {b}"))?;
        let bytes = std::fs::read(path)?;
        parse_offload_codes(&bytes, &self.cm.blocks[b])
            .map_err(|e| anyhow!("offload file {path}: {e}"))
    }

    /// Fresh decode-buffer allocations forced past the arena — 0 in
    /// steady state (the alloc-free serving tests pin this).
    pub fn decode_arena_fresh_allocs(&self) -> usize {
        self.arena.as_ref().map_or(0, DecodeArena::fresh_allocs)
    }

    /// Fetch block codes according to the residency mode.
    fn fetch_block(&self, b: usize) -> Result<(Vec<HostTensor>, f64)> {
        let t0 = std::time::Instant::now();
        let codes = match self.opts.residency {
            Residency::Bf16Resident | Residency::F8Resident => {
                self.resident_codes.as_ref().unwrap()[b].clone()
            }
            Residency::EntQuant => self.decode_block_codes(b)?,
            Residency::DiskOffload => self.offload_block_codes(b)?,
        };
        Ok((codes, t0.elapsed().as_secs_f64() * 1e3))
    }

    /// Run all blocks of one phase with the decode-ahead pipeline.
    /// `run_block(b, codes) -> Result<()>` mutates the caller's state.
    fn run_pipelined<F>(&self, ans_ms: &mut f64, mut run_block: F) -> Result<()>
    where
        F: FnMut(usize, &[HostTensor]) -> Result<()>,
    {
        let n = self.cm.blocks.len();
        if !self.opts.pipeline || self.opts.residency != Residency::EntQuant {
            for b in 0..n {
                let (codes, ms) = self.fetch_block(b)?;
                *ans_ms += ms;
                run_block(b, &codes)?;
            }
            return Ok(());
        }
        // decode-ahead (paper A.1 double buffering): the parallel
        // subsystem's one-ahead worker inflates block b+1's chunks
        // across `decode_threads` pool workers while the calling thread
        // executes block b
        let cm: &CompressedModel = &self.cm;
        let table = &self.value_table;
        let arena = self.arena.as_ref();
        let threads = self.opts.decode_threads;
        crate::parallel::decode_ahead(
            n,
            move |b| {
                let t0 = std::time::Instant::now();
                let codes = decode_codes(cm, table, arena, b, threads)?;
                Ok((codes, t0.elapsed().as_secs_f64() * 1e3))
            },
            |b, (codes, ms): (Vec<HostTensor>, f64)| {
                *ans_ms += ms; // decode wall (overlapped with prior exec)
                run_block(b, &codes).map_err(|e| format!("{e:#}"))
            },
        )
        .map_err(|e| anyhow!("decode pipeline: {e}"))
    }

    fn block_inputs(
        &self,
        b: usize,
        x: HostTensor,
        codes: &[HostTensor],
        extra: Vec<HostTensor>,
    ) -> Vec<HostTensor> {
        let mut inputs = Vec::with_capacity(1 + 7 + 7 + 2 + extra.len());
        inputs.push(x);
        inputs.extend(codes.iter().cloned());
        inputs.extend(self.consts[b].scales.iter().cloned());
        inputs.push(self.consts[b].norm_attn.clone());
        inputs.push(self.consts[b].norm_mlp.clone());
        inputs.extend(extra);
        inputs
    }

    /// Prefill one packed batch: returns (full logits [B,S,V], caches).
    pub fn prefill(&self, batch: &Batch, metrics: &mut Metrics) -> Result<(HostTensor, Vec<(HostTensor, HostTensor)>)> {
        let (b, s) = batch.slot;
        let cfg = &self.rt.manifest.config;
        let t0 = std::time::Instant::now();
        let tokens = HostTensor::i32(batch.tokens.iter().map(|&t| t as i32).collect(), &[b, s]);
        let starts = HostTensor::i32(batch.starts.clone(), &[b]);
        let mut x = self
            .rt
            .call(&format!("embed_p_b{b}_s{s}"), &[tokens, self.embed.clone()])?
            .remove(0);
        let mut caches: Vec<(HostTensor, HostTensor)> = Vec::with_capacity(cfg.n_layers);
        let exec_name = format!("block_p_b{b}_s{s}");
        let mut ans_ms = 0.0;
        self.run_pipelined(&mut ans_ms, |blk, codes| {
            let t1 = std::time::Instant::now();
            let inputs = self.block_inputs(blk, x.clone(), codes, vec![starts.clone()]);
            let mut out = self.rt.call(&exec_name, &inputs)?;
            x = out.remove(0);
            let k = out.remove(0);
            let v = out.remove(0);
            caches.push((k, v));
            metrics.exec_ms += t1.elapsed().as_secs_f64() * 1e3;
            Ok(())
        })?;
        metrics.ans_decode_ms += ans_ms;
        let logits = self
            .rt
            .call(&format!("head_p_b{b}_s{s}"), &[x, self.norm_final.clone(), self.head.clone()])?
            .remove(0);
        metrics.prefill_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok((logits, caches))
    }

    /// Greedy-generate `max_new` tokens for a packed batch.
    pub fn generate(&self, batch: &Batch, max_new: usize) -> Result<(Vec<Vec<u8>>, Metrics)> {
        let (b, s) = batch.slot;
        let cfg = &self.rt.manifest.config;
        let (_, ctx) = *self
            .rt
            .manifest
            .decode_slots
            .iter()
            .find(|(db, _)| *db == b)
            .ok_or_else(|| anyhow!("no decode slot for batch {b}"))?;
        let mut metrics = Metrics {
            prefill_ms: 0.0,
            decode_ms: 0.0,
            decode_tokens: 0,
            ans_decode_ms: 0.0,
            exec_ms: 0.0,
            ttft_ms: 0.0,
        };
        let t_start = std::time::Instant::now();
        let (logits, prefill_caches) = self.prefill(batch, &mut metrics)?;
        metrics.ttft_ms = t_start.elapsed().as_secs_f64() * 1e3;

        // expand prefill caches [B,H,S,hd] into decode caches [B,H,C,hd]
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let mut caches: Vec<(HostTensor, HostTensor)> = prefill_caches
            .into_iter()
            .map(|(k, v)| {
                let expand = |t: &HostTensor| {
                    let src = t.as_f32();
                    let mut dst = vec![0.0f32; b * h * ctx * hd];
                    for bi in 0..b {
                        for hi in 0..h {
                            for si in 0..s {
                                let so = ((bi * h + hi) * s + si) * hd;
                                let d0 = ((bi * h + hi) * ctx + si) * hd;
                                dst[d0..d0 + hd].copy_from_slice(&src[so..so + hd]);
                            }
                        }
                    }
                    HostTensor::f32(dst, &[b, h, ctx, hd])
                };
                (expand(&k), expand(&v))
            })
            .collect();

        // greedy pick from the last prefill position
        let vsize = cfg.vocab;
        let lf = logits.as_f32();
        let mut next: Vec<i32> = (0..b)
            .map(|bi| {
                let row = &lf[(bi * s + (s - 1)) * vsize..(bi * s + s) * vsize];
                argmax(row) as i32
            })
            .collect();
        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); batch.requests.len()];
        for (bi, o) in outputs.iter_mut().enumerate() {
            o.push(next[bi] as u8);
        }

        let starts = HostTensor::i32(batch.starts.clone(), &[b]);
        let embed_name = format!("embed_d_b{b}");
        let block_name = format!("block_d_b{b}_c{ctx}");
        let head_name = format!("head_d_b{b}");
        let t_dec = std::time::Instant::now();
        for step in 0..max_new.saturating_sub(1) {
            let pos = (s + step) as i32;
            if pos as usize >= ctx {
                break;
            }
            let toks = HostTensor::i32(next.clone(), &[b, 1]);
            let mut x = self.rt.call(&embed_name, &[toks, self.embed.clone()])?.remove(0);
            let mut ans_ms = 0.0;
            let caches_ref = &mut caches;
            let rt = &self.rt;
            let consts = &self.consts;
            {
                let x_cell = std::cell::RefCell::new(&mut x);
                self.run_pipelined(&mut ans_ms, |blk, codes| {
                    let t1 = std::time::Instant::now();
                    let (kc, vc) = caches_ref[blk].clone();
                    let mut inputs = Vec::with_capacity(21);
                    inputs.push((*x_cell.borrow()).clone());
                    inputs.extend(codes.iter().cloned());
                    inputs.extend(consts[blk].scales.iter().cloned());
                    inputs.push(consts[blk].norm_attn.clone());
                    inputs.push(consts[blk].norm_mlp.clone());
                    inputs.push(kc);
                    inputs.push(vc);
                    inputs.push(HostTensor::scalar_i32(pos));
                    inputs.push(starts.clone());
                    let mut out = rt.call(&block_name, &inputs)?;
                    **x_cell.borrow_mut() = out.remove(0);
                    caches_ref[blk] = (out.remove(0), out.remove(0));
                    metrics.exec_ms += t1.elapsed().as_secs_f64() * 1e3;
                    Ok(())
                })?;
            }
            metrics.ans_decode_ms += ans_ms;
            let logits = self
                .rt
                .call(&head_name, &[x, self.norm_final.clone(), self.head.clone()])?
                .remove(0);
            let lf = logits.as_f32();
            for bi in 0..b {
                next[bi] = argmax(&lf[bi * vsize..(bi + 1) * vsize]) as i32;
            }
            for (bi, o) in outputs.iter_mut().enumerate() {
                o.push(next[bi] as u8);
            }
            metrics.decode_tokens += 1;
        }
        metrics.decode_ms = t_dec.elapsed().as_secs_f64() * 1e3;
        Ok((outputs, metrics))
    }

    /// Approximate resident weight bytes for this residency mode (the
    /// Figure F.3 peak-memory series).
    pub fn resident_weight_bytes(&self) -> usize {
        let linear_f32: usize = self.cm.blocks.iter().map(|b| b.n_symbols() * 4).sum();
        let streams: usize = self.cm.blocks.iter().map(|b| b.bitstream.serialized_len()).sum();
        let buffer = self.cm.blocks.iter().map(|b| b.n_symbols() * 4).max().unwrap_or(0);
        match self.opts.residency {
            Residency::Bf16Resident | Residency::F8Resident => linear_f32,
            Residency::EntQuant => streams + 2 * buffer, // bitstreams + double buffer
            Residency::DiskOffload => buffer,
        }
    }
}

/// ANS-decode one block of `cm` straight to f32 code tensors — the
/// fused bitstream->LUT path, with no intermediate block-sized symbol
/// buffer.  With an arena the block buffer comes from the double-buffer
/// slots and the per-layer tensors are zero-copy views into it; without
/// (load-time resident/offload decode) a fresh exactly-sized buffer
/// backs the views.  Free function (not a method) so the decode-ahead
/// worker can run it without capturing `&ServingEngine` (whose
/// executable cache is a single-threaded `RefCell`).
fn decode_codes(
    cm: &CompressedModel,
    value_table: &[f32; 256],
    arena: Option<&DecodeArena>,
    b: usize,
    threads: usize,
) -> std::result::Result<Vec<HostTensor>, String> {
    let cb = cm.blocks.get(b).ok_or_else(|| format!("block {b} out of range"))?;
    let n = cb.n_symbols();
    let mut buf = match arena {
        Some(a) => a.acquire(b),
        None => Arc::new(vec![0.0f32; n]),
    };
    // exclusive by construction: acquire() only hands out buffers whose
    // previous views have all been dropped (or a fresh allocation)
    let dst = Arc::get_mut(&mut buf).expect("arena buffer is exclusively held");
    let decoded = if dst.len() < n {
        Err(format!("arena buffer holds {} f32s, block {b} needs {n}", dst.len()))
    } else {
        cm.decode_block_fused_into(b, &mut dst[..n], value_table, threads)
            .map_err(|e| format!("{e:#}"))
    };
    // release on every path so an error never strands the slot empty
    if let Some(a) = arena {
        a.release(b, &buf);
    }
    decoded?;
    let mut out = Vec::with_capacity(cb.layers.len());
    for ((off, len), l) in cb.layer_offsets().into_iter().zip(&cb.layers) {
        out.push(HostTensor::f32_view(Arc::clone(&buf), off, len, &[l.rows, l.cols]));
    }
    Ok(out)
}

/// Parse one block's disk-offloaded f32 codes.  The file length is
/// checked once against the block's symbol count — a truncated or
/// padded offload file is an `Err`, not a slice panic — and each layer
/// decodes in bulk via `chunks_exact` instead of per-element indexing.
fn parse_offload_codes(
    bytes: &[u8],
    cb: &CompressedBlock,
) -> std::result::Result<Vec<HostTensor>, String> {
    let want = cb
        .n_symbols()
        .checked_mul(4)
        .ok_or_else(|| "block byte size overflows".to_string())?;
    if bytes.len() != want {
        return Err(format!("{} bytes, want {want} (truncated or corrupt)", bytes.len()));
    }
    let mut out = Vec::with_capacity(cb.layers.len());
    let mut off = 0usize;
    for l in &cb.layers {
        let n = l.rows * l.cols;
        let data: Vec<f32> = bytes[off..off + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        off += 4 * n;
        out.push(HostTensor::f32(data, &[l.rows, l.cols]));
    }
    Ok(out)
}

fn argmax(x: &[f32]) -> usize {
    let mut best = 0usize;
    for i in 1..x.len() {
        if x[i] > x[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loader::synthetic_model;
    use crate::model::Config;
    use crate::store::pipeline::{compress_model, CompressOpts};

    fn tiny_compressed() -> CompressedModel {
        let m = synthetic_model(
            Config {
                name: "T".into(),
                vocab: 64,
                d_model: 16,
                n_layers: 3,
                n_heads: 2,
                d_ff: 24,
                max_ctx: 32,
            },
            23,
        );
        compress_model(&m, &CompressOpts { lam: 0.3, ..Default::default() }).unwrap().0
    }

    #[test]
    fn arena_decode_matches_owned_and_is_alloc_free() {
        let cm = tiny_compressed();
        let lut = cm.fmt.value_table();
        let max = cm.blocks.iter().map(|b| b.n_symbols()).max().unwrap();
        let arena = DecodeArena::new(max);
        // two consecutive passes over all blocks = two generate steps;
        // views drop at the end of each block, like the forward does
        for pass in 0..2 {
            for b in 0..cm.blocks.len() {
                let owned = decode_codes(&cm, &lut, None, b, 1).unwrap();
                let view = decode_codes(&cm, &lut, Some(&arena), b, 2).unwrap();
                assert_eq!(owned.len(), view.len());
                for (o, v) in owned.iter().zip(&view) {
                    assert_eq!(o.as_f32(), v.as_f32(), "pass={pass} block={b}");
                    assert_eq!(o.dims(), v.dims());
                }
            }
        }
        assert_eq!(arena.fresh_allocs(), 0, "steady-state decode must reuse the arena");
    }

    #[test]
    fn arena_survives_held_views_with_counted_fallback() {
        let cm = tiny_compressed();
        let lut = cm.fmt.value_table();
        let max = cm.blocks.iter().map(|b| b.n_symbols()).max().unwrap();
        let arena = DecodeArena::new(max);
        // hold block 0's views across its slot's next turn: the arena
        // must fall back to a fresh buffer (counted), never clobber
        let held = decode_codes(&cm, &lut, Some(&arena), 0, 1).unwrap();
        let snapshot: Vec<Vec<f32>> = held.iter().map(|t| t.as_f32().to_vec()).collect();
        let again = decode_codes(&cm, &lut, Some(&arena), 0, 1).unwrap();
        assert_eq!(arena.fresh_allocs(), 1);
        for ((h, s), a) in held.iter().zip(&snapshot).zip(&again) {
            assert_eq!(h.as_f32(), &s[..], "held view was clobbered");
            assert_eq!(h.as_f32(), a.as_f32());
        }
    }

    #[test]
    fn offload_parse_rejects_truncated_and_padded_files() {
        let cm = tiny_compressed();
        let cb = &cm.blocks[0];
        let lut = cm.fmt.value_table();
        let codes = decode_codes(&cm, &lut, None, 0, 1).unwrap();
        let mut bytes = Vec::new();
        for t in &codes {
            for &v in t.as_f32() {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        let parsed = parse_offload_codes(&bytes, cb).unwrap();
        for (p, c) in parsed.iter().zip(&codes) {
            assert_eq!(p.as_f32(), c.as_f32());
        }
        assert!(parse_offload_codes(&bytes[..bytes.len() - 1], cb).is_err());
        assert!(parse_offload_codes(&bytes[..4], cb).is_err());
        let mut padded = bytes.clone();
        padded.extend_from_slice(&[0u8; 4]);
        assert!(parse_offload_codes(&padded, cb).is_err());
    }

    #[test]
    fn zero_token_metrics_are_zero_not_nan() {
        let m = Metrics {
            prefill_ms: 1.0,
            decode_ms: 0.0,
            decode_tokens: 0,
            ans_decode_ms: 0.0,
            exec_ms: 0.0,
            ttft_ms: 1.0,
        };
        assert_eq!(m.tokens_per_s_decode(4), 0.0);
        // tokens but an (impossible) zero duration must not be inf
        let m2 = Metrics { decode_tokens: 10, ..m };
        assert_eq!(m2.tokens_per_s_decode(4), 0.0);
    }

    #[test]
    fn nonzero_metrics_compute_rate() {
        let m = Metrics {
            prefill_ms: 0.0,
            decode_ms: 500.0,
            decode_tokens: 50,
            ans_decode_ms: 0.0,
            exec_ms: 0.0,
            ttft_ms: 0.0,
        };
        assert!((m.tokens_per_s_decode(2) - 200.0).abs() < 1e-9);
    }
}

