//! L3 serving engine — the coordination layer of the three-layer stack:
//! request batching, block-wise ANS decode-ahead pipeline, and PJRT
//! execution of the AOT artifacts.  Python never runs here.

pub mod batcher;
pub mod engine;
pub mod kv;

pub use batcher::{pack, select_slot, Batch, Request};
pub use engine::{DecodeState, EngineOpts, Metrics, Residency, ServingEngine, ShardRole};
pub use kv::{KvCache, KvCfg, KvMode, TailFmt};
