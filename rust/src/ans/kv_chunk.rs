//! Chunk codec for the compressed KV-cache tail (`coordinator::kv`).
//!
//! A sealed chunk is a self-contained byte container for a fixed number
//! of quantized cache rows.  The framing is deliberately tiny — the
//! 512-byte `FreqTable` serialization used by the weight store would
//! dwarf a chunk of f8 rows, so present symbols are listed sparsely:
//!
//! ```text
//!   byte 0 == 0 (RAW):  quantized row bytes, verbatim
//!   byte 0 == 1 (RANS): u16 LE n          present-symbol count (1..=256)
//!                       n x { u8 sym, u16 LE freq }   freqs sum to 4096
//!                       rANS payload      (`rans::encode_chunk` framing)
//! ```
//!
//! Sealing deterministically picks whichever encoding is smaller, so a
//! chunk never costs more than one byte over the quantized rows.  Decode
//! treats the chunk as untrusted (it can arrive via fault replay of a
//! half-written step): corrupt framing must surface as `Err`, never a
//! panic — `entlint`'s `no-panic-on-untrusted` rule covers this module.

use crate::ans::rans::{self, FreqTable, PROB_BITS};
use crate::entropy::{histogram, normalize_freqs};

pub const FLAG_RAW: u8 = 0;
pub const FLAG_RANS: u8 = 1;

/// Reusable decode state: the frequency scratch and a slot table that is
/// rebuilt in place per chunk (`FreqTable::rebuild`), so steady-state
/// tail decode allocates nothing.
pub struct ChunkScratch {
    freq: [u32; 256],
    table: FreqTable,
}

impl ChunkScratch {
    pub fn new() -> Self {
        ChunkScratch { freq: [0u32; 256], table: FreqTable::from_data(&[]) }
    }
}

impl Default for ChunkScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Seal `bytes` (one chunk of quantized rows) into `out`, appending.
/// Trusted in-process path: the bytes come from our own quantizer.
pub fn seal_into(bytes: &[u8], out: &mut Vec<u8>) {
    if bytes.is_empty() {
        out.push(FLAG_RAW);
        return;
    }
    let freq = normalize_freqs(&histogram(bytes), PROB_BITS);
    let table = FreqTable::from_freqs(freq);
    let payload = rans::encode_chunk(bytes, &table);
    let n_present = freq.iter().filter(|&&f| f > 0).count();
    let rans_len = 1 + 2 + 3 * n_present + payload.len();
    if rans_len >= 1 + bytes.len() {
        out.push(FLAG_RAW);
        out.extend_from_slice(bytes);
    } else {
        out.push(FLAG_RANS);
        out.extend_from_slice(&(n_present as u16).to_le_bytes());
        for (sym, &f) in freq.iter().enumerate() {
            if f > 0 {
                out.push(sym as u8);
                out.extend_from_slice(&(f as u16).to_le_bytes());
            }
        }
        out.extend_from_slice(&payload);
    }
}

/// Pop `n` bytes off the front of `buf`, erroring (not panicking) on
/// truncated input.
fn take<'a>(buf: &mut &'a [u8], n: usize) -> Result<&'a [u8], String> {
    if buf.len() < n {
        return Err("kv chunk truncated".into());
    }
    let (head, tail) = buf.split_at(n);
    *buf = tail;
    Ok(head)
}

/// Decode a sealed chunk into exactly `out.len()` quantized bytes,
/// reusing `scratch` so the steady-state decode path is alloc-free.
// entlint: allow(no-panic-on-untrusted) — every index sits below a `take` length guard
// (fixed-width reads of slices `take` already bounds-checked)
// entlint: hot
pub fn decode_into(chunk: &[u8], scratch: &mut ChunkScratch, out: &mut [u8]) -> Result<(), String> {
    let mut buf = chunk;
    let flag = take(&mut buf, 1)?[0];
    match flag {
        FLAG_RAW => {
            if buf.len() != out.len() {
                return Err("kv chunk raw body length mismatch".into());
            }
            out.copy_from_slice(buf);
            Ok(())
        }
        FLAG_RANS => {
            let nb = take(&mut buf, 2)?;
            let n = u16::from_le_bytes([nb[0], nb[1]]) as usize;
            if n == 0 || n > 256 {
                return Err("kv chunk symbol count out of range".into());
            }
            let entries = take(&mut buf, 3 * n)?;
            scratch.freq.fill(0);
            for ent in entries.chunks_exact(3) {
                let sym = ent[0] as usize;
                let f = u16::from_le_bytes([ent[1], ent[2]]) as u32;
                if f == 0 {
                    return Err("kv chunk zero-frequency symbol entry".into());
                }
                if scratch.freq[sym] != 0 {
                    return Err("kv chunk duplicate symbol entry".into());
                }
                scratch.freq[sym] = f;
            }
            // rebuild validates sum == 2^PROB_BITS; a table that passes
            // can still mismatch the payload, which the final-state /
            // consumption checks inside `decode_chunk_into` catch.
            scratch.table.rebuild(&scratch.freq)?;
            rans::decode_chunk_into(buf, out, &scratch.table)
        }
        _ => Err("kv chunk unknown flag byte".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let mut sealed = Vec::new();
        seal_into(data, &mut sealed);
        let mut scratch = ChunkScratch::new();
        let mut out = vec![0u8; data.len()];
        decode_into(&sealed, &mut scratch, &mut out).expect("roundtrip decode");
        assert_eq!(out, data);
        sealed
    }

    #[test]
    fn raw_fallback_for_incompressible_bytes() {
        // splitmix-ish pseudo-random bytes: high entropy, rANS with a
        // sparse-table header cannot win at this size.
        let mut x = 0x9e3779b97f4a7c15u64;
        let data: Vec<u8> = (0..256)
            .map(|_| {
                x ^= x >> 27;
                x = x.wrapping_mul(0x2545f4914f6cdd1d);
                (x >> 56) as u8
            })
            .collect();
        let sealed = roundtrip(&data);
        assert_eq!(sealed[0], FLAG_RAW);
        assert_eq!(sealed.len(), 1 + data.len());
    }

    #[test]
    fn rans_wins_on_skewed_bytes() {
        let mut data = vec![0u8; 2048];
        for (i, b) in data.iter_mut().enumerate() {
            if i % 17 == 0 {
                *b = 0x38;
            }
        }
        let sealed = roundtrip(&data);
        assert_eq!(sealed[0], FLAG_RANS);
        assert!(sealed.len() < data.len() / 2, "sealed {} bytes", sealed.len());
    }

    #[test]
    fn empty_chunk_roundtrips() {
        let sealed = roundtrip(&[]);
        assert_eq!(sealed, vec![FLAG_RAW]);
    }

    #[test]
    fn corrupt_chunks_error_not_panic() {
        let mut sealed = Vec::new();
        seal_into(&vec![0x38u8; 2048], &mut sealed);
        assert_eq!(sealed[0], FLAG_RANS);
        let mut scratch = ChunkScratch::new();
        let mut out = vec![0u8; 2048];
        // empty container
        assert!(decode_into(&[], &mut scratch, &mut out).is_err());
        // unknown flag
        assert!(decode_into(&[7, 1, 2], &mut scratch, &mut out).is_err());
        // raw body length mismatch
        assert!(decode_into(&[FLAG_RAW, 1, 2, 3], &mut scratch, &mut out).is_err());
        // truncations at every prefix length must error, never panic
        for cut in 0..sealed.len() {
            assert!(decode_into(&sealed[..cut], &mut scratch, &mut out).is_err(), "cut {cut}");
        }
        // flipped payload byte: caught by the decoder's state checks
        let mut bad = sealed.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        let r = decode_into(&bad, &mut scratch, &mut out);
        if let Ok(()) = r {
            // a single flipped byte can in principle still decode to
            // *different* bytes with valid framing; it must not match
            assert_ne!(out, vec![0x38u8; 2048]);
        }
        // duplicate symbol entry
        let dup = [FLAG_RANS, 2, 0, 5, 0x00, 0x08, 5, 0x00, 0x08];
        assert!(decode_into(&dup, &mut scratch, &mut out).is_err());
        // zero-frequency entry
        let zf = [FLAG_RANS, 1, 0, 5, 0x00, 0x00];
        assert!(decode_into(&zf, &mut scratch, &mut out).is_err());
        // bad sum (single symbol, freq 1 != 4096)
        let bs = [FLAG_RANS, 1, 0, 5, 0x01, 0x00];
        assert!(decode_into(&bs, &mut scratch, &mut out).is_err());
    }
}
