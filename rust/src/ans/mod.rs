//! Lossless entropy-coding substrate: from-scratch rANS (the paper's
//! nvCOMP analogue), chunked bitstream framing, and a canonical Huffman
//! baseline.

pub mod bitstream;
pub mod huffman;
pub mod kv_chunk;
pub mod rans;

pub use bitstream::{Bitstream, DEFAULT_CHUNK, MAX_CHUNK};
pub use huffman::Huffman;
pub use rans::{FreqTable, N_STREAMS, PROB_BITS};
