//! Chunked ANS bitstream container — the `z` of paper Algorithms 1/2.
//!
//! Mirrors the nvCOMP framing the paper uses (§A.1): symbols are split
//! into 256 KiB chunks, each encoded independently against a *single*
//! per-bitstream frequency table, so chunks encode and decode in
//! parallel (nvCOMP parallelizes across GPU blocks; we fan out across
//! the shared `parallel::Pool`).
//!
//! Wire layout (little endian):
//!   magic  b"EQZB"
//!   u32    crc32 over everything after this field (integrity check:
//!          corrupt or truncated streams deserialize to Err, never panic)
//!   u32    n_symbols_total
//!   u32    chunk_size (symbols per chunk)
//!   u32    n_chunks
//!   [u32]  compressed byte length per chunk
//!   512B   frequency table
//!   bytes  chunk payloads, concatenated
//!
//! Robustness contract (exercised by tests/corruption.rs): every public
//! decode/deserialize entry point returns `Err` on malformed input —
//! attacker-controlled `chunk_lens`, header fields, tables, or payload
//! bytes must never cause a panic or a silent mis-decode.

use super::rans::{
    decode_chunk_fused, decode_chunk_into, decode_chunk_pair_fused, decode_chunk_pair_into,
    encode_chunk, FreqTable,
};
use crate::parallel::{pair_jobs, Pool};
use crate::util::crc32;

pub const DEFAULT_CHUNK: usize = 256 * 1024; // symbols per chunk (paper §A.1)
/// Largest chunk the framing accepts (16x the default; every in-repo
/// encoder uses <= 1 MiB).  Bounds the per-chunk decode allocation an
/// untrusted header can demand.  Note: like any entropy-coded format, a
/// *valid* stream can still legitimately expand enormously (an all-zero
/// layer compresses ~20 bytes/chunk), so callers decoding fully
/// untrusted streams should additionally budget `n_symbols` at the
/// application level.
pub const MAX_CHUNK: usize = 16 * DEFAULT_CHUNK;
const MAGIC: &[u8; 4] = b"EQZB";
/// magic + crc + n_symbols + chunk_size + n_chunks
const HEADER_LEN: usize = 20;

#[derive(Clone)]
pub struct Bitstream {
    pub n_symbols: usize,
    pub chunk_size: usize,
    pub chunk_lens: Vec<u32>,
    pub table: FreqTable,
    pub payload: Vec<u8>,
}

/// One decode job: (payload offset, payload len, symbols in this chunk).
type ChunkJob = (usize, usize, usize);

/// A chunk job paired with its disjoint output slice (u8 symbols or
/// fused f32 codes).
type DecodeTask<'a, T> = (ChunkJob, &'a mut [T]);

/// `ceil(a / b)` without the 1.73+ `div_ceil`; overflow-free for any
/// operands (b must be nonzero).
fn ceil_div(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}

impl Bitstream {
    /// Encode `symbols` into a chunked bitstream (scalar path).
    pub fn encode(symbols: &[u8], chunk_size: usize) -> Self {
        Self::encode_parallel(symbols, chunk_size, 1)
    }

    /// Encode with chunks fanned out across `threads` workers.  The
    /// output is byte-identical to the scalar path for any thread count
    /// (chunks are independent and reassembled in order).
    pub fn encode_parallel(symbols: &[u8], chunk_size: usize, threads: usize) -> Self {
        // from_data guarantees nonzero frequency for every present
        // symbol, so the coverage scan in the external-table entry
        // point is unnecessary here
        let table = FreqTable::from_data(symbols);
        Self::encode_chunks(symbols, chunk_size, table, threads)
    }

    pub fn encode_with_table(symbols: &[u8], chunk_size: usize, table: FreqTable) -> Self {
        Self::encode_with_table_parallel(symbols, chunk_size, table, 1)
    }

    /// External-table entry point: validates that `table` covers every
    /// symbol actually present (a zero-frequency symbol would mis-encode
    /// and divide by zero) before encoding.  The internal
    /// `encode_parallel` path skips this scan — its table comes from
    /// `FreqTable::from_data`, which guarantees coverage.
    // entlint: allow(no-panic-on-untrusted) — encode path over trusted in-process data;
    // the coverage scan is u8-indexed into fixed 256-entry arrays
    pub fn encode_with_table_parallel(
        symbols: &[u8],
        chunk_size: usize,
        table: FreqTable,
        threads: usize,
    ) -> Self {
        let mut present = [false; 256];
        for &s in symbols {
            present[s as usize] = true;
        }
        for sym in 0..256 {
            assert!(
                !present[sym] || table.freq[sym] > 0,
                "symbol {sym} present in data but has zero frequency in table"
            );
        }
        Self::encode_chunks(symbols, chunk_size, table, threads)
    }

    /// Shared encode core; `table` must cover all present symbols.
    // entlint: allow(no-panic-on-untrusted) — encode path: `chunks[i]` is indexed by the
    // pool's job index, which ranges over chunks.len() by construction
    fn encode_chunks(symbols: &[u8], chunk_size: usize, table: FreqTable, threads: usize) -> Self {
        assert!(
            chunk_size > 0 && chunk_size <= MAX_CHUNK,
            "chunk_size must be in 1..={MAX_CHUNK}"
        );
        if symbols.is_empty() {
            return Bitstream {
                n_symbols: 0,
                chunk_size,
                chunk_lens: Vec::new(),
                table,
                payload: Vec::new(),
            };
        }
        let chunks: Vec<&[u8]> = symbols.chunks(chunk_size).collect();
        let encoded: Vec<Vec<u8>> =
            Pool::new(threads).par_map_indexed(chunks.len(), |i| encode_chunk(chunks[i], &table));
        let mut chunk_lens = Vec::with_capacity(encoded.len());
        let mut payload = Vec::with_capacity(encoded.iter().map(Vec::len).sum());
        for enc in &encoded {
            chunk_lens.push(enc.len() as u32);
            payload.extend_from_slice(enc);
        }
        Bitstream { n_symbols: symbols.len(), chunk_size, chunk_lens, table, payload }
    }

    /// Validate the chunk layout and return one decode job per chunk.
    /// Every slice boundary the decoder will touch is checked here, so
    /// corrupt `chunk_lens` / `chunk_size` / `n_symbols` combinations
    /// surface as `Err` instead of a slice panic.
    fn chunk_jobs(&self) -> Result<Vec<ChunkJob>, String> {
        if self.n_symbols == 0 {
            if !self.chunk_lens.is_empty() || !self.payload.is_empty() {
                return Err("corrupt bitstream: empty stream with chunk data".into());
            }
            return Ok(Vec::new());
        }
        if self.chunk_size == 0 || self.chunk_size > MAX_CHUNK {
            return Err(format!(
                "corrupt bitstream: chunk_size {} outside 1..={MAX_CHUNK}",
                self.chunk_size
            ));
        }
        let want_chunks = ceil_div(self.n_symbols, self.chunk_size);
        if self.chunk_lens.len() != want_chunks {
            return Err(format!(
                "corrupt bitstream: {} chunks for {} symbols of chunk_size {} (want {})",
                self.chunk_lens.len(),
                self.n_symbols,
                self.chunk_size,
                want_chunks
            ));
        }
        let mut jobs = Vec::with_capacity(want_chunks);
        let mut off = 0usize;
        let mut remaining = self.n_symbols;
        for &len in &self.chunk_lens {
            let len = len as usize;
            let end = off
                .checked_add(len)
                .ok_or_else(|| "corrupt bitstream: chunk length overflow".to_string())?;
            if end > self.payload.len() {
                return Err(format!(
                    "corrupt bitstream: chunk extends past payload ({end} > {})",
                    self.payload.len()
                ));
            }
            let n = remaining.min(self.chunk_size);
            jobs.push((off, len, n));
            off = end;
            remaining -= n;
        }
        if off != self.payload.len() {
            return Err(format!(
                "corrupt bitstream: {} trailing payload bytes",
                self.payload.len() - off
            ));
        }
        Ok(jobs)
    }

    /// Decode the whole stream (scalar path).
    ///
    /// Allocates `n_symbols` bytes after the chunk layout validates
    /// (structural lies like `n_symbols = usize::MAX` are rejected
    /// first).  A structurally *valid* untrusted stream can still
    /// demand up to u32::MAX symbols from a few KiB of input — an
    /// inherent property of entropy coding (cf. zstd bombs); servers
    /// decoding untrusted streams should budget `n_symbols` before
    /// calling, or use `decode_into` with a caller-sized buffer.
    pub fn decode(&self) -> Result<Vec<u8>, String> {
        self.chunk_jobs()?;
        let mut out = vec![0u8; self.n_symbols];
        self.decode_into(&mut out, 1)?;
        Ok(out)
    }

    /// Pair each chunk with its disjoint output slice (chunk_jobs()
    /// guarantees the slice lengths sum to exactly n_symbols), then
    /// group chunks two-per-task where that keeps every worker busy:
    /// a worker that owns both chunks of a task decodes them in the
    /// 8-chain software-pipelined joint loop (see `rans`).
    fn decode_tasks<'a, T>(
        &self,
        out: &'a mut [T],
        threads: usize,
    ) -> Result<Vec<(DecodeTask<'a, T>, Option<DecodeTask<'a, T>>)>, String> {
        let jobs = self.chunk_jobs()?;
        let mut tasks: Vec<DecodeTask<'a, T>> = Vec::with_capacity(jobs.len());
        let mut rest = out;
        for &job in &jobs {
            let (head, tail) = rest.split_at_mut(job.2);
            tasks.push((job, head));
            rest = tail;
        }
        Ok(pair_jobs(tasks, threads))
    }

    /// Shared decode driver: validate the output size, build (possibly
    /// paired) chunk tasks, and fan them out — `single`/`pair` supply
    /// the per-task decode (byte sink or fused f32 sink).
    // entlint: hot
    // entlint: allow(no-panic-on-untrusted) — every payload range sliced here was
    // bounds-checked against payload.len() by chunk_jobs() before any decode starts
    fn decode_dispatch<T, FS, FP>(
        &self,
        out: &mut [T],
        threads: usize,
        single: FS,
        pair: FP,
    ) -> Result<(), String>
    where
        T: Send,
        FS: Fn(&[u8], &mut [T]) -> Result<(), String> + Sync,
        FP: Fn(&[u8], &mut [T], &[u8], &mut [T]) -> Result<(), String> + Sync,
    {
        if out.len() != self.n_symbols {
            // entlint: allow(hot-path-alloc-free) — cold error branch; taken once on
            // caller misuse, never in the decode steady state
            return Err(format!(
                "output buffer holds {} elements but stream has {} symbols",
                out.len(),
                self.n_symbols
            ));
        }
        let tasks = self.decode_tasks(out, threads)?;
        Pool::new(threads).try_for_each(tasks, |_, (((ao, al, _), a_out), second)| {
            match second {
                Some(((bo, bl, _), b_out)) => {
                    pair(&self.payload[ao..ao + al], a_out, &self.payload[bo..bo + bl], b_out)
                }
                None => single(&self.payload[ao..ao + al], a_out),
            }
        })
    }

    /// Decode into a caller-provided buffer (the serving arena path: no
    /// allocation on the request path — symbols are written straight
    /// into `out`'s chunk slices).  Chunks decode across `threads`
    /// workers of the shared pool; the result is identical to the
    /// scalar path for any thread count.
    // entlint: hot
    pub fn decode_into(&self, out: &mut [u8], threads: usize) -> Result<(), String> {
        self.decode_dispatch(
            out,
            threads,
            |p, o| decode_chunk_into(p, o, &self.table),
            |pa, oa, pb, ob| decode_chunk_pair_into(pa, oa, pb, ob, &self.table),
        )
    }

    /// Fused decode->dequant: inflate the whole stream straight to f32
    /// codes through a 256-entry LUT — the serving hot path, with no
    /// intermediate symbol buffer.  Output equals `decode_into` mapped
    /// through `lut`, for any thread count.
    // entlint: hot
    pub fn decode_fused_into(
        &self,
        out: &mut [f32],
        lut: &[f32; 256],
        threads: usize,
    ) -> Result<(), String> {
        self.decode_dispatch(
            out,
            threads,
            |p, o| decode_chunk_fused(p, o, lut, &self.table),
            |pa, oa, pb, ob| decode_chunk_pair_fused(pa, oa, pb, ob, lut, &self.table),
        )
    }

    /// Total serialized size in bytes (storage accounting for the
    /// effective-bits-per-parameter numbers in every table).
    pub fn serialized_len(&self) -> usize {
        HEADER_LEN + 4 * self.chunk_lens.len() + FreqTable::serialized_len() + self.payload.len()
    }

    // entlint: allow(no-panic-on-untrusted) — serialization of an in-memory stream; the
    // crc patch slices a buffer this fn just wrote (always >= HEADER_LEN bytes)
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.serialized_len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&[0u8; 4]); // crc placeholder
        out.extend_from_slice(&(self.n_symbols as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_size as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_lens.len() as u32).to_le_bytes());
        for &l in &self.chunk_lens {
            out.extend_from_slice(&l.to_le_bytes());
        }
        self.table.serialize_into(&mut out);
        out.extend_from_slice(&self.payload);
        let crc = crc32(&out[8..]);
        out[4..8].copy_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse a bitstream from `bytes`, returning it plus the number of
    /// bytes consumed (trailing data is the caller's business).  All
    /// header fields are cross-validated and the crc32 must match; any
    /// corruption or truncation yields `Err`.
    // entlint: allow(no-panic-on-untrusted) — every slice offset is checked against
    // bytes.len() (with overflow-checked arithmetic) before use, and rd_u32's try_into
    // on an exact 4-byte slice is infallible
    pub fn deserialize(bytes: &[u8]) -> Result<(Self, usize), String> {
        if bytes.len() < HEADER_LEN + FreqTable::serialized_len() || &bytes[..4] != MAGIC {
            return Err("bad bitstream magic or truncated header".into());
        }
        let rd_u32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let crc_stored = rd_u32(4);
        let n_symbols = rd_u32(8) as usize;
        let chunk_size = rd_u32(12) as usize;
        let n_chunks = rd_u32(16) as usize;

        // structural consistency before any allocation or slicing
        if n_symbols == 0 {
            if n_chunks != 0 {
                return Err("corrupt bitstream: empty stream with chunks".into());
            }
        } else {
            if chunk_size == 0 || chunk_size > MAX_CHUNK {
                return Err(format!(
                    "corrupt bitstream: chunk_size {chunk_size} outside 1..={MAX_CHUNK}"
                ));
            }
            if n_chunks != ceil_div(n_symbols, chunk_size) {
                return Err(format!(
                    "corrupt bitstream: {n_chunks} chunks for {n_symbols} symbols of chunk_size {chunk_size}"
                ));
            }
        }
        let lens_bytes = n_chunks
            .checked_mul(4)
            .ok_or_else(|| "corrupt bitstream: chunk count overflow".to_string())?;
        let payload_off = HEADER_LEN
            .checked_add(lens_bytes)
            .and_then(|o| o.checked_add(FreqTable::serialized_len()))
            .ok_or_else(|| "corrupt bitstream: header overflow".to_string())?;
        let table_off = payload_off - FreqTable::serialized_len();
        if bytes.len() < payload_off {
            return Err("bitstream truncated (header)".into());
        }

        let mut chunk_lens = Vec::with_capacity(n_chunks);
        let mut total = 0u64;
        for i in 0..n_chunks {
            let l = rd_u32(HEADER_LEN + 4 * i);
            total += l as u64;
            chunk_lens.push(l);
        }
        let total = usize::try_from(total)
            .map_err(|_| "corrupt bitstream: payload length overflow".to_string())?;
        let consumed = payload_off
            .checked_add(total)
            .ok_or_else(|| "corrupt bitstream: payload length overflow".to_string())?;
        if bytes.len() < consumed {
            return Err("bitstream truncated (payload)".into());
        }
        if crc32(&bytes[8..consumed]) != crc_stored {
            return Err("corrupt bitstream: crc32 mismatch".into());
        }

        let table = FreqTable::deserialize(&bytes[table_off..payload_off])?;
        let payload = bytes[payload_off..consumed].to_vec();
        Ok((Bitstream { n_symbols, chunk_size, chunk_lens, table, payload }, consumed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn data(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| ((rng.normal().abs() * 6.0) as usize).min(255) as u8).collect()
    }

    #[test]
    fn roundtrip_multi_chunk() {
        let d = data(10_000, 1);
        let bs = Bitstream::encode(&d, 1024);
        assert_eq!(bs.chunk_lens.len(), 10);
        assert_eq!(bs.decode().unwrap(), d);
    }

    #[test]
    fn roundtrip_odd_tail() {
        let d = data(2500, 2);
        let bs = Bitstream::encode(&d, 1000);
        assert_eq!(bs.chunk_lens.len(), 3);
        assert_eq!(bs.decode().unwrap(), d);
    }

    #[test]
    fn decode_into_matches_decode() {
        let d = data(50_000, 3);
        let bs = Bitstream::encode(&d, 4096);
        let mut buf = vec![0u8; d.len()];
        bs.decode_into(&mut buf, 1).unwrap();
        assert_eq!(buf, d);
        let mut buf2 = vec![0u8; d.len()];
        bs.decode_into(&mut buf2, 4).unwrap();
        assert_eq!(buf2, d);
    }

    #[test]
    fn fused_decode_matches_scalar_across_threads() {
        let d = data(100_000, 12);
        // 13 chunks: exercises both the paired path (threads small
        // enough to pair) and the odd single-chunk tail
        let bs = Bitstream::encode(&d, 8 * 1024);
        assert_eq!(bs.chunk_lens.len(), 13);
        let lut = core::array::from_fn::<f32, 256, _>(|i| (i as f32).sqrt() - 3.0);
        let mut sym = vec![0u8; d.len()];
        bs.decode_into(&mut sym, 1).unwrap();
        assert_eq!(sym, d);
        let want: Vec<f32> = d.iter().map(|&s| lut[s as usize]).collect();
        for threads in [1usize, 2, 3, 8] {
            let mut out = vec![0.0f32; d.len()];
            bs.decode_fused_into(&mut out, &lut, threads).unwrap();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn fused_decode_wrong_size_or_corrupt_is_error() {
        let d = data(5000, 13);
        let mut bs = Bitstream::encode(&d, 1024);
        let lut = [0.0f32; 256];
        let mut small = vec![0.0f32; d.len() - 1];
        assert!(bs.decode_fused_into(&mut small, &lut, 1).is_err());
        bs.chunk_lens[0] += 1;
        let mut out = vec![0.0f32; d.len()];
        assert!(bs.decode_fused_into(&mut out, &lut, 2).is_err());
    }

    #[test]
    fn parallel_encode_is_byte_identical() {
        let d = data(30_000, 8);
        let scalar = Bitstream::encode(&d, 1 << 10).serialize();
        for threads in [2, 4, 7] {
            let par = Bitstream::encode_parallel(&d, 1 << 10, threads).serialize();
            assert_eq!(par, scalar, "threads={threads}");
        }
    }

    #[test]
    fn serialize_roundtrip() {
        let d = data(5000, 4);
        let bs = Bitstream::encode(&d, 700);
        let ser = bs.serialize();
        assert_eq!(ser.len(), bs.serialized_len());
        let (bs2, consumed) = Bitstream::deserialize(&ser).unwrap();
        assert_eq!(consumed, ser.len());
        assert_eq!(bs2.decode().unwrap(), d);
    }

    #[test]
    fn serialize_with_trailing_data() {
        let d = data(100, 5);
        let bs = Bitstream::encode(&d, 64);
        let mut ser = bs.serialize();
        let len = ser.len();
        ser.extend_from_slice(b"trailing");
        let (bs2, consumed) = Bitstream::deserialize(&ser).unwrap();
        assert_eq!(consumed, len);
        assert_eq!(bs2.decode().unwrap(), d);
    }

    #[test]
    fn empty_stream() {
        let bs = Bitstream::encode(&[], 128);
        assert_eq!(bs.decode().unwrap(), Vec::<u8>::new());
        let (bs2, _) = Bitstream::deserialize(&bs.serialize()).unwrap();
        assert_eq!(bs2.n_symbols, 0);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let d = data(100, 6);
        let mut ser = Bitstream::encode(&d, 64).serialize();
        ser[0] = b'X';
        assert!(Bitstream::deserialize(&ser).is_err());
    }

    #[test]
    fn wrong_buffer_size_is_error_not_panic() {
        let d = data(1000, 9);
        let bs = Bitstream::encode(&d, 256);
        let mut small = vec![0u8; d.len() - 1];
        assert!(bs.decode_into(&mut small, 1).is_err());
        let mut big = vec![0u8; d.len() + 1];
        assert!(bs.decode_into(&mut big, 2).is_err());
    }

    #[test]
    fn lying_chunk_lens_is_error_not_panic() {
        let d = data(4000, 10);
        let mut bs = Bitstream::encode(&d, 1000);
        // chunk claims more payload than exists
        bs.chunk_lens[3] += 50;
        assert!(bs.decode().is_err());
        // chunk claims less: trailing payload bytes
        bs.chunk_lens[3] -= 100;
        assert!(bs.decode().is_err());
        // wrong chunk count entirely
        let mut bs2 = Bitstream::encode(&d, 1000);
        bs2.chunk_lens.pop();
        assert!(bs2.decode().is_err());
        // zero chunk_size with symbols outstanding
        let mut bs3 = Bitstream::encode(&d, 1000);
        bs3.chunk_size = 0;
        assert!(bs3.decode().is_err());
        // chunk_size beyond the framing cap (alloc-bomb guard)
        let mut bs4 = Bitstream::encode(&d, 1000);
        bs4.chunk_size = MAX_CHUNK + 1;
        bs4.n_symbols = MAX_CHUNK + 1;
        assert!(bs4.decode().is_err());
    }

    #[test]
    fn effective_bits_match_entropy() {
        let d = data(300_000, 7);
        let h = crate::entropy::entropy_of(&d);
        let bs = Bitstream::encode(&d, DEFAULT_CHUNK);
        let bits = bs.serialized_len() as f64 * 8.0 / d.len() as f64;
        assert!(bits < h + 0.1, "bits={bits} H={h}");
    }
}
