//! Chunked ANS bitstream container — the `z` of paper Algorithms 1/2.
//!
//! Mirrors the nvCOMP framing the paper uses (§A.1): symbols are split
//! into 256 KiB chunks, each encoded independently against a *single*
//! per-bitstream frequency table, so chunks decode in parallel (nvCOMP
//! parallelizes across GPU blocks; we use a thread pool / scalar loop).
//!
//! Wire layout (little endian):
//!   magic  b"EQZB"
//!   u32    n_symbols_total
//!   u32    chunk_size (symbols per chunk)
//!   u32    n_chunks
//!   [u32]  compressed byte length per chunk
//!   512B   frequency table
//!   bytes  chunk payloads, concatenated

use super::rans::{decode_chunk, encode_chunk, FreqTable};

pub const DEFAULT_CHUNK: usize = 256 * 1024; // symbols per chunk (paper §A.1)
const MAGIC: &[u8; 4] = b"EQZB";

#[derive(Clone)]
pub struct Bitstream {
    pub n_symbols: usize,
    pub chunk_size: usize,
    pub chunk_lens: Vec<u32>,
    pub table: FreqTable,
    pub payload: Vec<u8>,
}

impl Bitstream {
    /// Encode `symbols` into a chunked bitstream.
    pub fn encode(symbols: &[u8], chunk_size: usize) -> Self {
        assert!(chunk_size > 0);
        let table = FreqTable::from_data(symbols);
        Self::encode_with_table(symbols, chunk_size, table)
    }

    pub fn encode_with_table(symbols: &[u8], chunk_size: usize, table: FreqTable) -> Self {
        let mut chunk_lens = Vec::new();
        let mut payload = Vec::new();
        if symbols.is_empty() {
            return Bitstream { n_symbols: 0, chunk_size, chunk_lens, table, payload };
        }
        for chunk in symbols.chunks(chunk_size) {
            let enc = encode_chunk(chunk, &table);
            chunk_lens.push(enc.len() as u32);
            payload.extend_from_slice(&enc);
        }
        Bitstream { n_symbols: symbols.len(), chunk_size, chunk_lens, table, payload }
    }

    /// Decode the whole stream (scalar path).
    pub fn decode(&self) -> Result<Vec<u8>, String> {
        let mut out = Vec::with_capacity(self.n_symbols);
        let mut off = 0usize;
        let mut remaining = self.n_symbols;
        for &len in &self.chunk_lens {
            let n = remaining.min(self.chunk_size);
            let chunk = &self.payload[off..off + len as usize];
            out.extend_from_slice(&decode_chunk(chunk, n, &self.table)?);
            off += len as usize;
            remaining -= n;
        }
        Ok(out)
    }

    /// Decode into a caller-provided buffer (the serving double-buffer
    /// path: no allocation on the request path).  Chunks decode across
    /// `threads` OS threads when the stream is large enough.
    pub fn decode_into(&self, out: &mut [u8], threads: usize) -> Result<(), String> {
        assert_eq!(out.len(), self.n_symbols, "output buffer size mismatch");
        if self.n_symbols == 0 {
            return Ok(());
        }
        // precompute (payload range, out range) per chunk
        let mut jobs = Vec::with_capacity(self.chunk_lens.len());
        let mut off = 0usize;
        for (i, &len) in self.chunk_lens.iter().enumerate() {
            let start = i * self.chunk_size;
            let n = (self.n_symbols - start).min(self.chunk_size);
            jobs.push((off, len as usize, start, n));
            off += len as usize;
        }
        if threads <= 1 || jobs.len() == 1 {
            for &(poff, plen, start, n) in &jobs {
                let dec = decode_chunk(&self.payload[poff..poff + plen], n, &self.table)?;
                out[start..start + n].copy_from_slice(&dec);
            }
            return Ok(());
        }
        // split output into disjoint chunk-aligned slices for the threads
        let errs: std::sync::Mutex<Vec<String>> = std::sync::Mutex::new(Vec::new());
        let next = std::sync::atomic::AtomicUsize::new(0);
        let mut out_slices: Vec<Option<&mut [u8]>> = Vec::with_capacity(jobs.len());
        {
            let mut rest = out;
            for (i, &(_, _, start, n)) in jobs.iter().enumerate() {
                let rel = start - (jobs[..i].iter().map(|j| j.3).sum::<usize>());
                debug_assert_eq!(rel, 0);
                let (head, tail) = rest.split_at_mut(n);
                out_slices.push(Some(head));
                rest = tail;
            }
        }
        let slices = std::sync::Mutex::new(out_slices);
        std::thread::scope(|scope| {
            for _ in 0..threads.min(jobs.len()) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let (poff, plen, _, n) = jobs[i];
                    let slice = slices.lock().unwrap()[i].take().unwrap();
                    match decode_chunk(&self.payload[poff..poff + plen], n, &self.table) {
                        Ok(dec) => slice.copy_from_slice(&dec),
                        Err(e) => errs.lock().unwrap().push(e),
                    }
                });
            }
        });
        let errs = errs.into_inner().unwrap();
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    /// Total serialized size in bytes (storage accounting for the
    /// effective-bits-per-parameter numbers in every table).
    pub fn serialized_len(&self) -> usize {
        4 + 4 + 4 + 4 + 4 * self.chunk_lens.len() + FreqTable::serialized_len() + self.payload.len()
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.serialized_len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.n_symbols as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_size as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_lens.len() as u32).to_le_bytes());
        for &l in &self.chunk_lens {
            out.extend_from_slice(&l.to_le_bytes());
        }
        self.table.serialize_into(&mut out);
        out.extend_from_slice(&self.payload);
        out
    }

    pub fn deserialize(bytes: &[u8]) -> Result<(Self, usize), String> {
        if bytes.len() < 16 || &bytes[..4] != MAGIC {
            return Err("bad bitstream magic".into());
        }
        let rd_u32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let n_symbols = rd_u32(4) as usize;
        let chunk_size = rd_u32(8) as usize;
        let n_chunks = rd_u32(12) as usize;
        let mut off = 16;
        if bytes.len() < off + 4 * n_chunks + 512 {
            return Err("bitstream truncated (header)".into());
        }
        let mut chunk_lens = Vec::with_capacity(n_chunks);
        for i in 0..n_chunks {
            chunk_lens.push(rd_u32(off + 4 * i));
        }
        off += 4 * n_chunks;
        let table = FreqTable::deserialize(&bytes[off..off + 512])?;
        off += 512;
        let total: usize = chunk_lens.iter().map(|&l| l as usize).sum();
        if bytes.len() < off + total {
            return Err("bitstream truncated (payload)".into());
        }
        let payload = bytes[off..off + total].to_vec();
        Ok((
            Bitstream { n_symbols, chunk_size, chunk_lens, table, payload },
            off + total,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn data(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| ((rng.normal().abs() * 6.0) as usize).min(255) as u8).collect()
    }

    #[test]
    fn roundtrip_multi_chunk() {
        let d = data(10_000, 1);
        let bs = Bitstream::encode(&d, 1024);
        assert_eq!(bs.chunk_lens.len(), 10);
        assert_eq!(bs.decode().unwrap(), d);
    }

    #[test]
    fn roundtrip_odd_tail() {
        let d = data(2500, 2);
        let bs = Bitstream::encode(&d, 1000);
        assert_eq!(bs.chunk_lens.len(), 3);
        assert_eq!(bs.decode().unwrap(), d);
    }

    #[test]
    fn decode_into_matches_decode() {
        let d = data(50_000, 3);
        let bs = Bitstream::encode(&d, 4096);
        let mut buf = vec![0u8; d.len()];
        bs.decode_into(&mut buf, 1).unwrap();
        assert_eq!(buf, d);
        let mut buf2 = vec![0u8; d.len()];
        bs.decode_into(&mut buf2, 4).unwrap();
        assert_eq!(buf2, d);
    }

    #[test]
    fn serialize_roundtrip() {
        let d = data(5000, 4);
        let bs = Bitstream::encode(&d, 700);
        let ser = bs.serialize();
        assert_eq!(ser.len(), bs.serialized_len());
        let (bs2, consumed) = Bitstream::deserialize(&ser).unwrap();
        assert_eq!(consumed, ser.len());
        assert_eq!(bs2.decode().unwrap(), d);
    }

    #[test]
    fn serialize_with_trailing_data() {
        let d = data(100, 5);
        let bs = Bitstream::encode(&d, 64);
        let mut ser = bs.serialize();
        let len = ser.len();
        ser.extend_from_slice(b"trailing");
        let (bs2, consumed) = Bitstream::deserialize(&ser).unwrap();
        assert_eq!(consumed, len);
        assert_eq!(bs2.decode().unwrap(), d);
    }

    #[test]
    fn empty_stream() {
        let bs = Bitstream::encode(&[], 128);
        assert_eq!(bs.decode().unwrap(), Vec::<u8>::new());
        let (bs2, _) = Bitstream::deserialize(&bs.serialize()).unwrap();
        assert_eq!(bs2.n_symbols, 0);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let d = data(100, 6);
        let mut ser = Bitstream::encode(&d, 64).serialize();
        ser[0] = b'X';
        assert!(Bitstream::deserialize(&ser).is_err());
    }

    #[test]
    fn effective_bits_match_entropy() {
        let d = data(300_000, 7);
        let h = crate::entropy::entropy_of(&d);
        let bs = Bitstream::encode(&d, DEFAULT_CHUNK);
        let bits = bs.serialized_len() as f64 * 8.0 / d.len() as f64;
        assert!(bits < h + 0.1, "bits={bits} H={h}");
    }
}
