//! Canonical Huffman coder — the classical baseline (Han et al. 2016
//! used Huffman in Deep Compression).  Exists to demonstrate the paper's
//! §2.1 point: Huffman needs >= 1 bit/symbol and loses to ANS exactly in
//! the low-entropy regime EntQuant creates.

// Explicit bound comparisons read as the paper's inequalities here (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::manual_range_contains)]

use crate::entropy::histogram;

/// Code lengths per symbol via package-merge-free heap Huffman, capped
/// implicitly by the alphabet size (256 -> max depth 255 < u8 fits).
// entlint: allow(no-panic-on-untrusted) — offline baseline built from an in-process
// histogram: indices are u8-derived or < 512 by tree construction, and the heap pops
// are guarded by `heap.len() > 1`
fn code_lengths(hist: &[u64; 256]) -> [u8; 256] {
    #[derive(PartialEq, Eq)]
    struct Node {
        weight: u64,
        idx: usize,
    }
    impl Ord for Node {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            // min-heap via reverse
            other.weight.cmp(&self.weight).then(other.idx.cmp(&self.idx))
        }
    }
    impl PartialOrd for Node {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }

    let mut lens = [0u8; 256];
    let present: Vec<usize> = (0..256).filter(|&i| hist[i] > 0).collect();
    if present.is_empty() {
        return lens;
    }
    if present.len() == 1 {
        lens[present[0]] = 1;
        return lens;
    }
    // internal tree as parent pointers
    let mut parent: Vec<usize> = vec![usize::MAX; 512];
    let mut heap = std::collections::BinaryHeap::new();
    for (node_idx, &sym) in present.iter().enumerate() {
        heap.push(Node { weight: hist[sym], idx: node_idx });
    }
    let mut next = present.len();
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        parent[a.idx] = next;
        parent[b.idx] = next;
        heap.push(Node { weight: a.weight + b.weight, idx: next });
        next += 1;
    }
    for (node_idx, &sym) in present.iter().enumerate() {
        let mut d = 0u8;
        let mut p = node_idx;
        while parent[p] != usize::MAX {
            p = parent[p];
            d += 1;
        }
        lens[sym] = d;
    }
    lens
}

/// Canonical codes from lengths (shorter codes first, then by symbol).
// entlint: allow(no-panic-on-untrusted) — all indices come from (0..256) filters over
// fixed 256-entry arrays
fn canonical_codes(lens: &[u8; 256]) -> [(u32, u8); 256] {
    let mut order: Vec<usize> = (0..256).filter(|&i| lens[i] > 0).collect();
    order.sort_by_key(|&i| (lens[i], i));
    let mut codes = [(0u32, 0u8); 256];
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &sym in &order {
        code <<= lens[sym] - prev_len;
        codes[sym] = (code, lens[sym]);
        prev_len = lens[sym];
        code += 1;
    }
    codes
}

pub struct Huffman {
    pub lens: [u8; 256],
    codes: [(u32, u8); 256],
}

impl Huffman {
    pub fn from_data(data: &[u8]) -> Self {
        let lens = code_lengths(&histogram(data));
        let codes = canonical_codes(&lens);
        Huffman { lens, codes }
    }

    /// Encode; returns (bits, packed bytes).
    // entlint: allow(no-panic-on-untrusted) — encode path over trusted in-process data;
    // the code table is u8-indexed into a fixed 256-entry array
    pub fn encode(&self, data: &[u8]) -> (usize, Vec<u8>) {
        let mut out = Vec::new();
        let mut acc = 0u64;
        let mut nbits = 0u32;
        let mut total = 0usize;
        for &b in data {
            let (code, len) = self.codes[b as usize];
            debug_assert!(len > 0, "symbol {b} missing");
            acc = (acc << len) | code as u64;
            nbits += len as u32;
            total += len as usize;
            while nbits >= 8 {
                nbits -= 8;
                out.push((acc >> nbits) as u8);
            }
        }
        if nbits > 0 {
            out.push((acc << (8 - nbits)) as u8);
        }
        (total, out)
    }

    // entlint: allow(no-panic-on-untrusted) — offline-eval baseline decoding bytes produced
    // in-process by `encode` above; never fed container/network data (the serving path
    // decodes via `ans::rans`, which is fully checked)
    pub fn decode(&self, packed: &[u8], n_symbols: usize) -> Vec<u8> {
        // simple bit-by-bit canonical walk (baseline only; not hot path)
        let mut by_len: Vec<Vec<(u32, u8)>> = vec![Vec::new(); 33];
        for sym in 0..256usize {
            let (code, len) = self.codes[sym];
            if len > 0 {
                by_len[len as usize].push((code, sym as u8));
            }
        }
        let mut out = Vec::with_capacity(n_symbols);
        let mut code = 0u32;
        let mut len = 0usize;
        let mut bit_idx = 0usize;
        while out.len() < n_symbols {
            let byte = packed[bit_idx / 8];
            let bit = (byte >> (7 - bit_idx % 8)) & 1;
            bit_idx += 1;
            code = (code << 1) | bit as u32;
            len += 1;
            if let Some(&(_, sym)) = by_len[len].iter().find(|&&(c, _)| c == code) {
                out.push(sym);
                code = 0;
                len = 0;
            }
        }
        out
    }

    /// Average code length in bits/symbol over `data`.
    // entlint: allow(no-panic-on-untrusted) — u8-indexed read of a fixed 256-entry array
    pub fn mean_bits(&self, data: &[u8]) -> f64 {
        let total: usize = data.iter().map(|&b| self.lens[b as usize] as usize).sum();
        total as f64 / data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::entropy_of;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip() {
        let data = b"abracadabra abracadabra".to_vec();
        let h = Huffman::from_data(&data);
        let (_, packed) = h.encode(&data);
        assert_eq!(h.decode(&packed, data.len()), data);
    }

    #[test]
    fn kraft_inequality_holds() {
        let mut rng = Rng::new(5);
        let data: Vec<u8> = (0..3000)
            .map(|_| ((rng.normal().abs() * 15.0) as usize).min(255) as u8)
            .collect();
        let h = Huffman::from_data(&data);
        let kraft: f64 = h.lens.iter().filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-12, "{kraft}");
    }

    #[test]
    fn within_one_bit_of_entropy() {
        let mut rng = Rng::new(6);
        let data: Vec<u8> = (0..50_000)
            .map(|_| ((rng.normal().abs() * 10.0) as usize).min(255) as u8)
            .collect();
        let h = Huffman::from_data(&data);
        let mb = h.mean_bits(&data);
        let ent = entropy_of(&data);
        assert!(mb >= ent - 1e-9 && mb <= ent + 1.0, "mb={mb} H={ent}");
    }

    #[test]
    fn huffman_floor_is_one_bit_but_ans_is_not() {
        // the paper's motivating comparison: H(X) << 1
        let mut data = vec![0u8; 50_000];
        for i in 0..500 {
            data[i * 100] = 1;
        }
        let ent = entropy_of(&data);
        assert!(ent < 0.1);
        let h = Huffman::from_data(&data);
        assert!(h.mean_bits(&data) >= 1.0, "Huffman cannot go below 1 bit/sym");
        let bs = crate::ans::Bitstream::encode(&data, 1 << 18);
        let ans_bits = bs.payload.len() as f64 * 8.0 / data.len() as f64;
        assert!(ans_bits < 0.2, "ANS beats the Huffman floor: {ans_bits}");
    }

    #[test]
    fn single_symbol_alphabet() {
        let data = vec![9u8; 100];
        let h = Huffman::from_data(&data);
        let (bits, packed) = h.encode(&data);
        assert_eq!(bits, 100);
        assert_eq!(h.decode(&packed, 100), data);
    }
}
