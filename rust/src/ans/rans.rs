//! From-scratch rANS (range Asymmetric Numeral Systems, Duda 2013) —
//! the CPU analogue of the paper's nvCOMP GPU coder.
//!
//! Variant: 32-bit state, byte renormalization, 12-bit probability
//! resolution (M = 4096), N-way interleaved streams inside each chunk.
//! nvCOMP parallelizes across GPU blocks; we parallelize across 256 KiB
//! chunks (see `bitstream.rs`) and across the interleaved streams within
//! a chunk (instruction-level parallelism: the states carry no
//! dependency on each other, so the decoder sustains multiple symbol
//! decodes in flight per cycle).
//!
//! Invariants (checked by the proptest-style round-trip tests):
//!   * encode(decode(x)) == x for any byte sequence and any table built
//!     from its histogram
//!   * compressed size ~= cross_entropy(data, table) + O(streams) bytes

use crate::entropy::{histogram, normalize_freqs};

pub const PROB_BITS: u32 = 12;
pub const PROB_SCALE: u32 = 1 << PROB_BITS;
/// Lower bound of the normalized state interval.
const RANS_L: u32 = 1 << 23;
/// Number of interleaved states per chunk.
pub const N_STREAMS: usize = 4;

/// One decode-table entry: everything the inner loop needs for a slot in
/// a single 8-byte load (§Perf L3: replaces three dependent lookups).
#[derive(Clone, Copy)]
pub struct SlotEntry {
    pub sym: u8,
    pub freq: u16,
    pub cum: u16,
}

/// Frequency table + cumulative + slot->symbol lookup (the bitstream
/// "metadata" of paper Algorithm 1).
#[derive(Clone)]
pub struct FreqTable {
    pub freq: [u32; 256],
    pub cum: [u32; 257],
    /// 2^PROB_BITS packed entries (decode fast path).
    slots: Vec<SlotEntry>,
}

impl FreqTable {
    // entlint: allow(no-panic-on-untrusted) — table construction: the sum precondition is
    // checked by `rebuild`, which errors (not panics) on bad input
    pub fn from_freqs(freq: [u32; 256]) -> Self {
        let mut t = FreqTable {
            freq: [0u32; 256],
            cum: [0u32; 257],
            slots: vec![SlotEntry { sym: 0, freq: 0, cum: 0 }; PROB_SCALE as usize],
        };
        let built = t.rebuild(&freq);
        assert!(built.is_ok(), "frequencies must sum to 2^PROB_BITS");
        t
    }

    /// Rebuild this table in place from a new frequency array, reusing
    /// the slot storage — the alloc-free reuse path for per-step tail
    /// decode (`ans::kv_chunk`), where a fresh `from_freqs` per chunk
    /// would put a 4096-entry Vec on every decode step.
    // entlint: allow(no-panic-on-untrusted) — every index is u8-derived or bounded by
    // cum[256] == 2^12, checked before the slot fill; bad sums return Err
    // entlint: hot
    pub fn rebuild(&mut self, freq: &[u32; 256]) -> Result<(), String> {
        let mut cum = [0u32; 257];
        for i in 0..256 {
            cum[i + 1] = cum[i] + freq[i];
        }
        if cum[256] != PROB_SCALE {
            return Err("frequencies must sum to 2^PROB_BITS".into());
        }
        self.freq = *freq;
        self.cum = cum;
        debug_assert_eq!(self.slots.len(), PROB_SCALE as usize);
        for sym in 0..256 {
            for slot in cum[sym]..cum[sym + 1] {
                self.slots[slot as usize] =
                    SlotEntry { sym: sym as u8, freq: freq[sym] as u16, cum: cum[sym] as u16 };
            }
        }
        Ok(())
    }

    // entlint: allow(no-panic-on-untrusted) — writes one fixed index of a local [u32; 256]
    pub fn from_data(data: &[u8]) -> Self {
        if data.is_empty() {
            // degenerate table for empty streams: all mass on symbol 0
            let mut freq = [0u32; 256];
            freq[0] = PROB_SCALE;
            return Self::from_freqs(freq);
        }
        Self::from_freqs(normalize_freqs(&histogram(data), PROB_BITS))
    }

    // entlint: allow(no-panic-on-untrusted) — callers mask `slot` to PROB_SCALE-1 and the
    // slot table always holds exactly 2^12 entries
    #[inline]
    pub fn sym_at(&self, slot: u32) -> u8 {
        self.slots[slot as usize].sym
    }

    /// Serialized size (the per-bitstream metadata overhead): freqs are
    /// stored as 256 x u16.
    pub fn serialized_len() -> usize {
        512
    }

    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        for &f in &self.freq {
            out.extend_from_slice(&(f as u16).to_le_bytes());
        }
    }

    // entlint: allow(no-panic-on-untrusted) — all reads sit below the `len() < 512` guard
    pub fn deserialize(bytes: &[u8]) -> Result<Self, String> {
        if bytes.len() < 512 {
            return Err("freq table truncated".into());
        }
        let mut freq = [0u32; 256];
        let mut total = 0u64;
        for i in 0..256 {
            freq[i] = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]) as u32;
            total += freq[i] as u64;
        }
        if total != PROB_SCALE as u64 {
            return Err(format!("freq table sums to {total}, want {PROB_SCALE}"));
        }
        // A table can sum to 2^12 yet still be the *wrong* table for a
        // payload (e.g. freq 0 for a symbol the encoder used).  That
        // cannot be detected here without the payload; `decode_chunk`
        // catches it via its final-state/consumption checks instead of
        // silently mis-decoding.
        Ok(Self::from_freqs(freq))
    }
}

/// Encode one chunk of symbols with N interleaved rANS states.
/// Returns the compressed payload (head: 4 x u32 final states, then the
/// byte stream in *decode order*).
// entlint: allow(no-panic-on-untrusted) — encode path: input is trusted in-process data and
// every table access is u8-indexed into fixed 256/257-entry arrays
pub fn encode_chunk(symbols: &[u8], table: &FreqTable) -> Vec<u8> {
    // rANS encodes in reverse; stream i owns symbols[i], symbols[i+N], ...
    let mut states = [RANS_L; N_STREAMS];
    let mut out: Vec<u8> = Vec::with_capacity(symbols.len() / 2 + 16);

    // walk symbols backwards, rotating across streams so the decoder
    // (walking forwards) touches streams round-robin
    for (idx, &sym) in symbols.iter().enumerate().rev() {
        let st = idx % N_STREAMS;
        let f = table.freq[sym as usize];
        debug_assert!(f > 0, "symbol {sym} not in table");
        let mut x = states[st];
        // renormalize: emit low bytes while x too large for this freq
        let x_max = ((RANS_L >> PROB_BITS) << 8) * f;
        while x >= x_max {
            out.push((x & 0xFF) as u8);
            x >>= 8;
        }
        states[st] = ((x / f) << PROB_BITS) + (x % f) + table.cum[sym as usize];
    }

    // header: final states (decoder's initial states), then bytes reversed
    let mut payload = Vec::with_capacity(out.len() + 16);
    for st in states {
        payload.extend_from_slice(&st.to_le_bytes());
    }
    payload.extend(out.iter().rev());
    payload
}

/// Where decoded symbols land: a raw byte buffer (`decode_chunk_into`)
/// or, fused through a 256-entry dequant LUT, an f32 code buffer
/// (`decode_chunk_fused`).  Monomorphized away — each sink compiles to
/// a single store in the inner loop.
trait SymbolSink {
    fn put(&mut self, idx: usize, sym: u8);
}

struct ByteSink<'a>(&'a mut [u8]);

impl SymbolSink for ByteSink<'_> {
    // entlint: hot
    // entlint: allow(no-panic-on-untrusted) — idx < n_symbols == out.len() by the decode
    // loop bounds
    #[inline(always)]
    fn put(&mut self, idx: usize, sym: u8) {
        self.0[idx] = sym;
    }
}

struct FusedSink<'a> {
    out: &'a mut [f32],
    lut: &'a [f32; 256],
}

impl SymbolSink for FusedSink<'_> {
    // entlint: hot
    // entlint: allow(no-panic-on-untrusted) — idx < n_symbols == out.len() by the decode
    // loop bounds; the LUT is u8-indexed into a fixed 256-entry array
    #[inline(always)]
    fn put(&mut self, idx: usize, sym: u8) {
        self.out[idx] = self.lut[sym as usize];
    }
}

/// Parse the N_STREAMS initial states off a chunk payload header.
// entlint: hot
// entlint: allow(no-panic-on-untrusted) — reads sit below the `len() < 4*N_STREAMS` guard,
// and try_into on an exact 4-byte slice is infallible
#[inline]
fn read_states(payload: &[u8]) -> Result<([u32; N_STREAMS], &[u8]), String> {
    if payload.len() < 4 * N_STREAMS {
        return Err("chunk payload too short".into());
    }
    let mut states = [0u32; N_STREAMS];
    for (i, st) in states.iter_mut().enumerate() {
        *st = u32::from_le_bytes(payload[4 * i..4 * i + 4].try_into().unwrap());
    }
    Ok((states, &payload[4 * N_STREAMS..]))
}

/// Shared integrity epilogue: decoding is the exact inverse of
/// encoding, so a well-formed (payload, n_symbols, table) triple
/// consumes every input byte and returns every state to the encoder's
/// initial L.  Anything else — truncated/extended payload, a table
/// whose frequencies disagree with the one used at encode time
/// (including freq-0 symbols that were present in the data), or a wrong
/// symbol count — fails here instead of silently mis-decoding.
#[inline]
fn check_final(ip: usize, inp_len: usize, states: &[u32; N_STREAMS]) -> Result<(), String> {
    if ip != inp_len {
        return Err(format!("rans: {} unconsumed payload bytes (corrupt chunk)", inp_len - ip));
    }
    for (i, &x) in states.iter().enumerate() {
        if x != RANS_L {
            return Err(format!(
                "rans: stream {i} final state {x:#010x} != L (corrupt chunk or wrong freq table)"
            ));
        }
    }
    Ok(())
}

/// §Perf L3: the inner loop is unrolled over the 4 interleaved states
/// (no per-symbol modulo, 4 independent dependency chains in flight) and
/// each symbol costs a single packed SlotEntry load.  Byte pulls stay in
/// exact program order so the stream layout matches the encoder.
// entlint: hot
// entlint: allow(no-panic-on-untrusted) — slot is masked to PROB_SCALE-1 against the
// 2^12-entry slot table, tail streams index mod N_STREAMS, and renorm byte pulls go
// through get(); nothing here trusts the payload
#[inline(always)]
fn decode_core<S: SymbolSink>(
    payload: &[u8],
    n_symbols: usize,
    table: &FreqTable,
    sink: &mut S,
) -> Result<(), String> {
    let (states, inp) = read_states(payload)?;
    let mut ip = 0usize;
    let mask = PROB_SCALE - 1;
    let slots = &table.slots[..];

    macro_rules! step {
        ($x:expr, $idx:expr) => {{
            let slot = $x & mask;
            let e = slots[slot as usize];
            sink.put($idx, e.sym);
            let mut x = (e.freq as u32) * ($x >> PROB_BITS) + slot - e.cum as u32;
            while x < RANS_L {
                let b = *inp.get(ip).ok_or("rans: input exhausted")?;
                ip += 1;
                x = (x << 8) | b as u32;
            }
            $x = x;
        }};
    }

    let n4 = n_symbols - n_symbols % N_STREAMS;
    let [mut x0, mut x1, mut x2, mut x3] = states;
    let mut idx = 0usize;
    while idx < n4 {
        step!(x0, idx);
        step!(x1, idx + 1);
        step!(x2, idx + 2);
        step!(x3, idx + 3);
        idx += 4;
    }
    let mut tail_states = [x0, x1, x2, x3];
    for idx in n4..n_symbols {
        step!(tail_states[idx % N_STREAMS], idx);
    }
    check_final(ip, inp.len(), &tail_states)
}

/// Software-pipelined joint decode of two *independent* chunks: the 4
/// interleaved states of chunk A and the 4 of chunk B carry no
/// dependency on each other, so the main loop keeps 8 decode chains in
/// flight per iteration (the renorm byte pulls of each chunk stay in
/// exact program order against its own payload, so output is
/// byte-identical to decoding the chunks one after another).  When the
/// chunks differ in length the longer one drains on the plain 4-chain
/// loop.
// entlint: hot
// entlint: allow(no-panic-on-untrusted) — same bounds story as decode_core: masked slots,
// mod-N_STREAMS tails, get()-checked byte pulls
#[inline(always)]
fn decode_pair_core<S: SymbolSink>(
    a: (&[u8], usize, &mut S),
    b: (&[u8], usize, &mut S),
    table: &FreqTable,
) -> Result<(), String> {
    let (pa, na, sink_a) = a;
    let (pb, nb, sink_b) = b;
    let (st_a, inp_a) = read_states(pa)?;
    let (st_b, inp_b) = read_states(pb)?;
    let (mut ipa, mut ipb) = (0usize, 0usize);
    let mask = PROB_SCALE - 1;
    let slots = &table.slots[..];

    macro_rules! step_a {
        ($x:expr, $idx:expr) => {{
            let slot = $x & mask;
            let e = slots[slot as usize];
            sink_a.put($idx, e.sym);
            let mut x = (e.freq as u32) * ($x >> PROB_BITS) + slot - e.cum as u32;
            while x < RANS_L {
                let byte = *inp_a.get(ipa).ok_or("rans: input exhausted")?;
                ipa += 1;
                x = (x << 8) | byte as u32;
            }
            $x = x;
        }};
    }
    macro_rules! step_b {
        ($x:expr, $idx:expr) => {{
            let slot = $x & mask;
            let e = slots[slot as usize];
            sink_b.put($idx, e.sym);
            let mut x = (e.freq as u32) * ($x >> PROB_BITS) + slot - e.cum as u32;
            while x < RANS_L {
                let byte = *inp_b.get(ipb).ok_or("rans: input exhausted")?;
                ipb += 1;
                x = (x << 8) | byte as u32;
            }
            $x = x;
        }};
    }

    let n4a = na - na % N_STREAMS;
    let n4b = nb - nb % N_STREAMS;
    let joint = n4a.min(n4b);
    let [mut a0, mut a1, mut a2, mut a3] = st_a;
    let [mut b0, mut b1, mut b2, mut b3] = st_b;
    let mut idx = 0usize;
    while idx < joint {
        step_a!(a0, idx);
        step_b!(b0, idx);
        step_a!(a1, idx + 1);
        step_b!(b1, idx + 1);
        step_a!(a2, idx + 2);
        step_b!(b2, idx + 2);
        step_a!(a3, idx + 3);
        step_b!(b3, idx + 3);
        idx += 4;
    }

    let mut ia = joint;
    while ia < n4a {
        step_a!(a0, ia);
        step_a!(a1, ia + 1);
        step_a!(a2, ia + 2);
        step_a!(a3, ia + 3);
        ia += 4;
    }
    let mut tail_a = [a0, a1, a2, a3];
    for i in n4a..na {
        step_a!(tail_a[i % N_STREAMS], i);
    }

    let mut ib = joint;
    while ib < n4b {
        step_b!(b0, ib);
        step_b!(b1, ib + 1);
        step_b!(b2, ib + 2);
        step_b!(b3, ib + 3);
        ib += 4;
    }
    let mut tail_b = [b0, b1, b2, b3];
    for i in n4b..nb {
        step_b!(tail_b[i % N_STREAMS], i);
    }

    check_final(ipa, inp_a.len(), &tail_a)?;
    check_final(ipb, inp_b.len(), &tail_b)
}

/// Decode `n_symbols` from one chunk payload (allocating convenience
/// wrapper around `decode_chunk_into`).
pub fn decode_chunk(payload: &[u8], n_symbols: usize, table: &FreqTable) -> Result<Vec<u8>, String> {
    let mut out = vec![0u8; n_symbols];
    decode_chunk_into(payload, &mut out, table)?;
    Ok(out)
}

/// Decode `out.len()` symbols from one chunk payload straight into the
/// caller's slice — the allocation-free serving path.
// entlint: hot
pub fn decode_chunk_into(payload: &[u8], out: &mut [u8], table: &FreqTable) -> Result<(), String> {
    let n = out.len();
    decode_core(payload, n, table, &mut ByteSink(out))
}

/// Fused decode->dequant: inflate one chunk straight to f32 codes
/// through `lut`, with no intermediate symbol buffer.
// entlint: hot
pub fn decode_chunk_fused(
    payload: &[u8],
    out: &mut [f32],
    lut: &[f32; 256],
    table: &FreqTable,
) -> Result<(), String> {
    let n = out.len();
    decode_core(payload, n, table, &mut FusedSink { out, lut })
}

/// Decode two independent chunks in the 8-chain software-pipelined
/// joint loop (see `decode_pair_core`); outputs are byte-identical to
/// two `decode_chunk_into` calls.
// entlint: hot
pub fn decode_chunk_pair_into(
    payload_a: &[u8],
    out_a: &mut [u8],
    payload_b: &[u8],
    out_b: &mut [u8],
    table: &FreqTable,
) -> Result<(), String> {
    let (na, nb) = (out_a.len(), out_b.len());
    decode_pair_core(
        (payload_a, na, &mut ByteSink(out_a)),
        (payload_b, nb, &mut ByteSink(out_b)),
        table,
    )
}

/// Fused 8-chain pair decode: two chunks straight to f32 codes.
// entlint: hot
pub fn decode_chunk_pair_fused(
    payload_a: &[u8],
    out_a: &mut [f32],
    payload_b: &[u8],
    out_b: &mut [f32],
    lut: &[f32; 256],
    table: &FreqTable,
) -> Result<(), String> {
    let (na, nb) = (out_a.len(), out_b.len());
    decode_pair_core(
        (payload_a, na, &mut FusedSink { out: out_a, lut }),
        (payload_b, nb, &mut FusedSink { out: out_b, lut }),
        table,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::{cross_entropy_bits, entropy_of, histogram};
    use crate::tensor::Rng;

    fn skewed_data(n: usize, spread: f64, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| ((rng.normal().abs() * spread) as usize).min(255) as u8)
            .collect()
    }

    #[test]
    fn roundtrip_small() {
        let data = b"hello hello hello world".to_vec();
        let t = FreqTable::from_data(&data);
        let enc = encode_chunk(&data, &t);
        assert_eq!(decode_chunk(&enc, data.len(), &t).unwrap(), data);
    }

    #[test]
    fn roundtrip_empty_and_single() {
        let data = vec![42u8];
        let t = FreqTable::from_data(&data);
        let enc = encode_chunk(&data, &t);
        assert_eq!(decode_chunk(&enc, 1, &t).unwrap(), data);

        let empty: Vec<u8> = vec![];
        let t = FreqTable::from_data(&[1, 2, 3]);
        let enc = encode_chunk(&empty, &t);
        assert_eq!(decode_chunk(&enc, 0, &t).unwrap(), empty);
    }

    #[test]
    fn roundtrip_property_sweep() {
        // proptest-style sweep: sizes x skews x seeds
        for &n in &[2usize, 3, 5, 17, 100, 1000, 10_000] {
            for &spread in &[0.5f64, 3.0, 40.0] {
                for seed in 1..4u64 {
                    let data = skewed_data(n, spread, seed * 7 + n as u64);
                    let t = FreqTable::from_data(&data);
                    let enc = encode_chunk(&data, &t);
                    let dec = decode_chunk(&enc, n, &t).unwrap();
                    assert_eq!(dec, data, "n={n} spread={spread} seed={seed}");
                }
            }
        }
    }

    #[test]
    fn roundtrip_uniform_random() {
        let mut rng = Rng::new(77);
        let data: Vec<u8> = (0..50_000).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let t = FreqTable::from_data(&data);
        let enc = encode_chunk(&data, &t);
        assert_eq!(decode_chunk(&enc, data.len(), &t).unwrap(), data);
        // incompressible: size ~ n + header
        assert!(enc.len() as f64 > data.len() as f64 * 0.98);
    }

    #[test]
    fn compression_approaches_entropy() {
        for spread in [1.0f64, 5.0, 30.0] {
            let data = skewed_data(200_000, spread, 5);
            let h = entropy_of(&data);
            let t = FreqTable::from_data(&data);
            let enc = encode_chunk(&data, &t);
            let bits_per_sym = enc.len() as f64 * 8.0 / data.len() as f64;
            let ce = cross_entropy_bits(&histogram(&data), &t.freq, PROB_BITS);
            assert!(bits_per_sym <= ce + 0.02, "spread={spread}: {bits_per_sym} vs ce {ce}");
            assert!(bits_per_sym >= h - 0.01, "below entropy?! {bits_per_sym} vs {h}");
        }
    }

    #[test]
    fn sub_one_bit_regime() {
        // H < 1: the regime where Huffman is stuck at 1 bit/sym but ANS
        // is not (paper §2.1 "Entropy Coding")
        let mut data = vec![0u8; 100_000];
        for i in 0..2000 {
            data[i * 50] = 1 + (i % 5) as u8;
        }
        let h = entropy_of(&data);
        assert!(h < 0.3, "{h}");
        let t = FreqTable::from_data(&data);
        let enc = encode_chunk(&data, &t);
        let bps = enc.len() as f64 * 8.0 / data.len() as f64;
        assert!(bps < 0.35, "ANS must beat 1 bit/sym: got {bps} at H={h}");
    }

    #[test]
    fn into_pair_fused_match_scalar_sweep() {
        // proptest-style sweep: every decode variant (slice sink, fused
        // LUT sink, 8-chain pair loop) must be byte-identical to the
        // scalar `decode_chunk` for any size/skew/seed, including the
        // uneven-pair case where one chunk drains on the 4-chain loop
        let lut = core::array::from_fn::<f32, 256, _>(|i| i as f32 * 0.5 - 17.0);
        for &n in &[2usize, 3, 5, 17, 100, 1000, 10_000] {
            for seed in 1..3u64 {
                let a = skewed_data(n, 3.0, seed * 13 + n as u64);
                let b = skewed_data(n + n / 3 + 1, 8.0, seed * 13 + n as u64 + 100);
                let mut joint = a.clone();
                joint.extend_from_slice(&b);
                let t = FreqTable::from_data(&joint);
                let ea = encode_chunk(&a, &t);
                let eb = encode_chunk(&b, &t);
                let want_a = decode_chunk(&ea, a.len(), &t).unwrap();
                assert_eq!(want_a, a, "n={n} seed={seed}");

                let mut out_a = vec![0u8; a.len()];
                decode_chunk_into(&ea, &mut out_a, &t).unwrap();
                assert_eq!(out_a, a, "into n={n} seed={seed}");

                let mut pa = vec![0u8; a.len()];
                let mut pb = vec![0u8; b.len()];
                decode_chunk_pair_into(&ea, &mut pa, &eb, &mut pb, &t).unwrap();
                assert_eq!(pa, a, "pair A n={n} seed={seed}");
                assert_eq!(pb, b, "pair B n={n} seed={seed}");

                let want_fa: Vec<f32> = a.iter().map(|&s| lut[s as usize]).collect();
                let want_fb: Vec<f32> = b.iter().map(|&s| lut[s as usize]).collect();
                let mut fa = vec![0.0f32; a.len()];
                decode_chunk_fused(&ea, &mut fa, &lut, &t).unwrap();
                assert_eq!(fa, want_fa, "fused n={n} seed={seed}");

                let mut ga = vec![0.0f32; a.len()];
                let mut gb = vec![0.0f32; b.len()];
                decode_chunk_pair_fused(&ea, &mut ga, &eb, &mut gb, &lut, &t).unwrap();
                assert_eq!(ga, want_fa, "pair-fused A n={n} seed={seed}");
                assert_eq!(gb, want_fb, "pair-fused B n={n} seed={seed}");
            }
        }
    }

    #[test]
    fn pair_decode_corrupt_member_is_error_not_panic() {
        let a = skewed_data(3000, 4.0, 21);
        let b = skewed_data(2500, 4.0, 22);
        let mut joint = a.clone();
        joint.extend_from_slice(&b);
        let t = FreqTable::from_data(&joint);
        let ea = encode_chunk(&a, &t);
        let eb = encode_chunk(&b, &t);
        let mut oa = vec![0u8; a.len()];
        let mut ob = vec![0u8; b.len()];
        // truncate either member: error, never panic
        let cut = &ea[..ea.len() / 2];
        assert!(decode_chunk_pair_into(cut, &mut oa, &eb, &mut ob, &t).is_err());
        assert!(decode_chunk_pair_into(&ea, &mut oa, &eb[..8], &mut ob, &t).is_err());
        // extended member: unconsumed bytes
        let mut ext = eb.clone();
        ext.push(1);
        assert!(decode_chunk_pair_into(&ea, &mut oa, &ext, &mut ob, &t).is_err());
        // fused variant shares the same integrity checks
        let lut = [0.5f32; 256];
        let mut fa = vec![0.0f32; a.len()];
        let mut fb = vec![0.0f32; b.len()];
        assert!(decode_chunk_pair_fused(cut, &mut fa, &eb, &mut fb, &lut, &t).is_err());
        // and the untouched pair still round-trips
        decode_chunk_pair_into(&ea, &mut oa, &eb, &mut ob, &t).unwrap();
        assert_eq!(oa, a);
        assert_eq!(ob, b);
    }

    #[test]
    fn table_serialization_roundtrip() {
        let data = skewed_data(10_000, 10.0, 11);
        let t = FreqTable::from_data(&data);
        let mut buf = Vec::new();
        t.serialize_into(&mut buf);
        assert_eq!(buf.len(), FreqTable::serialized_len());
        let t2 = FreqTable::deserialize(&buf).unwrap();
        assert_eq!(t.freq, t2.freq);
        let enc = encode_chunk(&data, &t);
        assert_eq!(decode_chunk(&enc, data.len(), &t2).unwrap(), data);
    }

    #[test]
    fn table_rejects_bad_sum() {
        let mut buf = vec![0u8; 512];
        buf[0] = 1; // freq[0] = 1, total = 1 != 4096
        assert!(FreqTable::deserialize(&buf).is_err());
    }

    #[test]
    fn wrong_table_is_error_not_silent_misdecode() {
        // encode against a table that covers symbols {0..=5}; decode with
        // a valid-looking table (sums to 2^12) that gives those symbols
        // zero frequency — the satellite-bug scenario where a corrupt
        // FreqTable passes the sum check but belongs to different data
        let data = skewed_data(5000, 2.0, 17);
        let t = FreqTable::from_data(&data);
        let enc = encode_chunk(&data, &t);

        let mut wrong = [0u32; 256];
        wrong[200] = PROB_SCALE; // all mass on a symbol absent from `data`
        let wrong = FreqTable::from_freqs(wrong);
        assert!(decode_chunk(&enc, data.len(), &wrong).is_err());

        // a mildly perturbed table (still sums to 2^12) must also fail
        let mut freqs = t.freq;
        let hi = (0..256).max_by_key(|&s| freqs[s]).unwrap();
        let lo = (0..256).find(|&s| freqs[s] == 0).unwrap();
        freqs[hi] -= 1;
        freqs[lo] += 1;
        let perturbed = FreqTable::from_freqs(freqs);
        assert!(decode_chunk(&enc, data.len(), &perturbed).is_err());
    }

    #[test]
    fn extended_payload_is_error() {
        let data = skewed_data(1000, 3.0, 19);
        let t = FreqTable::from_data(&data);
        let mut enc = encode_chunk(&data, &t);
        enc.push(0xAB); // unconsumed trailing byte inside a chunk
        assert!(decode_chunk(&enc, data.len(), &t).is_err());
    }

    #[test]
    fn decode_with_truncated_payload_errors() {
        let data = skewed_data(1000, 2.0, 13);
        let t = FreqTable::from_data(&data);
        let enc = encode_chunk(&data, &t);
        let cut = &enc[..enc.len() / 2];
        // must error, not panic (decoder pulls more bytes than available)
        assert!(decode_chunk(cut, data.len(), &t).is_err());
        assert!(decode_chunk(&enc[..8], data.len(), &t).is_err());
    }
}
