//! Seeded schedule exploration — a mini-loom for the offline image.
//!
//! `sched_point()` is a shim the pool's workers call at every atomic or
//! lock acquisition.  In production builds it compiles to nothing.  In
//! test builds a seeded PRNG decides, per call, whether to inject a
//! `yield_now` or a micro-sleep — perturbing the thread interleaving so
//! a sweep over many seeds explores schedules CI would otherwise never
//! hit (the lost-wakeup/double-claim windows live exactly at these
//! acquisition points).
//!
//! Determinism contract (stated honestly): the *perturbation schedule*
//! replays exactly — thread `k`'s `j`-th `sched_point` takes the same
//! action for the same seed, because each thread derives its stream
//! from `(seed, own hit counter)` only, never from cross-thread state
//! or registration order.  The OS is still free to interleave
//! differently around those perturbations; what the sweep guarantees is
//! that the same pressure pattern is re-applied, which in practice
//! reproduces pool-level failures reliably.
//!
//! Sweep controls (read by the `schedule_sweep` test):
//! - `ENTQ_SCHED_SEEDS=N`  — number of seeds to sweep (default 200)
//! - `ENTQ_SCHED_SEED=S`   — replay exactly one seed (takes precedence)
//!
//! Every seed is printed before it runs, so a failing sweep's last
//! printed seed is the replay handle.

/// Schedule-exploration hook; a no-op outside test builds.
#[cfg(not(test))]
#[inline(always)]
pub fn sched_point() {}

/// Schedule-exploration hook; consults the active sweep seed.
#[cfg(test)]
pub fn sched_point() {
    test_impl::hit();
}

#[cfg(test)]
pub(crate) mod test_impl {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Active sweep seed; 0 = perturbation disabled.
    // Relaxed: the seed is a test-wide tuning knob read opportunistically at
    // perturbation points; no other memory is published through it
    static SEED: AtomicU64 = AtomicU64::new(0);

    thread_local! {
        static HITS: Cell<u64> = const { Cell::new(0) };
    }

    pub fn set_seed(seed: u64) {
        // Relaxed: see SEED above
        SEED.store(seed, Ordering::Relaxed);
    }

    fn splitmix64(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn hit() {
        // Relaxed: see SEED above
        let seed = SEED.load(Ordering::Relaxed);
        if seed == 0 {
            return;
        }
        let j = HITS.with(|h| {
            let v = h.get();
            h.set(v + 1);
            v
        });
        let r = splitmix64(seed ^ splitmix64(j));
        match r % 8 {
            // mostly yields: cheap, and a yield at an acquisition point is
            // exactly the "other thread wins the race" schedule
            0..=3 => std::thread::yield_now(),
            // occasional micro-sleep: widens the window enough for a whole
            // competing critical section to run
            4 => std::thread::sleep(std::time::Duration::from_micros((r >> 8) % 50)),
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_impl::set_seed;
    use crate::parallel::{pair_jobs, Pool, Service};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn splitmix64(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    fn seeds_to_run() -> Vec<u64> {
        if let Ok(s) = std::env::var("ENTQ_SCHED_SEED") {
            let seed: u64 = s.parse().expect("ENTQ_SCHED_SEED must be a u64");
            return vec![seed];
        }
        let n: u64 = std::env::var("ENTQ_SCHED_SEEDS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(200);
        // the sweep's seed list is itself fixed: seed i = splitmix64(i),
        // never wall time — so "seed 137 of the default sweep" names the
        // same schedule on every machine
        (1..=n).map(splitmix64).map(|s| s.max(1)).collect()
    }

    /// `par_map_indexed`: every index computed exactly once, results in
    /// index order, independent of interleaving.
    fn scenario_par_map_exactly_once() {
        let n = 48;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let out = Pool::new(4).par_map_indexed(n, |i| {
            crate::parallel::sched_point();
            hits[i].fetch_add(1, Ordering::Relaxed);
            i * 3
        });
        assert_eq!(out, (0..n).map(|i| i * 3).collect::<Vec<_>>(), "index order broken");
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "job {i} not run exactly once");
        }
    }

    /// `try_par_map_indexed`: the lowest-index error wins no matter
    /// which worker observes its error first.
    fn scenario_try_map_first_error() {
        let r = Pool::new(4).try_par_map_indexed(48, |i| {
            crate::parallel::sched_point();
            if i % 9 == 7 {
                Err(i)
            } else {
                Ok(i)
            }
        });
        assert_eq!(r, Err(7), "first-error determinism broken");
    }

    /// `try_for_each`: exactly-once job delivery plus lowest-index-error
    /// reporting under the owned-jobs queue.
    fn scenario_for_each_first_error() {
        let n = 48;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let r = Pool::new(4).try_for_each((0..n).collect::<Vec<_>>(), |i, job| {
            crate::parallel::sched_point();
            assert_eq!(i, job, "index/job pairing broken");
            hits[i].fetch_add(1, Ordering::Relaxed);
            if job == 7 || job == 29 {
                Err(job)
            } else {
                Ok(())
            }
        });
        assert_eq!(r, Err(7), "lowest-index error must win");
        for (i, h) in hits.iter().enumerate() {
            assert!(h.load(Ordering::Relaxed) <= 1, "job {i} ran twice");
        }
    }

    /// `Service` stop/abort race: a stop racing the worker's first loop
    /// iterations must still stop it, join cleanly, and never lose the
    /// worker's completed increments.
    fn scenario_service_stop_race() {
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let svc = Service::spawn("sched-sweep", move |stop| {
            while !stop.load(Ordering::SeqCst) {
                crate::parallel::sched_point();
                c2.fetch_add(1, Ordering::SeqCst);
            }
        });
        crate::parallel::sched_point();
        svc.stop().expect("service must join cleanly under any schedule");
        let settled = count.load(Ordering::SeqCst);
        // after stop() returns the worker is joined: no further writes
        assert_eq!(count.load(Ordering::SeqCst), settled, "worker wrote after join");
    }

    /// `pair_jobs` + `try_for_each` as the decoder drives it: pairing
    /// must preserve index order under any interleaving.
    fn scenario_paired_jobs_keep_order() {
        let jobs = pair_jobs((0..32usize).collect(), 4);
        let seen: Vec<AtomicUsize> = (0..32).map(|_| AtomicUsize::new(0)).collect();
        Pool::new(4)
            .try_for_each(jobs, |_, (a, b)| {
                crate::parallel::sched_point();
                seen[a].fetch_add(1, Ordering::Relaxed);
                if let Some(b) = b {
                    assert_eq!(b, a + 1, "pairing must keep adjacent index order");
                    seen[b].fetch_add(1, Ordering::Relaxed);
                }
                Ok::<(), ()>(())
            })
            .unwrap();
        assert!(seen.iter().all(|h| h.load(Ordering::Relaxed) == 1), "pair coverage broken");
    }

    #[test]
    fn schedule_sweep_holds_pool_invariants() {
        let seeds = seeds_to_run();
        println!("sched sweep: {} seed(s); replay any with ENTQ_SCHED_SEED=<seed>", seeds.len());
        for &seed in &seeds {
            println!("sched sweep: seed {seed}");
            set_seed(seed);
            let r = catch_unwind(AssertUnwindSafe(|| {
                scenario_par_map_exactly_once();
                scenario_try_map_first_error();
                scenario_for_each_first_error();
                scenario_service_stop_race();
                scenario_paired_jobs_keep_order();
            }));
            set_seed(0);
            if let Err(e) = r {
                let msg = e
                    .downcast_ref::<String>()
                    .map(String::as_str)
                    .or_else(|| e.downcast_ref::<&str>().copied())
                    .unwrap_or("non-string panic");
                panic!(
                    "schedule sweep failed at seed {seed}: {msg}\n\
                     replay exactly with: ENTQ_SCHED_SEED={seed} cargo test -q -p entquant --lib parallel::sched"
                );
            }
        }
    }

    #[test]
    fn sched_point_is_inert_without_a_seed() {
        // seed 0 = disabled: sched_point must be a pure no-op so unrelated
        // tests in this binary are never perturbed
        set_seed(0);
        for _ in 0..1000 {
            crate::parallel::sched_point();
        }
    }

    #[test]
    fn seed_list_is_reproducible() {
        // the default sweep's seed i is a pure function of i
        assert_eq!(splitmix64(1), splitmix64(1));
        assert_ne!(splitmix64(1), splitmix64(2));
    }
}
