//! Shared parallel subsystem — the embarrassing parallelism the paper's
//! "<30 min for a 70B model" claim rests on (per-layer RD optimization,
//! nvCOMP-style chunk-parallel ANS, §A.1 decode-ahead double buffering),
//! factored out of the former ad-hoc `std::thread::scope` + mutex-vec
//! sites in `store::pipeline`, `ans::bitstream`, and
//! `coordinator::engine`.
//!
//! Design points:
//! * **Scoped**: everything runs under `std::thread::scope`, so jobs may
//!   borrow from the caller's stack — no `'static` bounds, no channels
//!   of owned clones.
//! * **Chunked work stealing**: workers pull job indices from a shared
//!   atomic counter (or an owned-job queue), so skewed per-job cost
//!   (e.g. RD optimization on differently shaped layers) balances
//!   automatically.
//! * **Deterministic results**: `par_map_indexed` returns results in
//!   index order and `try_*` variants surface the lowest-index error,
//!   so `threads = N` is byte-identical to `threads = 1` on every path
//!   (the encode/decode identity tests in `tests/corruption.rs` pin
//!   this).
//! * **Graceful degeneration**: `threads <= 1` (or a single job) runs
//!   the plain sequential loop on the calling thread — no pool, no
//!   channels, no overhead on the single-core testbed.

//! * **Explored schedules**: workers call `sched::sched_point()` at
//!   every atomic/lock acquisition — a no-op in production, a seeded
//!   yield/delay injector under test, letting the schedule-exploration
//!   sweep (`parallel::sched`) rerun the pool suites across hundreds of
//!   perturbed interleavings with exact replay from a printed seed.

pub mod pool;
pub mod sched;

pub use pool::{decode_ahead, pair_jobs, stage_pipeline, Pool, Service, StageError};
pub use sched::sched_point;

/// Default worker count for `--threads`-style knobs: the
/// `ENTQUANT_THREADS` env var when set, else the machine's available
/// parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ENTQUANT_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
