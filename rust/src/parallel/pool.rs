//! The scoped pool: indexed fan-out (`par_map_indexed`), owned-job
//! fan-out (`try_for_each`), the one-ahead producer/consumer used by
//! the serving engine (`decode_ahead`), and the long-lived `Service`
//! worker loop the serve scheduler's driver runs on.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Uninhabited error type for the infallible `par_map_indexed` wrapper.
enum Never {}

/// A lightweight handle describing how wide to fan out.  Cheap to
/// construct per call site; actual OS threads are scoped to each call.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        Pool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over `0..n`, returning results in index order.  Jobs are
    /// distributed by work stealing; the output is independent of the
    /// thread count.
    pub fn par_map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match self.try_par_map_indexed(n, |i| Ok::<T, Never>(f(i))) {
            Ok(v) => v,
            Err(e) => match e {},
        }
    }

    /// Fallible `par_map_indexed`: on failure, returns the error with
    /// the lowest job index (deterministic error reporting; remaining
    /// jobs are abandoned as soon as any error is observed).
    pub fn try_par_map_indexed<T, E, F>(&self, n: usize, f: F) -> Result<Vec<T>, E>
    where
        T: Send,
        E: Send,
        F: Fn(usize) -> Result<T, E> + Sync,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }

        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<(usize, Result<T, E>)>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let (f, next, abort) = (&f, &next, &abort);
                scope.spawn(move || loop {
                    super::sched_point();
                    // Relaxed: abort is a latching advisory flag; a worker missing one update just runs one extra job, and scope join is the real synchronization point
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    // Relaxed: pure work-stealing ticket counter; fetch_add uniqueness is the only contract
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    super::sched_point();
                    if i >= n {
                        break;
                    }
                    let r = f(i);
                    if r.is_err() {
                        // Relaxed: latching advisory flag (see load above); result delivery goes through the channel
                        abort.store(true, Ordering::Relaxed);
                    }
                    super::sched_point();
                    if tx.send((i, r)).is_err() {
                        break;
                    }
                });
            }
            drop(tx); // the receive loop below ends when all workers exit

            let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
            slots.resize_with(n, || None);
            let mut first_err: Option<(usize, E)> = None;
            for (i, r) in rx {
                match r {
                    Ok(v) => slots[i] = Some(v),
                    Err(e) => {
                        let replace = match &first_err {
                            Some((j, _)) => i < *j,
                            None => true,
                        };
                        if replace {
                            first_err = Some((i, e));
                        }
                    }
                }
            }
            if let Some((_, e)) = first_err {
                return Err(e);
            }
            Ok(slots
                .into_iter()
                .map(|s| s.expect("pool: worker completed every job"))
                .collect())
        })
    }

    /// Run `f(index, job)` over owned jobs (e.g. disjoint `&mut` output
    /// slices paired with their chunk descriptors).  Jobs are handed out
    /// in index order; on failure the lowest-index error observed is
    /// returned and remaining jobs are abandoned.
    pub fn try_for_each<I, E, F>(&self, jobs: Vec<I>, f: F) -> Result<(), E>
    where
        I: Send,
        E: Send,
        F: Fn(usize, I) -> Result<(), E> + Sync,
    {
        let n = jobs.len();
        if n == 0 {
            return Ok(());
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            for (i, job) in jobs.into_iter().enumerate() {
                f(i, job)?;
            }
            return Ok(());
        }

        let mut stack: Vec<(usize, I)> = jobs.into_iter().enumerate().collect();
        stack.reverse(); // pop() hands out jobs in index order
        let queue = Mutex::new(stack);
        let abort = AtomicBool::new(false);
        let first_err: Mutex<Option<(usize, E)>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let (queue, abort, first_err, f) = (&queue, &abort, &first_err, &f);
                scope.spawn(move || loop {
                    super::sched_point();
                    // Relaxed: abort is a latching advisory flag; the queue mutex and scope join do the real synchronization
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    super::sched_point();
                    let job = queue.lock().unwrap().pop();
                    let Some((i, job)) = job else { break };
                    if let Err(e) = f(i, job) {
                        // Relaxed: latching advisory flag; first_err is published under its own mutex
                        abort.store(true, Ordering::Relaxed);
                        super::sched_point();
                        let mut slot = first_err.lock().unwrap();
                        let replace = match &*slot {
                            Some((j, _)) => i < *j,
                            None => true,
                        };
                        if replace {
                            *slot = Some((i, e));
                        }
                    }
                });
            }
        });
        match first_err.into_inner().unwrap() {
            Some((_, e)) => Err(e),
            None => Ok(()),
        }
    }
}

/// Chunk-pair scheduling: group jobs two-per-task when the pairing
/// still leaves every worker at least one task.  A worker that owns
/// both members of a pair can software-pipeline them (the decoder's
/// 8-chain joint rANS loop); with fewer jobs than `2 * threads`,
/// pairing would idle workers, so jobs stay single.  Pairs keep index
/// order, so downstream results are independent of the thread count.
pub fn pair_jobs<I>(jobs: Vec<I>, threads: usize) -> Vec<(I, Option<I>)> {
    let threads = threads.max(1);
    if jobs.len() < 2 * threads {
        return jobs.into_iter().map(|j| (j, None)).collect();
    }
    let mut out = Vec::with_capacity(jobs.len() / 2 + 1);
    let mut it = jobs.into_iter();
    while let Some(first) = it.next() {
        out.push((first, it.next()));
    }
    out
}

/// A long-lived named worker: unlike the scoped fan-outs above (which
/// join before returning), a `Service` owns an OS thread that runs the
/// caller's loop until `request_stop`/drop — the serve scheduler's
/// driver lives on one so request admission and decode stepping happen
/// off the submitting caller's thread.
///
/// The closure receives the stop flag and is responsible for polling it
/// between units of work (cooperative shutdown; nothing is interrupted
/// mid-step).  Drop requests stop and joins, so a `Service` can never
/// outlive the state its closure borrows via `Arc`s.
pub struct Service {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    pub fn spawn<F>(name: &str, f: F) -> Service
    where
        F: FnOnce(&AtomicBool) + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || f(&flag))
            .expect("spawning service worker");
        Service { stop, handle: Some(handle) }
    }

    /// Signal the worker loop to exit after its current unit of work.
    pub fn request_stop(&self) {
        super::sched_point();
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Stop and join.  A worker that panicked is reported as `Err` with
    /// the thread name (the panic itself already printed to stderr).
    pub fn stop(mut self) -> Result<(), String> {
        self.request_stop();
        match self.handle.take() {
            Some(h) => {
                let name = h.thread().name().unwrap_or("service").to_string();
                h.join().map_err(|_| format!("service worker '{name}' panicked"))
            }
            None => Ok(()),
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.request_stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One-ahead producer/consumer: `produce(i)` runs on a background worker
/// one step ahead of `consume(i, item)` on the calling thread — the
/// paper's §A.1 double-buffer scheme (block i+1's ANS decode overlaps
/// block i's compute).  `consume` always observes items in index order.
/// The first error (from either side) aborts the pipeline.
pub fn decode_ahead<T, E, P, C>(n: usize, produce: P, mut consume: C) -> Result<(), E>
where
    T: Send,
    E: Send,
    P: Fn(usize) -> Result<T, E> + Sync,
    C: FnMut(usize, T) -> Result<(), E>,
{
    if n == 0 {
        return Ok(());
    }
    std::thread::scope(|scope| {
        let (req_tx, req_rx) = mpsc::channel::<usize>();
        let (res_tx, res_rx) = mpsc::channel::<(usize, Result<T, E>)>();
        let produce = &produce;
        scope.spawn(move || {
            while let Ok(i) = req_rx.recv() {
                super::sched_point();
                if res_tx.send((i, produce(i))).is_err() {
                    break;
                }
            }
        });
        req_tx.send(0).ok();
        let mut result = Ok(());
        for i in 0..n {
            let (j, item) = match res_rx.recv() {
                Ok(x) => x,
                // worker gone early: its panic (if any) propagates when
                // the scope joins, so just stop consuming
                Err(_) => break,
            };
            debug_assert_eq!(j, i, "decode_ahead results must arrive in order");
            let item = match item {
                Ok(t) => t,
                Err(e) => {
                    result = Err(e);
                    break;
                }
            };
            // request the next block before consuming this one, so the
            // worker decodes ahead while the caller computes
            if i + 1 < n {
                req_tx.send(i + 1).ok();
            }
            if let Err(e) = consume(i, item) {
                result = Err(e);
                break;
            }
        }
        drop(req_tx); // unblocks the worker's recv loop
        result
    })
}

/// Error from a `stage_pipeline` run: which stage failed, on which
/// item, and the error itself.  When several stages fail concurrently
/// the error kept is the one with the lowest item index (deterministic
/// reporting, the same convention as `try_par_map_indexed`).
#[derive(Debug)]
pub struct StageError<E> {
    /// Index of the stage whose callback returned the error.
    pub stage: usize,
    /// Index of the item the stage was processing.
    pub item: usize,
    pub error: E,
}

/// Micro-batch stage pipeline: stream `items` through `ctxs.len()`
/// stages so stage `s` processes item `i` while stage `s+1` processes
/// item `i-1` — the cross-request pipeline-parallel decode step (shard
/// *i* computes micro-batch *b* while shard *i+1* computes micro-batch
/// *b−1*).
///
/// One scoped worker per stage (threads live only here, in
/// `parallel/`), each with exclusive ownership of its `C` for the whole
/// run — `C` only needs `Send`, never `Sync`, which is what lets the
/// serve layer hand each worker a `&mut` shard engine.  Items flow
/// stage-to-stage over channels in index order; each stage is a FIFO,
/// so the per-stage call sequence (and with it any per-stage fault
/// scripting) is deterministic regardless of thread interleaving, and
/// the returned items keep their original order.
///
/// On error the failing stage stops: upstream stages stop at their next
/// handoff, downstream stages drain what already arrived, and the
/// lowest-item error is returned.  Degenerate shapes (one stage or one
/// item) run inline on the caller's thread with the same stage/item
/// order.
pub fn stage_pipeline<C, T, E, F>(
    ctxs: Vec<C>,
    items: Vec<T>,
    f: F,
) -> Result<Vec<T>, StageError<E>>
where
    C: Send,
    T: Send,
    E: Send,
    F: Fn(usize, usize, &mut C, &mut T) -> Result<(), E> + Sync,
{
    let n_stages = ctxs.len();
    let n_items = items.len();
    if n_stages == 0 || n_items == 0 {
        return Ok(items);
    }
    if n_stages == 1 || n_items == 1 {
        let mut ctxs = ctxs;
        let mut items = items;
        for (i, item) in items.iter_mut().enumerate() {
            for (s, ctx) in ctxs.iter_mut().enumerate() {
                f(s, i, ctx, item).map_err(|error| StageError { stage: s, item: i, error })?;
            }
        }
        return Ok(items);
    }

    let first_err: Mutex<Option<StageError<E>>> = Mutex::new(None);
    let record = |stage: usize, item: usize, error: E| {
        let mut slot = first_err.lock().unwrap();
        let replace = match &*slot {
            Some(prev) => item < prev.item,
            None => true,
        };
        if replace {
            *slot = Some(StageError { stage, item, error });
        }
    };

    let mut slots: Vec<Option<T>> = Vec::with_capacity(n_items);
    slots.resize_with(n_items, || None);
    std::thread::scope(|scope| {
        // channel `s` feeds stage `s`; the final channel feeds the
        // collector.  Stage 0's queue is seeded with every item up
        // front (channels are unbounded; backpressure comes from each
        // stage being a single FIFO worker).
        let (tx0, rx0) = mpsc::channel::<(usize, T)>();
        for pair in items.into_iter().enumerate() {
            tx0.send(pair).expect("stage 0 input queue");
        }
        drop(tx0);
        let mut rx = rx0;
        for (s, mut ctx) in ctxs.into_iter().enumerate() {
            let (tx, next_rx) = mpsc::channel::<(usize, T)>();
            let in_rx = std::mem::replace(&mut rx, next_rx);
            let (f, record) = (&f, &record);
            scope.spawn(move || {
                while let Ok((i, mut item)) = in_rx.recv() {
                    super::sched_point();
                    if let Err(error) = f(s, i, &mut ctx, &mut item) {
                        record(s, i, error);
                        // dropping in_rx fails upstream handoffs, which
                        // stops the stages behind this one
                        break;
                    }
                    super::sched_point();
                    if tx.send((i, item)).is_err() {
                        break; // downstream stage stopped
                    }
                }
            });
        }
        // collect on the caller's thread; FIFO stages deliver in index
        // order, but place by index anyway so the output contract never
        // rests on channel ordering
        while let Ok((i, item)) = rx.recv() {
            slots[i] = Some(item);
        }
    });
    if let Some(err) = first_err.into_inner().unwrap() {
        return Err(err);
    }
    Ok(slots
        .into_iter()
        .map(|s| s.expect("stage pipeline: every item passed every stage"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn map_matches_scalar_for_any_thread_count() {
        let f = |i: usize| i * i + 7;
        let want: Vec<usize> = (0..100).map(f).collect();
        for threads in [1, 2, 3, 8, 64] {
            assert_eq!(Pool::new(threads).par_map_indexed(100, f), want, "threads={threads}");
        }
    }

    #[test]
    fn map_empty_and_single() {
        assert_eq!(Pool::new(4).par_map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(Pool::new(4).par_map_indexed(1, |i| i + 1), vec![1]);
    }

    #[test]
    fn zero_threads_degenerates_to_one() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::new(0).par_map_indexed(5, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn try_map_reports_lowest_index_error() {
        for threads in [1, 4] {
            let r = Pool::new(threads).try_par_map_indexed(64, |i| {
                if i % 10 == 3 {
                    Err(format!("bad {i}"))
                } else {
                    Ok(i)
                }
            });
            assert_eq!(r, Err("bad 3".to_string()), "threads={threads}");
        }
    }

    #[test]
    fn for_each_runs_every_job_exactly_once() {
        for threads in [1, 3, 16] {
            let n = 200;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            let jobs: Vec<usize> = (0..n).collect();
            Pool::new(threads)
                .try_for_each(jobs, |i, job| {
                    assert_eq!(i, job);
                    hits[i].fetch_add(1, Ordering::Relaxed);
                    Ok::<(), String>(())
                })
                .unwrap();
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={threads}");
        }
    }

    #[test]
    fn for_each_writes_disjoint_mut_slices() {
        let mut out = vec![0u8; 40];
        let jobs: Vec<(usize, &mut [u8])> = out.chunks_mut(10).enumerate().collect();
        Pool::new(4)
            .try_for_each(jobs, |_, (k, slice)| {
                slice.fill(k as u8 + 1);
                Ok::<(), String>(())
            })
            .unwrap();
        for (k, chunk) in out.chunks(10).enumerate() {
            assert!(chunk.iter().all(|&b| b == k as u8 + 1));
        }
    }

    #[test]
    fn for_each_propagates_error() {
        let r = Pool::new(4).try_for_each((0..50).collect::<Vec<_>>(), |_, job| {
            if job == 7 {
                Err("seven")
            } else {
                Ok(())
            }
        });
        assert_eq!(r, Err("seven"));
    }

    #[test]
    fn pair_jobs_pairs_only_when_workers_stay_busy() {
        // plenty of jobs: pair up (odd tail stays single)
        let t = pair_jobs((0..5).collect::<Vec<_>>(), 1);
        assert_eq!(t, vec![(0, Some(1)), (2, Some(3)), (4, None)]);
        let t = pair_jobs((0..8).collect::<Vec<_>>(), 4);
        assert_eq!(t.len(), 4);
        assert!(t.iter().all(|(_, snd)| snd.is_some()));
        // too few jobs per worker: stay single so all workers get one
        let t = pair_jobs((0..5).collect::<Vec<_>>(), 3);
        assert_eq!(t, (0..5).map(|i| (i, None)).collect::<Vec<_>>());
        // degenerate inputs
        assert_eq!(pair_jobs(Vec::<u8>::new(), 4), vec![]);
        assert_eq!(pair_jobs(vec![9], 0), vec![(9, None)]);
    }

    #[test]
    fn decode_ahead_consumes_in_order() {
        for n in [0usize, 1, 2, 9] {
            let mut seen = Vec::new();
            decode_ahead(
                n,
                |i| Ok::<usize, String>(i * 2),
                |i, item| {
                    assert_eq!(item, i * 2);
                    seen.push(i);
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn decode_ahead_producer_error_stops_pipeline() {
        let consumed = AtomicUsize::new(0);
        let r = decode_ahead(
            10,
            |i| if i == 3 { Err(format!("produce {i}")) } else { Ok(i) },
            |_, _| {
                consumed.fetch_add(1, Ordering::Relaxed);
                Ok(())
            },
        );
        assert_eq!(r, Err("produce 3".to_string()));
        assert_eq!(consumed.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn decode_ahead_consumer_error_stops_pipeline() {
        let r = decode_ahead(
            10,
            |i| Ok::<usize, String>(i),
            |i, _| if i == 2 { Err("consume 2".to_string()) } else { Ok(()) },
        );
        assert_eq!(r, Err("consume 2".to_string()));
    }

    #[test]
    fn service_runs_until_stopped() {
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let svc = Service::spawn("test-service", move |stop| {
            while !stop.load(Ordering::SeqCst) {
                c2.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        });
        // the loop must actually be running in the background
        let t0 = std::time::Instant::now();
        while count.load(Ordering::SeqCst) < 3 {
            assert!(t0.elapsed().as_secs() < 10, "service loop never ran");
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
        svc.stop().unwrap();
    }

    #[test]
    fn service_drop_joins_cleanly() {
        let done = Arc::new(AtomicUsize::new(0));
        let d2 = Arc::clone(&done);
        {
            let _svc = Service::spawn("drop-service", move |stop| {
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
                d2.store(1, Ordering::SeqCst);
            });
            // drop at end of scope must request stop and join
        }
        assert_eq!(done.load(Ordering::SeqCst), 1, "drop must stop + join the worker");
    }

    #[test]
    fn service_stop_reports_panic() {
        let svc = Service::spawn("panic-service", |_| panic!("worker died"));
        let err = svc.stop().unwrap_err();
        assert!(err.contains("panic"), "{err}");
    }

    #[test]
    fn stage_pipeline_applies_every_stage_in_order() {
        for (n_stages, n_items) in [(1usize, 5usize), (3, 1), (3, 8), (4, 4)] {
            let ctxs: Vec<usize> = (0..n_stages).collect();
            let items: Vec<Vec<usize>> = (0..n_items).map(|i| vec![i]).collect();
            let out = stage_pipeline(ctxs, items, |s, i, ctx, item| {
                assert_eq!(*ctx, s, "each worker owns its own context");
                assert_eq!(item[0], i, "items keep their identity through stages");
                item.push(s);
                Ok::<(), String>(())
            })
            .unwrap();
            for (i, item) in out.iter().enumerate() {
                let mut want = vec![i];
                want.extend(0..n_stages);
                assert_eq!(item, &want, "stages={n_stages} items={n_items}");
            }
        }
    }

    #[test]
    fn stage_pipeline_stages_are_fifo_and_contexts_exclusive() {
        // each context tracks the next item index it expects; the stage
        // mutates it with no synchronization at all — exclusivity and
        // per-stage FIFO order are the contract being pinned
        let n_items = 16usize;
        let out = stage_pipeline(vec![0usize; 3], (0..n_items).collect(), |s, i, next, item| {
            assert_eq!(i, *next, "stage {s} must see items in FIFO order");
            *next += 1;
            *item += 1;
            Ok::<(), String>(())
        })
        .unwrap();
        assert_eq!(out, (3..n_items + 3).collect::<Vec<_>>());
    }

    #[test]
    fn stage_pipeline_reports_failing_stage_and_item() {
        let r = stage_pipeline(vec![(); 3], (0..10usize).collect(), |s, i, _, item| {
            if s == 1 && i == 4 {
                Err(format!("stage {s} item {i}"))
            } else {
                *item += 1;
                Ok(())
            }
        });
        let err = r.unwrap_err();
        assert_eq!((err.stage, err.item), (1, 4));
        assert_eq!(err.error, "stage 1 item 4");
    }

    #[test]
    fn stage_pipeline_degenerate_shapes_run_inline() {
        let out = stage_pipeline(vec![1usize, 2, 3], vec![10usize], |_, _, c, item| {
            *item += *c;
            Ok::<(), String>(())
        })
        .unwrap();
        assert_eq!(out, vec![16]);
        let none: Vec<u8> = Vec::new();
        assert!(stage_pipeline(vec![(); 3], none, |_, _, _, _: &mut u8| Ok::<(), String>(()))
            .unwrap()
            .is_empty());
        let out = stage_pipeline(Vec::<()>::new(), vec![5u8], |_, _, _, _| Ok::<(), String>(()))
            .unwrap();
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn map_overlaps_work_across_threads() {
        // not a timing assertion (CI varies); just exercises real
        // contention: many jobs, shared state behind atomics only
        let total = AtomicUsize::new(0);
        let out = Pool::new(8).par_map_indexed(1000, |i| {
            total.fetch_add(i, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 1000);
        assert_eq!(total.load(Ordering::Relaxed), 1000 * 999 / 2);
    }
}
