//! Deterministic fault injection for the serving runtime — the test
//! infrastructure behind the shard-failure reroute path.
//!
//! A `FaultPlan` scripts *where* engine errors strike: each
//! `FaultScript` names a `(shard, step, block)` coordinate, where
//! `step` counts that shard's decode steps (bursts of `block_d_*`
//! executable calls) and `block` picks the call within the step.  A
//! `FaultRuntime` arms one shard's `Runtime` with a shared plan
//! (`Runtime::with_fault`): every `call` is checked first, and a
//! matching coordinate fails exactly once with an `injected fault`
//! error — indistinguishable from a real runtime/engine failure to
//! everything above it, but perfectly reproducible.
//!
//! Plans are either scripted explicitly or generated from a seed
//! (`FaultPlan::seeded`), so a failing fault-tolerance test can be
//! replayed by printing its seed.  `fail_next_prefill` additionally
//! arms a one-shot fault on a shard's next `block_p_*` call, covering
//! the batch-formation recovery path.
//!
//! Step counting is frozen at arm time (`blocks_owned`): after a
//! reroute the surviving engine owns more blocks, so script further
//! injections against pre-reroute coordinates only.

use crate::tensor::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// One scripted injection: fail shard `shard`'s decode call for block
/// `block` (shard-local index) of its `step`-th decode step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultScript {
    pub shard: usize,
    pub step: usize,
    pub block: usize,
}

/// The shared injection schedule: scripted decode faults plus optional
/// one-shot prefill and splice faults, each firing at most once.
pub struct FaultPlan {
    scripts: Mutex<Vec<(FaultScript, bool)>>,
    prefill_shards: Mutex<Vec<usize>>,
    splice_shards: Mutex<Vec<usize>>,
    fired: AtomicUsize,
}

impl FaultPlan {
    pub fn scripted(scripts: Vec<FaultScript>) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            scripts: Mutex::new(scripts.into_iter().map(|s| (s, false)).collect()),
            prefill_shards: Mutex::new(Vec::new()),
            splice_shards: Mutex::new(Vec::new()),
            fired: AtomicUsize::new(0),
        })
    }

    /// A reproducible random plan: `n_faults` coordinates drawn from
    /// `shard < n_shards`, `step < max_step`, `block < max_block`.
    /// Print the seed on failure and the run replays exactly.
    pub fn seeded(
        seed: u64,
        n_shards: usize,
        max_step: usize,
        max_block: usize,
        n_faults: usize,
    ) -> Arc<FaultPlan> {
        let mut rng = Rng::new(seed);
        let scripts = (0..n_faults)
            .map(|_| FaultScript {
                shard: rng.below(n_shards.max(1)),
                step: rng.below(max_step.max(1)),
                block: rng.below(max_block.max(1)),
            })
            .collect();
        FaultPlan::scripted(scripts)
    }

    /// Arm a one-shot fault on `shard`'s next prefill block call.
    pub fn fail_next_prefill(&self, shard: usize) {
        self.prefill_shards.lock().unwrap().push(shard);
    }

    /// Arm a one-shot fault on `shard`'s next reroute splice
    /// (`ServingEngine::reopen_blocks` probes before touching state),
    /// covering the mid-recovery failure path: the splice must abort
    /// cleanly and leave the engine serving its old range.
    pub fn fail_next_splice(&self, shard: usize) {
        self.splice_shards.lock().unwrap().push(shard);
    }

    /// How many injections have fired so far (tests assert the script
    /// actually ran).
    pub fn fired(&self) -> usize {
        // Relaxed: monotonic injection counter read by test assertions; no ordering contract
        self.fired.load(Ordering::Relaxed)
    }

    fn fire_decode(&self, shard: usize, step: usize, block: usize) -> bool {
        let mut scripts = self.scripts.lock().unwrap();
        for (s, done) in scripts.iter_mut() {
            if !*done && s.shard == shard && s.step == step && s.block == block {
                *done = true;
                // Relaxed: monotonic counter; the script slot itself is guarded by the mutex above
                self.fired.fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        false
    }

    fn fire_prefill(&self, shard: usize) -> bool {
        Self::fire_one_shot(&self.prefill_shards, &self.fired, shard)
    }

    fn fire_splice(&self, shard: usize) -> bool {
        Self::fire_one_shot(&self.splice_shards, &self.fired, shard)
    }

    fn fire_one_shot(armed: &Mutex<Vec<usize>>, fired: &AtomicUsize, shard: usize) -> bool {
        let mut shards = armed.lock().unwrap();
        if let Some(i) = shards.iter().position(|&s| s == shard) {
            shards.remove(i);
            // Relaxed: monotonic counter; the armed-shard list is guarded by the mutex above
            fired.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }
}

/// Arms one shard's runtime with a shared `FaultPlan`: wraps the call
/// path (`Runtime::with_fault`) and converts scripted coordinates into
/// injected errors.  Wraps the native executor in the tests, but is
/// backend-agnostic — the check runs before dispatch.
pub struct FaultRuntime {
    plan: Arc<FaultPlan>,
    shard: usize,
    /// blocks this shard served at arm time; decode step index =
    /// block_d calls seen / blocks_owned
    blocks_owned: usize,
    block_d_calls: AtomicUsize,
}

impl FaultRuntime {
    pub fn new(plan: Arc<FaultPlan>, shard: usize, blocks_owned: usize) -> FaultRuntime {
        FaultRuntime {
            plan,
            shard,
            blocks_owned: blocks_owned.max(1),
            block_d_calls: AtomicUsize::new(0),
        }
    }

    /// Called by `Runtime::call` before dispatch; `Err` = injected.
    pub(crate) fn check(&self, name: &str) -> anyhow::Result<()> {
        if name.starts_with("block_d_") {
            // Relaxed: per-runtime call counter; single writer path, value only feeds step/block arithmetic here
            let idx = self.block_d_calls.fetch_add(1, Ordering::Relaxed);
            let (step, block) = (idx / self.blocks_owned, idx % self.blocks_owned);
            if self.plan.fire_decode(self.shard, step, block) {
                anyhow::bail!(
                    "injected fault: shard {} step {step} block {block}",
                    self.shard
                );
            }
        } else if name.starts_with("block_p_") && self.plan.fire_prefill(self.shard) {
            anyhow::bail!("injected prefill fault: shard {}", self.shard);
        } else if name.starts_with("splice") && self.plan.fire_splice(self.shard) {
            anyhow::bail!("injected splice fault: shard {}", self.shard);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_decode_fault_fires_exactly_once_at_its_coordinate() {
        let plan = FaultPlan::scripted(vec![FaultScript { shard: 1, step: 2, block: 1 }]);
        let wrong_shard = FaultRuntime::new(Arc::clone(&plan), 0, 3);
        let armed = FaultRuntime::new(Arc::clone(&plan), 1, 3);
        // shard 0 never matches, however many steps pass
        for _ in 0..12 {
            wrong_shard.check("block_d_b2_c24").unwrap();
        }
        // shard 1: steps 0 and 1 (3 block calls each) pass, then step 2
        // fails at block 1 only, and never again
        let mut errors = 0;
        for call in 0..9 {
            if armed.check("block_d_b2_c24").is_err() {
                errors += 1;
                assert_eq!(call, 2 * 3 + 1, "fired at the wrong call index");
            }
        }
        assert_eq!(errors, 1);
        assert_eq!(plan.fired(), 1);
        for _ in 0..9 {
            armed.check("block_d_b2_c24").unwrap();
        }
        assert_eq!(plan.fired(), 1);
    }

    #[test]
    fn prefill_fault_is_one_shot_and_per_shard() {
        let plan = FaultPlan::scripted(Vec::new());
        plan.fail_next_prefill(0);
        let s0 = FaultRuntime::new(Arc::clone(&plan), 0, 2);
        let s1 = FaultRuntime::new(Arc::clone(&plan), 1, 2);
        s1.check("block_p_b4_s16").unwrap(); // other shard unaffected
        s0.check("embed_p_b4_s16").unwrap(); // only block_p triggers
        assert!(s0.check("block_p_b4_s16").is_err());
        s0.check("block_p_b4_s16").unwrap(); // one-shot
        assert_eq!(plan.fired(), 1);
    }

    #[test]
    fn splice_fault_is_one_shot_and_per_shard() {
        let plan = FaultPlan::scripted(Vec::new());
        plan.fail_next_splice(1);
        let s0 = FaultRuntime::new(Arc::clone(&plan), 0, 2);
        let s1 = FaultRuntime::new(Arc::clone(&plan), 1, 2);
        s0.check("splice_reopen").unwrap(); // other shard unaffected
        s1.check("block_d_b1_c8").unwrap(); // only splice probes trigger
        assert!(s1.check("splice_reopen").is_err());
        s1.check("splice_reopen").unwrap(); // one-shot
        assert_eq!(plan.fired(), 1);
    }

    #[test]
    fn seeded_plans_are_reproducible_and_in_range() {
        let a = FaultPlan::seeded(9, 4, 20, 3, 5);
        let b = FaultPlan::seeded(9, 4, 20, 3, 5);
        let (sa, sb) = (a.scripts.lock().unwrap(), b.scripts.lock().unwrap());
        assert_eq!(sa.len(), 5);
        for ((x, _), (y, _)) in sa.iter().zip(sb.iter()) {
            assert_eq!(x, y, "same seed must script the same faults");
            assert!(x.shard < 4 && x.step < 20 && x.block < 3);
        }
    }
}
