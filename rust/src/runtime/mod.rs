//! Serving runtime — executes the per-block serving functions either on
//! the PJRT CPU client (HLO-text artifacts emitted by
//! python/compile/aot.py) or on the built-in native CPU executor
//! (`native`), which implements the same executables in pure Rust.
//!
//! PJRT pattern follows /opt/xla-example/load_hlo: HloModuleProto::
//! from_text -> XlaComputation -> client.compile -> execute.
//! Executables are compiled lazily on first use and cached for the
//! lifetime of the runtime (one compiled executable per model variant,
//! as the paper's Marlin-kernel deployment does per dtype/shape).
//!
//! When the PJRT client is unavailable (this image vendors a
//! compile-time `xla` stub), `Runtime::new` degrades to the native
//! executor instead of failing, and `Runtime::native` builds a runtime
//! from an in-memory `Manifest::synthetic` with no artifacts at all —
//! the path CI's serving/serve tests and benches run on.

pub mod fault;
pub mod native;

use crate::store::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

pub struct ExecSpec {
    pub name: String,
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

pub struct Manifest {
    pub serve_size: String,
    pub config: crate::model::Config,
    pub prefill_slots: Vec<(usize, usize)>,
    pub decode_slots: Vec<(usize, usize)>,
    pub executables: Vec<ExecSpec>,
}

impl Manifest {
    /// An in-memory manifest for the native executor: no files, no
    /// executable specs (the native backend derives every shape from
    /// its inputs).  Slot tables are the caller's to choose; serving
    /// code only requires that a decode slot exists for every prefill
    /// batch size it uses.
    pub fn synthetic(
        config: crate::model::Config,
        prefill_slots: Vec<(usize, usize)>,
        decode_slots: Vec<(usize, usize)>,
    ) -> Manifest {
        Manifest {
            serve_size: "synthetic".to_string(),
            config,
            prefill_slots,
            decode_slots,
            executables: Vec::new(),
        }
    }

    pub fn load(artifacts_dir: &str) -> Result<Self> {
        let path = format!("{artifacts_dir}/manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        let v = json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let config = crate::model::Config::from_json(v.get("config").ok_or(anyhow!("config"))?)
            .map_err(|e| anyhow!(e))?;
        let slots = |key: &str| -> Result<Vec<(usize, usize)>> {
            v.get(key)
                .and_then(Value::as_array)
                .ok_or(anyhow!("{key}"))?
                .iter()
                .map(|s| {
                    let a = s.f64_array().ok_or(anyhow!("slot"))?;
                    Ok((a[0] as usize, a[1] as usize))
                })
                .collect()
        };
        let tensor_specs = |arr: &Value| -> Vec<TensorSpec> {
            arr.as_array()
                .map(|a| {
                    a.iter()
                        .map(|t| TensorSpec {
                            shape: t
                                .get("shape")
                                .and_then(Value::f64_array)
                                .unwrap_or_default()
                                .iter()
                                .map(|&x| x as usize)
                                .collect(),
                            dtype: t.get("dtype").and_then(Value::as_str).unwrap_or("f32").into(),
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        let mut executables = Vec::new();
        for e in v.get("executables").and_then(Value::as_array).ok_or(anyhow!("executables"))? {
            executables.push(ExecSpec {
                name: e.get("name").and_then(Value::as_str).ok_or(anyhow!("name"))?.into(),
                path: e.get("path").and_then(Value::as_str).ok_or(anyhow!("path"))?.into(),
                inputs: tensor_specs(e.get("inputs").ok_or(anyhow!("inputs"))?),
                outputs: tensor_specs(e.get("outputs").ok_or(anyhow!("outputs"))?),
            });
        }
        Ok(Manifest {
            serve_size: v.get("serve_size").and_then(Value::as_str).unwrap_or("M").into(),
            config,
            prefill_slots: slots("prefill_slots")?,
            decode_slots: slots("decode_slots")?,
            executables,
        })
    }
}

/// A host-side tensor flowing in/out of PJRT executables.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    /// `len` f32s at `off` inside a shared (arena-recycled) buffer:
    /// per-layer views of a decoded block alias one block buffer, so
    /// cloning is an Arc bump and the serving arena reclaims the
    /// buffer once every view has been dropped.
    F32View { data: Arc<Vec<f32>>, off: usize, len: usize, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

/// Logical equality: f32 tensors compare by (dims, visible window), so
/// an owned `F32` and an arena-backed `F32View` with the same contents
/// are equal, and views never compare their out-of-window buffer tails.
impl PartialEq for HostTensor {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (HostTensor::I32 { data: a, dims: da }, HostTensor::I32 { data: b, dims: db }) => {
                a == b && da == db
            }
            (HostTensor::I32 { .. }, _) | (_, HostTensor::I32 { .. }) => false,
            _ => self.dims() == other.dims() && self.as_f32() == other.as_f32(),
        }
    }
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>().max(1));
        HostTensor::F32 { data, dims: dims.to_vec() }
    }

    /// Zero-copy view into a shared f32 buffer (serving arena path).
    pub fn f32_view(data: Arc<Vec<f32>>, off: usize, len: usize, dims: &[usize]) -> Self {
        assert_eq!(len, dims.iter().product::<usize>().max(1));
        assert!(off + len <= data.len(), "view {off}+{len} outside buffer of {}", data.len());
        HostTensor::F32View { data, off, len, dims: dims.to_vec() }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>().max(1));
        HostTensor::I32 { data, dims: dims.to_vec() }
    }

    /// A zero-element, zero-allocation f32 placeholder — what
    /// `mem::replace` leaves behind when a hot path moves an owned
    /// tensor into an executor input list instead of cloning it.
    pub fn empty() -> Self {
        HostTensor::F32 { data: Vec::new(), dims: Vec::new() }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { data: vec![v], dims: vec![] }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            HostTensor::F32View { data, off, len, .. } => &data[*off..*off + *len],
            _ => panic!("not f32"),
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. } => dims,
            HostTensor::F32View { dims, .. } => dims,
            HostTensor::I32 { dims, .. } => dims,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { .. } | HostTensor::F32View { .. } => {
                xla::Literal::vec1(self.as_f32())
            }
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        let dims = self.dims();
        Ok(if dims.is_empty() {
            lit.reshape(&[])?
        } else {
            lit.reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?
        })
    }

    fn from_literal(lit: &xla::Literal, spec_dims: Vec<usize>) -> Result<Self> {
        // outputs of our artifacts are f32
        let data = lit.to_vec::<f32>()?;
        Ok(HostTensor::F32 { data, dims: spec_dims })
    }
}

/// Which engine actually executes a `call`.
enum Backend {
    Pjrt(xla::PjRtClient),
    Native(native::NativeExec),
}

/// The serving runtime: backend + lazily compiled executable cache
/// (PJRT only; the native executor has nothing to compile).
pub struct Runtime {
    backend: Backend,
    artifacts_dir: String,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// wall time spent in compile (reported by the CLI)
    pub compile_s: RefCell<f64>,
    /// scripted fault injector (tests / fault drills); checked before
    /// every dispatch
    fault: Option<fault::FaultRuntime>,
}

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = match xla::PjRtClient::cpu() {
            Ok(client) => Backend::Pjrt(client),
            // the vendored stub (or a missing plugin) degrades to the
            // native executor rather than refusing to serve
            Err(_) => Backend::Native(native::NativeExec::new(manifest.config.n_heads)),
        };
        Ok(Runtime {
            backend,
            artifacts_dir: artifacts_dir.to_string(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_s: RefCell::new(0.0),
            fault: None,
        })
    }

    /// A native-executor runtime over an in-memory manifest — no
    /// artifacts directory, no PJRT.  This is how the serve subsystem's
    /// tests and benches run the full engine stack in CI.
    pub fn native(manifest: Manifest) -> Self {
        let backend = Backend::Native(native::NativeExec::new(manifest.config.n_heads));
        Runtime {
            backend,
            artifacts_dir: String::new(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_s: RefCell::new(0.0),
            fault: None,
        }
    }

    /// Arm this runtime with a scripted fault injector (see
    /// `runtime::fault`): every subsequent `call` consults the plan
    /// first and fails with an `injected fault` error at scripted
    /// coordinates.
    pub fn with_fault(mut self, fault: fault::FaultRuntime) -> Runtime {
        self.fault = Some(fault);
        self
    }

    /// Consult the armed fault injector (if any) with a synthetic probe
    /// name — lets non-executable paths (e.g. the reroute splice in
    /// `ServingEngine::reopen_blocks`) take scripted faults too.  A
    /// no-op without an injector.
    pub fn fault_probe(&self, name: &str) -> Result<()> {
        match &self.fault {
            Some(f) => f.check(name),
            None => Ok(()),
        }
    }

    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native(_))
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Pjrt(client) => client.platform_name(),
            Backend::Native(_) => "native-cpu".to_string(),
        }
    }

    fn spec(&self, name: &str) -> Result<&ExecSpec> {
        self.manifest
            .executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("unknown executable {name}"))
    }

    /// Ensure an executable is compiled (warmup path; no-op on the
    /// native backend, which has nothing to compile).
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        let client = match &self.backend {
            Backend::Pjrt(client) => client,
            Backend::Native(_) => return Ok(()),
        };
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.spec(name)?;
        let path = format!("{}/{}", self.artifacts_dir, spec.path);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        *self.compile_s.borrow_mut() += t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute by name.  Inputs must match the manifest spec; outputs are
    /// returned as host tensors (jax lowers with return_tuple=True, so
    /// the single result literal is a tuple to destructure).  The native
    /// backend validates arity and shapes itself from the inputs.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if let Some(fault) = &self.fault {
            fault.check(name)?;
        }
        if let Backend::Native(exec) = &self.backend {
            return exec.call(name, inputs);
        }
        self.ensure_compiled(name)?;
        let spec = self.spec(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: {} inputs given, {} expected", inputs.len(), spec.inputs.len());
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("sync: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: {} outputs, {} expected", parts.len(), spec.outputs.len());
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(l, os)| HostTensor::from_literal(l, os.shape.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = crate::artifacts_dir();
        if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            eprintln!("artifacts missing; run `make artifacts` (skipping)");
            return None;
        }
        Some(Runtime::new(&dir).expect("runtime"))
    }

    #[test]
    fn f32_view_reads_its_window() {
        let buf = Arc::new((0..12).map(|i| i as f32).collect::<Vec<f32>>());
        let v = HostTensor::f32_view(Arc::clone(&buf), 4, 6, &[2, 3]);
        assert_eq!(v.dims(), &[2, 3]);
        assert_eq!(v.as_f32(), &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        // clones are Arc bumps sharing the same storage
        let c = v.clone();
        drop(v);
        assert_eq!(c.as_f32(), &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        drop(c);
        assert_eq!(Arc::strong_count(&buf), 1);
    }

    #[test]
    fn native_runtime_serves_without_artifacts() {
        let cfg = crate::model::Config {
            name: "T".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_ctx: 16,
        };
        let rt = Runtime::native(Manifest::synthetic(cfg, vec![(1, 4)], vec![(1, 8)]));
        assert!(rt.is_native());
        assert_eq!(rt.platform(), "native-cpu");
        assert_eq!(rt.manifest.prefill_slots, vec![(1, 4)]);
        rt.ensure_compiled("embed_p_b1_s4").unwrap(); // no-op, must not error
        let mut table = vec![0.0f32; 16 * 8];
        for t in 0..16 {
            for c in 0..8 {
                table[t * 8 + c] = t as f32;
            }
        }
        let tokens = HostTensor::i32(vec![5i32; 4], &[1, 4]);
        let out = rt
            .call("embed_p_b1_s4", &[tokens, HostTensor::f32(table, &[16, 8])])
            .unwrap();
        assert_eq!(out[0].dims(), &[1, 4, 8]);
        assert!(out[0].as_f32().iter().all(|&x| x == 5.0));
        // unknown executables are a clean error on the native path too
        assert!(rt.call("nonexistent", &[]).is_err());
    }

    #[test]
    fn manifest_loads() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.manifest.serve_size, "M");
        assert!(!rt.manifest.executables.is_empty());
        assert!(rt.platform().to_lowercase().contains("pu")); // cpu host
    }

    #[test]
    fn embed_prefill_executes() {
        let Some(rt) = runtime() else { return };
        let cfg = &rt.manifest.config;
        let (v, d) = (cfg.vocab, cfg.d_model);
        // embed table with row t = [t, t, ...] so gather is easy to check
        let mut table = vec![0.0f32; v * d];
        for t in 0..v {
            for c in 0..d {
                table[t * d + c] = t as f32;
            }
        }
        let tokens = HostTensor::i32(vec![5i32; 128], &[1, 128]);
        let out = rt
            .call("embed_p_b1_s128", &[tokens, HostTensor::f32(table, &[v, d])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims(), &[1, 128, d]);
        assert!(out[0].as_f32().iter().all(|&x| x == 5.0));
    }

    #[test]
    fn head_decode_executes() {
        let Some(rt) = runtime() else { return };
        let cfg = &rt.manifest.config;
        let (v, d) = (cfg.vocab, cfg.d_model);
        let x = HostTensor::f32(vec![0.1; d], &[1, 1, d]);
        let norm = HostTensor::f32(vec![1.0; d], &[d]);
        let head = HostTensor::f32(vec![0.01; v * d], &[v, d]);
        let out = rt.call("head_d_b1", &[x, norm, head]).unwrap();
        assert_eq!(out[0].dims(), &[1, 1, v]);
        // all head rows identical -> all logits identical
        let l = out[0].as_f32();
        assert!(l.iter().all(|&x| (x - l[0]).abs() < 1e-5));
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.call("head_d_b1", &[]).is_err());
        assert!(rt.call("nonexistent", &[]).is_err());
    }
}
