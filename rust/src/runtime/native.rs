//! Native CPU executor — a pure-Rust implementation of the serving
//! executables the PJRT runtime normally compiles from
//! python/compile/model.py (`embed_fwd`, `block_prefill`,
//! `block_decode`, `head_fwd`).
//!
//! Every executable is a pure function of its input tensors (weights
//! arrive as inputs: decoded symbol codes, channel scales, norms), so a
//! host implementation slots in behind `Runtime::call` with no state of
//! its own beyond the model's head count.  This is what lets the whole
//! serving stack — `ServingEngine`, `serve::shard`, `serve::Scheduler`
//! — run end-to-end in CI, where the vendored `xla` crate is a
//! compile-time stub (ROADMAP: "real PJRT backend / native interpreter
//! over model::forward").
//!
//! Numerical contract (the serve equivalence tests lean on all three):
//! * mirrors the JAX reference op-for-op: RMSNorm (eps 1e-5), absolute
//!   slot-position RoPE, causal + left-pad masking with -1e30, softmax
//!   over the full row, SwiGLU MLP, and the Pallas qmatmul's epilogue
//!   scaling `y[m,n] = (sum_k x[m,k] * codes[n,k]) * scale[n]`;
//! * **lane independence**: every output row of every op is computed
//!   from that lane's inputs alone with a fixed reduction order, so a
//!   request's trajectory is byte-identical whatever batch it rides in;
//! * decode/prefill consistency: a decode step at position `p` over
//!   caches copied from a prefill reproduces the prefill logits at `p`
//!   bit-for-bit (masked cache tail underflows to exactly 0 in
//!   softmax).

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

use crate::runtime::HostTensor;
use crate::tensor::{dot, rmsnorm, softmax_inplace};
use anyhow::{anyhow, bail, ensure, Result};

/// The executor: stateless beyond the model's head count (every other
/// shape is recovered from the input tensors themselves).
#[derive(Clone, Copy, Debug)]
pub struct NativeExec {
    n_heads: usize,
}

impl NativeExec {
    pub fn new(n_heads: usize) -> Self {
        NativeExec { n_heads: n_heads.max(1) }
    }

    /// Dispatch by executable name (the manifest naming scheme shared
    /// with python/compile/aot.py).
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if name.starts_with("embed_") {
            embed(name, inputs)
        } else if name.starts_with("block_p_") {
            self.block_prefill(name, inputs)
        } else if name.starts_with("block_d_") {
            self.block_decode(name, inputs)
        } else if name.starts_with("head_") {
            head(name, inputs)
        } else {
            bail!("native executor: unknown executable {name}")
        }
    }

    /// block_p_b{B}_s{S}: [x, 7 codes, 7 scales, norm_attn, norm_mlp,
    /// starts] -> [x', k [B,H,S,hd], v [B,H,S,hd]].
    fn block_prefill(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        ensure!(inputs.len() == 18, "{name}: {} inputs, 18 expected", inputs.len());
        let x = &inputs[0];
        let (b, s, d) = dims3(x, name)?;
        let codes = &inputs[1..8];
        let scales = &inputs[8..15];
        let norm_attn = inputs[15].as_f32();
        let norm_mlp = inputs[16].as_f32();
        let starts = as_i32(&inputs[17], name)?;
        ensure!(starts.len() == b, "{name}: starts len {} != batch {b}", starts.len());
        let h = self.n_heads;
        ensure!(d % h == 0, "{name}: d_model {d} not divisible by {h} heads");
        let hd = d / h;

        let xin = x.as_f32();
        let mut x1 = xin.to_vec();
        let mut knew = vec![0.0f32; b * h * s * hd];
        let mut vnew = vec![0.0f32; b * h * s * hd];
        // per lane: attention over this lane's rows only
        for bi in 0..b {
            let rows = &xin[bi * s * d..(bi + 1) * s * d];
            let xn = rmsnorm_rows(rows, norm_attn, s, d);
            let mut q = linear_rows(&xn, &codes[0], &scales[0], s, name)?;
            let mut k = linear_rows(&xn, &codes[1], &scales[1], s, name)?;
            let v = linear_rows(&xn, &codes[2], &scales[2], s, name)?;
            // RoPE at absolute slot positions 0..S (matches the JAX
            // prefill; left-padding relies on RoPE's relative-distance
            // property, not on shifting positions)
            for pos in 0..s {
                rope_row(&mut q[pos * d..(pos + 1) * d], pos, h, hd);
                rope_row(&mut k[pos * d..(pos + 1) * d], pos, h, hd);
            }
            // caches: [B,H,S,hd] from the roped k and raw v
            for head in 0..h {
                for pos in 0..s {
                    let dst = ((bi * h + head) * s + pos) * hd;
                    let src = pos * d + head * hd;
                    knew[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                    vnew[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
                }
            }
            let start = starts[bi].max(0) as usize;
            let mut ctx = vec![0.0f32; s * d];
            let scale = 1.0 / (hd as f32).sqrt();
            let mut att = vec![0.0f32; s];
            for head in 0..h {
                let off = head * hd;
                for i in 0..s {
                    let qi = &q[i * d + off..i * d + off + hd];
                    for j in 0..s {
                        att[j] = if j <= i && j >= start {
                            dot(qi, &k[j * d + off..j * d + off + hd]) * scale
                        } else {
                            -1e30
                        };
                    }
                    softmax_inplace(&mut att);
                    let out = &mut ctx[i * d + off..i * d + off + hd];
                    for j in 0..s {
                        let p = att[j];
                        let vj = &v[j * d + off..j * d + off + hd];
                        for t in 0..hd {
                            out[t] += p * vj[t];
                        }
                    }
                }
            }
            let att_out = linear_rows(&ctx, &codes[3], &scales[3], s, name)?;
            let lane_x1 = &mut x1[bi * s * d..(bi + 1) * s * d];
            for i in 0..s * d {
                lane_x1[i] += att_out[i];
            }
            mlp_inplace(lane_x1, norm_mlp, &codes[4..7], &scales[4..7], s, name)?;
        }
        Ok(vec![
            HostTensor::f32(x1, &[b, s, d]),
            HostTensor::f32(knew, &[b, h, s, hd]),
            HostTensor::f32(vnew, &[b, h, s, hd]),
        ])
    }

    /// block_d_b{B}_c{C}: [x, 7 codes, 7 scales, norm_attn, norm_mlp,
    /// k_cache, v_cache, pos, starts] -> [x', k', v'] with caches
    /// [B,H,C,hd] and the new k/v written at `pos`.
    fn block_decode(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        ensure!(inputs.len() == 21, "{name}: {} inputs, 21 expected", inputs.len());
        let x = &inputs[0];
        let (b, s1, d) = dims3(x, name)?;
        ensure!(s1 == 1, "{name}: decode step must have seq 1, got {s1}");
        let codes = &inputs[1..8];
        let scales = &inputs[8..15];
        let norm_attn = inputs[15].as_f32();
        let norm_mlp = inputs[16].as_f32();
        let kc = &inputs[17];
        let vc = &inputs[18];
        let pos = as_i32(&inputs[19], name)?;
        ensure!(pos.len() == 1, "{name}: pos must be a scalar");
        let pos = pos[0].max(0) as usize;
        let starts = as_i32(&inputs[20], name)?;
        ensure!(starts.len() == b, "{name}: starts len {} != batch {b}", starts.len());
        let h = self.n_heads;
        ensure!(d % h == 0, "{name}: d_model {d} not divisible by {h} heads");
        let hd = d / h;
        let c = cache_ctx(kc, b, h, hd, name)?;
        ensure!(cache_ctx(vc, b, h, hd, name)? == c, "{name}: k/v cache shapes differ");
        ensure!(pos < c, "{name}: write position {pos} outside cache of {c}");

        let xin = x.as_f32();
        let mut x1 = xin.to_vec();
        let mut knew = kc.as_f32().to_vec();
        let mut vnew = vc.as_f32().to_vec();
        for bi in 0..b {
            let row = &xin[bi * d..(bi + 1) * d];
            let xn = rmsnorm_rows(row, norm_attn, 1, d);
            let mut q = linear_rows(&xn, &codes[0], &scales[0], 1, name)?;
            let mut k = linear_rows(&xn, &codes[1], &scales[1], 1, name)?;
            let v = linear_rows(&xn, &codes[2], &scales[2], 1, name)?;
            rope_row(&mut q, pos, h, hd);
            rope_row(&mut k, pos, h, hd);
            // write this step's k/v into the lane's cache at `pos`
            for head in 0..h {
                let dst = ((bi * h + head) * c + pos) * hd;
                knew[dst..dst + hd].copy_from_slice(&k[head * hd..(head + 1) * hd]);
                vnew[dst..dst + hd].copy_from_slice(&v[head * hd..(head + 1) * hd]);
            }
            let start = starts[bi].max(0) as usize;
            let scale = 1.0 / (hd as f32).sqrt();
            let mut ctx = vec![0.0f32; d];
            let mut att = vec![0.0f32; c];
            for head in 0..h {
                let off = head * hd;
                let qh = &q[off..off + hd];
                let lane_k = &knew[(bi * h + head) * c * hd..(bi * h + head + 1) * c * hd];
                let lane_v = &vnew[(bi * h + head) * c * hd..(bi * h + head + 1) * c * hd];
                for j in 0..c {
                    att[j] = if j <= pos && j >= start {
                        dot(qh, &lane_k[j * hd..(j + 1) * hd]) * scale
                    } else {
                        -1e30
                    };
                }
                softmax_inplace(&mut att);
                let out = &mut ctx[off..off + hd];
                for j in 0..c {
                    let p = att[j];
                    let vj = &lane_v[j * hd..(j + 1) * hd];
                    for t in 0..hd {
                        out[t] += p * vj[t];
                    }
                }
            }
            let att_out = linear_rows(&ctx, &codes[3], &scales[3], 1, name)?;
            let lane_x1 = &mut x1[bi * d..(bi + 1) * d];
            for i in 0..d {
                lane_x1[i] += att_out[i];
            }
            mlp_inplace(lane_x1, norm_mlp, &codes[4..7], &scales[4..7], 1, name)?;
        }
        Ok(vec![
            HostTensor::f32(x1, &[b, 1, d]),
            HostTensor::f32(knew, &[b, h, c, hd]),
            HostTensor::f32(vnew, &[b, h, c, hd]),
        ])
    }
}

/// embed_p_b{B}_s{S} / embed_d_b{B}: [tokens i32 [B,S], embed [V,D]]
/// -> [x [B,S,D]] (token-row gather).
fn embed(name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    ensure!(inputs.len() == 2, "{name}: {} inputs, 2 expected", inputs.len());
    let toks = as_i32(&inputs[0], name)?;
    let tdims = inputs[0].dims();
    ensure!(tdims.len() == 2, "{name}: tokens must be [B,S], got {tdims:?}");
    let (b, s) = (tdims[0], tdims[1]);
    let table = &inputs[1];
    let edims = table.dims();
    ensure!(edims.len() == 2, "{name}: embed table must be [V,D], got {edims:?}");
    let (v, d) = (edims[0], edims[1]);
    let et = table.as_f32();
    let mut x = vec![0.0f32; b * s * d];
    for (i, &t) in toks.iter().enumerate() {
        let t = t as usize; // tokens are u8-ranged in this model family
        ensure!(t < v, "{name}: token {t} outside vocab {v}");
        x[i * d..(i + 1) * d].copy_from_slice(&et[t * d..(t + 1) * d]);
    }
    Ok(vec![HostTensor::f32(x, &[b, s, d])])
}

/// head_p_b{B}_s{S} / head_d_b{B}: [x [B,S,D], norm_final [D],
/// head [V,D]] -> [logits [B,S,V]].
fn head(name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    ensure!(inputs.len() == 3, "{name}: {} inputs, 3 expected", inputs.len());
    let x = &inputs[0];
    let (b, s, d) = dims3(x, name)?;
    let g = inputs[1].as_f32();
    ensure!(g.len() == d, "{name}: norm len {} != d_model {d}", g.len());
    let hdims = inputs[2].dims();
    ensure!(
        hdims.len() == 2 && hdims[1] == d,
        "{name}: head must be [V,{d}], got {hdims:?}"
    );
    let v = hdims[0];
    let ht = inputs[2].as_f32();
    let xin = x.as_f32();
    let mut logits = vec![0.0f32; b * s * v];
    let mut xn = vec![0.0f32; d];
    for m in 0..b * s {
        rmsnorm(&xin[m * d..(m + 1) * d], g, &mut xn);
        let lrow = &mut logits[m * v..(m + 1) * v];
        for (vi, l) in lrow.iter_mut().enumerate() {
            *l = dot(&xn, &ht[vi * d..(vi + 1) * d]);
        }
    }
    Ok(vec![HostTensor::f32(logits, &[b, s, v])])
}

// ---------------------------------------------------------------------------
// shared primitives (all lane-row deterministic)

fn dims3(x: &HostTensor, name: &str) -> Result<(usize, usize, usize)> {
    let d = x.dims();
    ensure!(d.len() == 3, "{name}: activation must be [B,S,D], got {d:?}");
    Ok((d[0], d[1], d[2]))
}

fn as_i32<'a>(t: &'a HostTensor, name: &str) -> Result<&'a [i32]> {
    match t {
        HostTensor::I32 { data, .. } => Ok(data),
        _ => Err(anyhow!("{name}: expected an i32 tensor")),
    }
}

fn cache_ctx(cache: &HostTensor, b: usize, h: usize, hd: usize, name: &str) -> Result<usize> {
    let d = cache.dims();
    ensure!(
        d.len() == 4 && d[0] == b && d[1] == h && d[3] == hd,
        "{name}: cache must be [{b},{h},C,{hd}], got {d:?}"
    );
    Ok(d[2])
}

fn rmsnorm_rows(x: &[f32], g: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        rmsnorm(&x[r * d..(r + 1) * d], g, &mut out[r * d..(r + 1) * d]);
    }
    out
}

/// The Pallas qmatmul contract: `y[m,n] = (sum_k x[m,k] * codes[n,k]) *
/// scale[n]` — channel scale applied once, in the epilogue, after the
/// K-reduction.  Row `m` touches only row `m` of `x`.
fn linear_rows(
    x: &[f32],
    codes: &HostTensor,
    scale: &HostTensor,
    rows: usize,
    name: &str,
) -> Result<Vec<f32>> {
    let cd = codes.dims();
    ensure!(cd.len() == 2, "{name}: weight codes must be 2-d, got {cd:?}");
    let (n, k) = (cd[0], cd[1]);
    ensure!(rows * k == x.len(), "{name}: activation len {} != {rows}x{k}", x.len());
    let s = scale.as_f32();
    ensure!(s.len() == n, "{name}: scale len {} != out channels {n}", s.len());
    let c = codes.as_f32();
    let mut y = vec![0.0f32; rows * n];
    for m in 0..rows {
        let xm = &x[m * k..(m + 1) * k];
        let ym = &mut y[m * n..(m + 1) * n];
        for j in 0..n {
            ym[j] = dot(xm, &c[j * k..(j + 1) * k]) * s[j];
        }
    }
    Ok(y)
}

/// RoPE over one activation row (heads contiguous): theta = pos *
/// 10000^(-j/half), halves rotated — matches model::forward and the JAX
/// `apply_rope`.
fn rope_row(row: &mut [f32], pos: usize, n_heads: usize, hd: usize) {
    let half = hd / 2;
    for h in 0..n_heads {
        let off = h * hd;
        for j in 0..half {
            let freq = 10000f32.powf(-(j as f32) / half as f32);
            let theta = pos as f32 * freq;
            let (sin, cos) = theta.sin_cos();
            let a = row[off + j];
            let b = row[off + half + j];
            row[off + j] = a * cos - b * sin;
            row[off + half + j] = a * sin + b * cos;
        }
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// SwiGLU MLP with residual, in place over `x1` ([rows, D]):
/// `x1 += w_down(silu(w_gate(norm(x1))) * w_up(norm(x1)))`.
fn mlp_inplace(
    x1: &mut [f32],
    norm_mlp: &[f32],
    codes: &[HostTensor],
    scales: &[HostTensor],
    rows: usize,
    name: &str,
) -> Result<()> {
    let d = norm_mlp.len();
    let xn2 = rmsnorm_rows(x1, norm_mlp, rows, d);
    let gate = linear_rows(&xn2, &codes[0], &scales[0], rows, name)?;
    let up = linear_rows(&xn2, &codes[1], &scales[1], rows, name)?;
    let mut hidden = vec![0.0f32; gate.len()];
    for i in 0..hidden.len() {
        hidden[i] = silu(gate[i]) * up[i];
    }
    let down = linear_rows(&hidden, &codes[2], &scales[2], rows, name)?;
    for i in 0..x1.len() {
        x1[i] += down[i];
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // a tiny deterministic "model": d_model 8, 2 heads, d_ff 12, vocab 16
    const D: usize = 8;
    const H: usize = 2;
    const F: usize = 12;
    const V: usize = 16;

    fn t(data: Vec<f32>, dims: &[usize]) -> HostTensor {
        HostTensor::f32(data, dims)
    }

    fn mat(rows: usize, cols: usize, seed: u64) -> HostTensor {
        let mut rng = crate::tensor::Rng::new(seed);
        t(
            (0..rows * cols).map(|_| (rng.normal() * 0.3) as f32).collect(),
            &[rows, cols],
        )
    }

    fn ones(n: usize) -> HostTensor {
        t(vec![1.0; n], &[n])
    }

    fn block_inputs(b: usize, s: usize, x: HostTensor, starts: Vec<i32>) -> Vec<HostTensor> {
        let mut inputs = vec![x];
        // 7 code matrices: wq wk wv wo [D,D], gate/up [F,D], down [D,F]
        for (i, (r, c)) in
            [(D, D), (D, D), (D, D), (D, D), (F, D), (F, D), (D, F)].iter().enumerate()
        {
            inputs.push(mat(*r, *c, 100 + i as u64));
        }
        for (i, r) in [D, D, D, D, F, F, D].iter().enumerate() {
            let mut rng = crate::tensor::Rng::new(200 + i as u64);
            inputs.push(t((0..*r).map(|_| 1.0 + rng.uniform() as f32 * 0.1).collect(), &[*r]));
        }
        inputs.push(ones(D)); // norm_attn
        inputs.push(ones(D)); // norm_mlp
        inputs.push(HostTensor::i32(starts, &[b]));
        let _ = s;
        inputs
    }

    fn lane_x(b: usize, s: usize, seed: u64) -> HostTensor {
        let mut rng = crate::tensor::Rng::new(seed);
        t(
            (0..b * s * D).map(|_| rng.normal() as f32 * 0.5).collect(),
            &[b, s, D],
        )
    }

    #[test]
    fn embed_gathers_rows() {
        let mut table = vec![0.0f32; V * D];
        for v in 0..V {
            for c in 0..D {
                table[v * D + c] = v as f32 + c as f32 * 0.01;
            }
        }
        let toks = HostTensor::i32(vec![3, 0, 15, 3], &[2, 2]);
        let out = embed("embed_p_b2_s2", &[toks, t(table.clone(), &[V, D])]).unwrap();
        assert_eq!(out[0].dims(), &[2, 2, D]);
        let x = out[0].as_f32();
        assert_eq!(&x[0..D], &table[3 * D..4 * D]);
        assert_eq!(&x[2 * D..3 * D], &table[15 * D..16 * D]);
        // out-of-vocab token is an error, not a panic
        let bad = HostTensor::i32(vec![16], &[1, 1]);
        assert!(embed("embed_d_b1", &[bad, t(table, &[V, D])]).is_err());
    }

    #[test]
    fn prefill_shapes_and_finiteness() {
        let ex = NativeExec::new(H);
        let (b, s) = (2, 6);
        let out = ex
            .block_prefill("block_p_b2_s6", &block_inputs(b, s, lane_x(b, s, 7), vec![0, 2]))
            .unwrap();
        assert_eq!(out[0].dims(), &[b, s, D]);
        assert_eq!(out[1].dims(), &[b, H, s, D / H]);
        assert_eq!(out[2].dims(), &[b, H, s, D / H]);
        for o in &out {
            assert!(o.as_f32().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn lanes_are_batch_invariant() {
        // THE serve-subsystem invariant: a lane's outputs must not
        // depend on what else rides in the batch
        let ex = NativeExec::new(H);
        let s = 5;
        let x2 = lane_x(2, s, 11);
        let solo0: Vec<f32> = x2.as_f32()[..s * D].to_vec();
        let solo1: Vec<f32> = x2.as_f32()[s * D..].to_vec();
        let big = ex
            .block_prefill("block_p_b2_s5", &block_inputs(2, s, x2, vec![1, 3]))
            .unwrap();
        let a = ex
            .block_prefill("block_p_b1_s5", &block_inputs(1, s, t(solo0, &[1, s, D]), vec![1]))
            .unwrap();
        let bl = ex
            .block_prefill("block_p_b1_s5", &block_inputs(1, s, t(solo1, &[1, s, D]), vec![3]))
            .unwrap();
        assert_eq!(&big[0].as_f32()[..s * D], a[0].as_f32());
        assert_eq!(&big[0].as_f32()[s * D..], bl[0].as_f32());
        assert_eq!(&big[1].as_f32()[..H * s * (D / H)], a[1].as_f32());
        assert_eq!(&big[2].as_f32()[H * s * (D / H)..], bl[2].as_f32());
    }

    #[test]
    fn left_pad_mask_hides_padding() {
        // tokens before `start` must not influence later positions
        let ex = NativeExec::new(H);
        let s = 6;
        let xa = lane_x(1, s, 21);
        let mut xb_data = xa.as_f32().to_vec();
        for v in xb_data.iter_mut().take(2 * D) {
            *v += 7.5; // perturb the two padding positions
        }
        let start = vec![2];
        let a = ex
            .block_prefill("block_p_b1_s6", &block_inputs(1, s, xa, start.clone()))
            .unwrap();
        let b = ex
            .block_prefill("block_p_b1_s6", &block_inputs(1, s, t(xb_data, &[1, s, D]), start))
            .unwrap();
        // positions >= start agree exactly
        assert_eq!(&a[0].as_f32()[2 * D..], &b[0].as_f32()[2 * D..]);
    }

    #[test]
    fn decode_step_matches_prefill_position() {
        // prefill over 4 real tokens == prefill over 3 + one decode step
        // at pos 3, bit for bit
        let ex = NativeExec::new(H);
        let (s, c) = (4, 8);
        let hd = D / H;
        let xfull = lane_x(1, s, 33);
        let full = ex
            .block_prefill("block_p_b1_s4", &block_inputs(1, s, xfull.clone(), vec![0]))
            .unwrap();

        // prefix prefill: first 3 positions
        let xpre = t(xfull.as_f32()[..3 * D].to_vec(), &[1, 3, D]);
        let pre = ex
            .block_prefill("block_p_b1_s3", &block_inputs(1, 3, xpre, vec![0]))
            .unwrap();
        // expand prefill caches [1,H,3,hd] into decode caches [1,H,C,hd]
        let expand = |t_: &HostTensor| {
            let src = t_.as_f32();
            let mut dst = vec![0.0f32; H * c * hd];
            for h in 0..H {
                for p in 0..3 {
                    let so = (h * 3 + p) * hd;
                    let eo = (h * c + p) * hd;
                    dst[eo..eo + hd].copy_from_slice(&src[so..so + hd]);
                }
            }
            HostTensor::f32(dst, &[1, H, c, hd])
        };
        let (kc, vc) = (expand(&pre[1]), expand(&pre[2]));
        let xstep = t(xfull.as_f32()[3 * D..4 * D].to_vec(), &[1, 1, D]);
        let mut inputs = block_inputs(1, 1, xstep, vec![0]);
        let starts = inputs.pop().unwrap();
        inputs.push(kc);
        inputs.push(vc);
        inputs.push(HostTensor::scalar_i32(3));
        inputs.push(starts);
        let step = ex.block_decode("block_d_b1_c8", &inputs).unwrap();
        // decode x' at pos 3 == prefill x' row 3
        assert_eq!(step[0].as_f32(), &full[0].as_f32()[3 * D..4 * D]);
        // and the written cache row matches the full prefill's row 3
        let kfull = full[1].as_f32();
        let knew = step[1].as_f32();
        for h in 0..H {
            assert_eq!(
                &knew[(h * c + 3) * hd..(h * c + 3) * hd + hd],
                &kfull[(h * s + 3) * hd..(h * s + 3) * hd + hd]
            );
        }
    }

    #[test]
    fn head_logits_shape_and_norm() {
        let x = lane_x(2, 3, 41);
        let out = head("head_p_b2_s3", &[x, ones(D), mat(V, D, 50)]).unwrap();
        assert_eq!(out[0].dims(), &[2, 3, V]);
        assert!(out[0].as_f32().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn malformed_inputs_error_not_panic() {
        let ex = NativeExec::new(H);
        assert!(ex.call("unknown_exec", &[]).is_err());
        assert!(ex.call("block_p_b1_s4", &[]).is_err());
        assert!(ex.call("head_p_b1_s4", &[lane_x(1, 4, 1)]).is_err());
        // wrong starts length
        let mut inputs = block_inputs(1, 4, lane_x(1, 4, 2), vec![0, 0]);
        assert!(ex.call("block_p_b1_s4", &inputs).is_err());
        // scale length mismatch
        inputs = block_inputs(1, 4, lane_x(1, 4, 2), vec![0]);
        inputs[8] = ones(D + 1);
        assert!(ex.call("block_p_b1_s4", &inputs).is_err());
    }
}
