//! Fixed-bucket log2 histograms (HDR-style, no sample storage).
//!
//! Values land in one of at most [`N_BUCKETS`] buckets: exact buckets
//! for `v < 32`, then 32 sub-buckets per power of two above that, so
//! the reported quantile (a bucket's upper bound) is within ~3.2%
//! relative error of the true value at any magnitude.  Recording is a
//! single `fetch_add` — lock-free, allocation-free, mergeable — which
//! is what lets it replace the mutex-guarded TTFT sample reservoir on
//! the serve hot path with *bounded* memory (the reservoir grew one
//! `f64` per request, forever).
//!
//! `count`/`sum`/`max` are tracked exactly, so `mean()` has no bucket
//! error; only quantiles are bucket-quantised.
//
// entlint: allow-file(ordering-audit) — every atomic here is an independent
// monotone counter; snapshots tolerate tearing between cells by design.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution: 2^5 = 32 sub-buckets per power of two.
const SUB_BITS: usize = 5;
const SUB: usize = 1 << SUB_BITS;

/// 32 exact buckets + 32 per exponent group for msb 5..=63.
pub const N_BUCKETS: usize = SUB + (64 - SUB_BITS) * SUB;

/// Bucket index for a value — exact below 32, log2-with-5-sub-bits above.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros() as usize;
        let shift = msb - SUB_BITS;
        let sub = ((v >> shift) as usize) - SUB;
        SUB + shift * SUB + sub
    }
}

/// Inclusive `(lower, upper)` value range of bucket `i` — the inverse
/// of [`bucket_index`]; `upper` is the quantile representative.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUB {
        (i as u64, i as u64)
    } else {
        let group = (i - SUB) / SUB;
        let sub = (i - SUB) % SUB;
        let lower = ((SUB + sub) as u64) << group;
        (lower, lower + (1u64 << group) - 1)
    }
}

pub struct Log2Hist {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Log2Hist {
    fn default() -> Self {
        Log2Hist::new()
    }
}

impl Log2Hist {
    pub fn new() -> Log2Hist {
        let buckets: Vec<AtomicU64> = (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Log2Hist {
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value.  Lock-free and allocation-free.
    // entlint: hot
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy; bucket cells may tear against concurrent
    /// records, which only misplaces in-flight samples between buckets.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Immutable histogram copy — mergeable across shards/processes and
/// queryable for nearest-rank quantiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot::empty()
    }
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot { counts: vec![0; N_BUCKETS], count: 0, sum: 0, max: 0 }
    }

    /// Merging snapshots is exactly equivalent to recording both
    /// sample streams into one histogram (pinned by property test).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`), reported as the upper
    /// bound of the bucket holding the ranked sample — the same
    /// smallest-element-with-rank `>= ceil(q*n)` convention as
    /// `serve::metrics::percentile`, quantised to bucket resolution.
    /// Empty histogram reports 0.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_bounds(i).1.min(self.max);
            }
        }
        self.max
    }

    /// Exact mean (from the exact running sum, not bucket bounds).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_region_is_exact() {
        for v in 0..32u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_bounds(v as usize), (v, v));
        }
    }

    #[test]
    fn bounds_invert_index_everywhere() {
        // Every bucket's bounds map back to that bucket, and the next
        // value after `upper` starts the next bucket.
        for i in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(bucket_index(lo), i, "lower bound of bucket {i}");
            assert_eq!(bucket_index(hi), i, "upper bound of bucket {i}");
            if hi < u64::MAX {
                assert_eq!(bucket_index(hi + 1), i + 1, "successor of bucket {i}");
            }
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn relative_error_bounded() {
        for &v in &[33u64, 100, 1000, 12345, 7_500_000, u64::MAX / 3] {
            let (lo, hi) = bucket_bounds(bucket_index(v));
            assert!(lo <= v && v <= hi);
            let err = (hi - v) as f64 / v as f64;
            assert!(err <= 1.0 / 32.0 + 1e-12, "v={v} err={err}");
        }
    }
}
