//! The tracer: tick-stamped event recording + JSONL / Chrome exporters.
//!
//! `Tracer` owns a bounded [`EventRing`] fed from the hot paths and a
//! bounded archive the ring drains into between decode steps.  The
//! record path is lock-free and allocation-free; the drain path runs
//! under a mutex (which also serialises the ring's single consumer).
//!
//! **Clock domains.**  Events carry only the scheduler tick
//! (`decode_steps`), so the recorded stream is byte-identical across
//! runs of the same seeded scenario.  Wall-clock annotation — a unix
//! anchor for correlating a trace with external logs — is applied only
//! at export time and only when the *caller* (e.g. `main.rs`, outside
//! the replay paths) supplies one; nothing in `obs/` reads a wall
//! clock except [`super::clock`].
//!
//! **Chrome export.**  `export_chrome` emits Chrome trace-event JSON
//! (one event per line) loadable in Perfetto / `chrome://tracing`:
//! pid 0 = one track per request (full lifecycle span + phase spans +
//! instants), pid 1 = one track per decode lane (occupancy spans),
//! pid 2 = one track per shard (fault/reroute/rejoin instants, splice
//! spans), pid 3 = driver counters (active lanes, queue depth).  `ts`
//! is the tick, microsecond-denominated, so one tick renders as 1µs.

use super::event::{Event, EventKind};
use super::ring::EventRing;
use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

pub struct Tracer {
    enabled: AtomicBool,
    /// Scheduler decode-step counter, mirrored here by the driver so
    /// producers on any thread can stamp events without reaching into
    /// scheduler state.
    tick: AtomicU64,
    ring: EventRing,
    /// Drained events in record order, capped at `archive_cap`.
    archive: Mutex<Vec<Event>>,
    archive_cap: usize,
    archive_dropped: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new(1 << 12, 1 << 16)
    }
}

impl Tracer {
    /// `ring_cap` bounds in-flight (undrained) events and must be a
    /// power of two; `archive_cap` bounds total retained events.
    pub fn new(ring_cap: usize, archive_cap: usize) -> Tracer {
        Tracer {
            enabled: AtomicBool::new(true),
            tick: AtomicU64::new(0),
            ring: EventRing::new(ring_cap),
            archive: Mutex::new(Vec::with_capacity(archive_cap.min(1 << 20))),
            archive_cap,
            archive_dropped: AtomicU64::new(0),
        }
    }

    pub fn set_enabled(&self, on: bool) {
        // Relaxed: a lone on/off flag, no ordering with event payloads
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        // Relaxed: see set_enabled
        self.enabled.load(Ordering::Relaxed)
    }

    /// Advance the tick mirror (driver-only, once per decode step).
    pub fn set_tick(&self, t: u64) {
        // Relaxed: the tick is an annotation stamp; cross-thread skew
        // only staggers stamps, never replayed computation
        self.tick.store(t, Ordering::Relaxed);
    }

    pub fn tick(&self) -> u64 {
        // Relaxed: see set_tick
        self.tick.load(Ordering::Relaxed)
    }

    /// Record one event stamped with the current tick.  Lock-free and
    /// allocation-free (pinned by `rust/tests/obs.rs`).
    // entlint: hot
    pub fn record(&self, kind: EventKind, id: u64, a: u64, b: u64) {
        if !self.enabled() {
            return;
        }
        let tick = self.tick();
        self.ring.push([tick, kind as u64, id, a, b]);
    }

    /// Total events lost to ring overflow or archive cap.
    pub fn dropped(&self) -> u64 {
        // Relaxed: monotone gauges
        self.ring.dropped() + self.archive_dropped.load(Ordering::Relaxed)
    }

    /// Move everything buffered in the ring into the archive.  Called
    /// by the scheduler driver between decode steps and by exporters;
    /// the archive mutex also serialises the ring's single consumer.
    pub fn drain(&self) {
        let mut archive = self.archive.lock().unwrap();
        while let Some(words) = self.ring.pop() {
            if archive.len() >= self.archive_cap {
                // Relaxed: drop counter only
                self.archive_dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            if let Some(e) = Event::from_words(words) {
                archive.push(e);
            }
        }
    }

    /// Drain, then copy the archived stream (record order).
    pub fn events(&self) -> Vec<Event> {
        self.drain();
        self.archive.lock().unwrap().clone()
    }

    /// Archived event count (after an implicit drain).
    pub fn len(&self) -> usize {
        self.drain();
        self.archive.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// JSONL export: one `{"tick":..,"kind":..,"id":..,"a":..,"b":..}`
    /// object per line.  `wall_anchor_us` (unix µs at export, supplied
    /// by the caller so `obs/` itself stays wall-clock-free) prepends a
    /// `{"anchor_unix_us":..}` header line; replay-path callers pass
    /// `None` and the output is byte-identical across seeded runs.
    pub fn export_jsonl(&self, wall_anchor_us: Option<u64>) -> String {
        let events = self.events();
        let mut out = String::with_capacity(64 * (events.len() + 1));
        if let Some(us) = wall_anchor_us {
            let _ = writeln!(out, "{{\"anchor_unix_us\":{us},\"dropped\":{}}}", self.dropped());
        }
        for e in &events {
            let _ = writeln!(
                out,
                "{{\"tick\":{},\"kind\":\"{}\",\"id\":{},\"a\":{},\"b\":{}}}",
                e.tick,
                e.kind.name(),
                e.id,
                e.a,
                e.b
            );
        }
        out
    }

    /// Chrome trace-event export (see module docs for the track
    /// layout).  Deterministic: no wall clock, stable metadata order,
    /// one traceEvent per line.
    pub fn export_chrome(&self) -> String {
        export_chrome_events(&self.events())
    }
}

/// Render an event stream as Chrome trace-event JSON.  Split out from
/// [`Tracer`] so tests and tools can render captured streams directly.
pub fn export_chrome_events(events: &[Event]) -> String {
    let mut requests: BTreeSet<u64> = BTreeSet::new();
    let mut lanes: BTreeSet<u64> = BTreeSet::new();
    let mut shards: BTreeSet<u64> = BTreeSet::new();
    for e in events {
        match e.kind {
            EventKind::LaneStart | EventKind::LaneEnd => {
                requests.insert(e.id);
                lanes.insert(e.a);
            }
            EventKind::DecodeStep | EventKind::Shed => {}
            k if k.is_shard() => {
                shards.insert(e.id);
                if k == EventKind::Reroute {
                    shards.insert(e.b);
                }
            }
            _ => {
                requests.insert(e.id);
            }
        }
    }

    let mut out = String::with_capacity(96 * (events.len() + 16));
    out.push_str("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push_line = |out: &mut String, line: &str| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(line);
    };

    // Process / thread naming metadata (stable order: pid, then tid).
    let mut line = String::new();
    for (pid, name) in
        [(0u32, "requests"), (1, "lanes"), (2, "shards"), (3, "driver")]
    {
        line.clear();
        let _ = write!(
            line,
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\"args\":{{\"name\":\"{name}\"}}}}"
        );
        push_line(&mut out, &line);
    }
    for (pid, ids, label) in
        [(0u32, &requests, "request"), (1, &lanes, "lane"), (2, &shards, "shard")]
    {
        for &tid in ids.iter() {
            line.clear();
            let _ = write!(
                line,
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\"{label} {tid}\"}}}}"
            );
            push_line(&mut out, &line);
        }
    }

    for e in events {
        line.clear();
        let ts = e.tick;
        let (id, a, b) = (e.id, e.a, e.b);
        match e.kind {
            EventKind::Submit => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"B\",\"pid\":0,\"tid\":{id},\"ts\":{ts},\"name\":\"request\",\"args\":{{\"prompt\":{a},\"max_new\":{b}}}}}"
                );
            }
            k if k.is_terminal() => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"E\",\"pid\":0,\"tid\":{id},\"ts\":{ts},\"name\":\"request\",\"args\":{{\"outcome\":\"{}\",\"tokens\":{a}}}}}",
                    k.name()
                );
            }
            EventKind::PrefillStart => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"B\",\"pid\":0,\"tid\":{id},\"ts\":{ts},\"name\":\"prefill\",\"args\":{{\"lane\":{a}}}}}"
                );
            }
            EventKind::PrefillEnd => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"E\",\"pid\":0,\"tid\":{id},\"ts\":{ts},\"name\":\"prefill\"}}"
                );
            }
            EventKind::LaneStart => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"B\",\"pid\":1,\"tid\":{a},\"ts\":{ts},\"name\":\"occupy\",\"args\":{{\"req\":{id}}}}}"
                );
            }
            EventKind::LaneEnd => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"E\",\"pid\":1,\"tid\":{a},\"ts\":{ts},\"name\":\"occupy\"}}"
                );
            }
            EventKind::SpliceStart => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"B\",\"pid\":2,\"tid\":{id},\"ts\":{ts},\"name\":\"splice\",\"args\":{{\"blocks\":{a}}}}}"
                );
            }
            EventKind::SpliceEnd => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"E\",\"pid\":2,\"tid\":{id},\"ts\":{ts},\"name\":\"splice\"}}"
                );
            }
            EventKind::DecodeStep => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"C\",\"pid\":3,\"tid\":0,\"ts\":{ts},\"name\":\"driver\",\"args\":{{\"active\":{a},\"queue\":{b}}}}}"
                );
            }
            // sheds have no request id: they render as driver-track
            // instants so refusals are visible next to the counters
            EventKind::Shed => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"i\",\"pid\":3,\"tid\":0,\"ts\":{ts},\"s\":\"t\",\"name\":\"shed\",\"args\":{{\"reason\":{a},\"retry_after\":{b}}}}}"
                );
            }
            k if k.is_shard() => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"i\",\"pid\":2,\"tid\":{id},\"ts\":{ts},\"s\":\"t\",\"name\":\"{}\",\"args\":{{\"a\":{a},\"b\":{b}}}}}",
                    k.name()
                );
            }
            k => {
                let _ = write!(
                    line,
                    "{{\"ph\":\"i\",\"pid\":0,\"tid\":{id},\"ts\":{ts},\"s\":\"t\",\"name\":\"{}\",\"args\":{{\"a\":{a},\"b\":{b}}}}}",
                    k.name()
                );
            }
        }
        push_line(&mut out, &line);
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_drain_export_roundtrip() {
        let t = Tracer::new(16, 64);
        t.set_tick(3);
        t.record(EventKind::Submit, 1, 10, 20);
        t.record(EventKind::Admit, 1, 0, 0);
        t.set_tick(5);
        t.record(EventKind::Done, 1, 7, 0);
        let ev = t.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, EventKind::Submit);
        assert_eq!(ev[0].tick, 3);
        assert_eq!(ev[2].tick, 5);

        let jsonl = t.export_jsonl(None);
        assert_eq!(jsonl.lines().count(), 3);
        assert!(jsonl.contains("\"kind\":\"submit\""));
        let anchored = t.export_jsonl(Some(42));
        assert!(anchored.starts_with("{\"anchor_unix_us\":42"));

        let chrome = t.export_chrome();
        assert!(chrome.starts_with("{\"traceEvents\":["));
        assert!(chrome.contains("\"ph\":\"B\""));
        assert!(chrome.contains("\"ph\":\"E\""));
        // One traceEvent per line, comma-led continuation lines.
        assert!(chrome.lines().any(|l| l.starts_with(",{\"ph\":")));
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(16, 64);
        t.set_enabled(false);
        t.record(EventKind::Submit, 1, 0, 0);
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn archive_cap_drops_and_counts() {
        let t = Tracer::new(16, 4);
        for i in 0..6 {
            t.record(EventKind::DecodeStep, 0, i, 0);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn export_is_deterministic() {
        let mk = || {
            let t = Tracer::new(32, 64);
            t.record(EventKind::Submit, 2, 4, 8);
            t.set_tick(1);
            t.record(EventKind::Reroute, 1, 1, 0);
            t.record(EventKind::Done, 2, 3, 0);
            (t.export_chrome(), t.export_jsonl(None))
        };
        assert_eq!(mk(), mk());
    }
}
