//! Tick-domain observability: request/shard tracing, log2 latency
//! histograms, and trace exporters.
//!
//! The serve stack's answer to "which phase ate the time": every
//! request-lifecycle transition (submit → admit/shed → prefill →
//! adoption → per-decode-step → terminal) and shard-lifecycle event
//! (fault, reroute, splice, rejoin, evict, backoff) is recorded as a
//! fixed-size [`Event`] into a bounded lock-free [`EventRing`], stamped
//! with the scheduler's **tick counter** — never a wall clock — so
//! traces from seeded scenarios are byte-identical across runs and the
//! `no-wallclock-in-replay` invariant holds with a single audited
//! escape ([`clock`]).
//!
//! Latency distributions use [`Log2Hist`] — fixed-bucket HDR-style
//! histograms with mergeable snapshots and ~3.2%-accurate
//! p50/p99/p999 — instead of unbounded sample reservoirs; recording is
//! one `fetch_add`, allocation-free, safe on the decode hot path.
//!
//! [`Tracer`] ties it together and exports JSONL or Chrome trace-event
//! JSON (Perfetto-loadable; one track per request, lane, and shard).
//! Wall-clock annotation happens only at export, supplied by callers
//! outside the replay paths.

pub mod clock;
pub mod event;
pub mod hist;
pub mod ring;
pub mod trace;

pub use clock::Stopwatch;
pub use event::{Event, EventKind};
pub use hist::{bucket_bounds, bucket_index, HistSnapshot, Log2Hist, N_BUCKETS};
pub use ring::EventRing;
pub use trace::{export_chrome_events, Tracer};
