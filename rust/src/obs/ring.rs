//! Bounded lock-free MPMC ring of fixed-size event records.
//!
//! A safe-Rust Vyukov-style bounded queue: each slot carries a sequence
//! number that encodes whether it is free for the producer at a given
//! head position or full for the consumer at a given tail position, so
//! producers never block and the record path never allocates.  Payloads
//! are five `u64` words stored through `AtomicU64` cells (the crate is
//! `#![forbid(unsafe_code)]`, so no `UnsafeCell` payload tricks); the
//! slot's Release/Acquire sequence handshake orders the payload words.
//!
//! Overflow policy is **drop-newest**: when the ring is full the push
//! fails and a dropped counter increments, so the *earliest* events —
//! the ones that open spans — are the ones retained.  Draining
//! (`pop`) is single-consumer by contract; `Tracer` enforces that by
//! only popping under its archive mutex.

use std::sync::atomic::{AtomicU64, Ordering};

struct Slot {
    /// Vyukov sequence: `== pos` means free for the producer claiming
    /// `pos`; `== pos + 1` means full for the consumer at `pos`.
    seq: AtomicU64,
    w: [AtomicU64; 5],
}

pub struct EventRing {
    slots: Box<[Slot]>,
    mask: u64,
    /// Next position a producer will claim.
    head: AtomicU64,
    /// Next position the (single) consumer will read.
    tail: AtomicU64,
    dropped: AtomicU64,
}

impl EventRing {
    /// `cap` must be a power of two (masked indexing).
    pub fn new(cap: usize) -> EventRing {
        assert!(cap.is_power_of_two() && cap >= 2, "ring capacity must be a power of two >= 2");
        let slots: Vec<Slot> = (0..cap)
            .map(|i| Slot { seq: AtomicU64::new(i as u64), w: Default::default() })
            .collect();
        EventRing {
            slots: slots.into_boxed_slice(),
            mask: cap as u64 - 1,
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events rejected because the ring was full.
    pub fn dropped(&self) -> u64 {
        // Relaxed: monotone gauge read, no payload depends on it
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record one event.  Lock-free, allocation-free; returns `false`
    /// (and counts a drop) when the ring is full.
    // entlint: hot
    pub fn push(&self, words: [u64; 5]) -> bool {
        loop {
            let head = self.head.load(Ordering::Acquire);
            let slot = &self.slots[(head & self.mask) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == head {
                // Slot free at our position: claim it by advancing head.
                if self
                    .head
                    .compare_exchange_weak(head, head + 1, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    for (cell, &w) in slot.w.iter().zip(words.iter()) {
                        // Relaxed: the seq Release store below publishes the payload
                        cell.store(w, Ordering::Relaxed);
                    }
                    slot.seq.store(head + 1, Ordering::Release);
                    return true;
                }
                // Lost the claim race — retry with the new head.
            } else if seq < head {
                // Slot still holds an unconsumed event a full lap back:
                // ring is full.  Drop-newest.
                // Relaxed: drop counter only, nothing orders against it
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            // seq > head: another producer claimed this position first; retry.
        }
    }

    /// Take the oldest event, if any.  **Single consumer only** — the
    /// caller must serialise pops externally (see `Tracer::drain`).
    pub fn pop(&self) -> Option<[u64; 5]> {
        let tail = self.tail.load(Ordering::Acquire);
        let slot = &self.slots[(tail & self.mask) as usize];
        if slot.seq.load(Ordering::Acquire) != tail + 1 {
            return None; // empty, or the producer is mid-publish
        }
        let mut words = [0u64; 5];
        for (out, cell) in words.iter_mut().zip(slot.w.iter()) {
            // Relaxed: the seq Acquire load above synchronised with the
            // producer's Release publish of these words
            *out = cell.load(Ordering::Relaxed);
        }
        // Mark the slot free for the producer one lap ahead.
        slot.seq.store(tail + self.slots.len() as u64, Ordering::Release);
        self.tail.store(tail + 1, Ordering::Release);
        Some(words)
    }

    /// Events currently buffered (racy under concurrent pushes; exact
    /// when quiescent).
    pub fn len(&self) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        head.saturating_sub(tail) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_within_capacity() {
        let r = EventRing::new(8);
        for i in 0..5u64 {
            assert!(r.push([i, 0, 0, 0, 0]));
        }
        assert_eq!(r.len(), 5);
        for i in 0..5u64 {
            assert_eq!(r.pop().unwrap()[0], i);
        }
        assert!(r.pop().is_none());
    }

    #[test]
    fn overflow_drops_newest_and_counts() {
        let r = EventRing::new(4);
        for i in 0..4u64 {
            assert!(r.push([i, 0, 0, 0, 0]));
        }
        assert!(!r.push([99, 0, 0, 0, 0]));
        assert!(!r.push([100, 0, 0, 0, 0]));
        assert_eq!(r.dropped(), 2);
        // The earliest four survive; the overflowing two are gone.
        let got: Vec<u64> = std::iter::from_fn(|| r.pop()).map(|w| w[0]).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn wraps_many_laps() {
        let r = EventRing::new(4);
        for lap in 0..100u64 {
            for i in 0..3 {
                assert!(r.push([lap * 3 + i, 0, 0, 0, 0]));
            }
            for i in 0..3 {
                assert_eq!(r.pop().unwrap()[0], lap * 3 + i);
            }
        }
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn concurrent_producers_lose_nothing_under_capacity() {
        use std::sync::Arc;
        let r = Arc::new(EventRing::new(1 << 12));
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..512u64 {
                        assert!(r.push([(t << 32) | i, 0, 0, 0, 0]));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let mut got: Vec<u64> = std::iter::from_fn(|| r.pop()).map(|w| w[0]).collect();
        assert_eq!(got.len(), 4 * 512);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 4 * 512, "no duplicated or torn records");
    }
}
