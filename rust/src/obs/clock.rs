//! The one sanctioned wall-clock in replay-adjacent code.
//!
//! Tick-domain events (`obs::Event`) carry the scheduler's decode-step
//! counter and never a wall time — that's what keeps replay
//! deterministic.  Wall time is still wanted for *annotation*: phase
//! durations in `coordinator::engine::Metrics`, TTFT and step-latency
//! histograms in `serve::metrics`.  `Stopwatch` is that annotation
//! surface: measured durations flow into metrics and exports only, and
//! **must never branch replayed computation** — which is why the
//! wall-clock escape lives here, once, instead of scattered through
//! every engine/scheduler timing site.
//
// entlint: allow-file(no-wallclock-in-replay) — durations measured here
// annotate metrics/exports only; no measured value feeds back into
// decode, scheduling, or replay decisions.

use std::time::{Duration, Instant};

/// A started timer.  `Copy`, allocation-free, and readable many times.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    #[inline]
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    #[inline]
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed milliseconds as `f64` — the unit `coordinator::engine`'s
    /// phase accounting accumulates.
    #[inline]
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }

    /// Elapsed whole microseconds — the unit the serve histograms record.
    #[inline]
    pub fn elapsed_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn units_agree() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let ms = sw.elapsed_ms();
        let us = sw.elapsed_us();
        assert!(ms >= 2.0);
        assert!(us >= 2000);
        // Microseconds and milliseconds read the same monotonic source.
        assert!((us as f64) <= sw.elapsed_ms() * 1000.0 + 1.0);
    }
}
