//! Fixed-size trace event records.
//!
//! Every event is five `u64` words — `[tick, kind, id, a, b]` — so the
//! ring buffer can store them in fixed slots with no pointers and no
//! allocation on the record path.  `tick` is the scheduler's decode-step
//! counter (the only clock that exists on replay paths); `id` is a
//! request id for request-lifecycle kinds and a shard index for
//! shard-lifecycle kinds; `a`/`b` are kind-specific payload words
//! (documented per variant).

/// What happened.  The discriminant is the on-ring encoding, so new
/// kinds must be appended, never reordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// Request entered `submit` (`a` = prompt tokens, `b` = max new tokens).
    Submit = 0,
    /// Admission accepted the request.
    Admit = 1,
    /// Admission shed the request (`a` = `ShedReason` discriminant,
    /// `b` = retry-after hint in decode steps; `id` is `u64::MAX` —
    /// a shed request never received one).
    Shed = 2,
    /// Prefill began for the request (`a` = `u64::MAX` for a batch
    /// prefill — lanes are assigned per-request afterwards — or 0 for
    /// a solo-slot prefill).
    PrefillStart = 3,
    /// Prefill finished (`a` as in `PrefillStart`; `b` = 1 when the
    /// prefill errored — the span stays balanced either way).
    PrefillEnd = 4,
    /// Speculative prefill started while the batch was mid-decode.
    SpecPrefill = 5,
    /// Catch-up decode replaying the batch's progress onto a solo
    /// prefill (`a` = steps replayed).
    Catchup = 6,
    /// Lane adoption into the live batch (`a` = lane,
    /// `b` = 1 if the prefill was speculative/fused).
    Adopt = 7,
    /// First output token surfaced to the client (`a` = tokens
    /// mirrored so far).
    FirstToken = 8,
    /// Terminal: completed normally (`a` = tokens produced).
    Done = 9,
    /// Terminal: deadline budget exhausted (`a` = tokens produced).
    Expired = 10,
    /// Terminal: cancelled by the client (`a` = tokens produced).
    Cancelled = 11,
    /// Terminal: failed after unrecoverable engine error (`a` = tokens).
    Failed = 12,
    /// Request began occupying a decode lane (`a` = lane).
    LaneStart = 13,
    /// Request released its decode lane (`a` = lane).
    LaneEnd = 14,
    /// Admitted request was pushed back to the queue front (engine
    /// failure before adoption).
    Requeue = 15,
    /// Shard `id` faulted (recorded at fault attribution).
    ShardFault = 16,
    /// Shard `id`'s range rerouted onto a survivor (`a` = from shard,
    /// `b` = to shard).
    Reroute = 17,
    /// Survivor shard `id` began splicing an absorbed range
    /// (`a` = blocks).
    SpliceStart = 18,
    /// Splice finished on shard `id` (`b` = 1 when the splice failed).
    SpliceEnd = 19,
    /// Replacement shard `id` rejoined the topology (`a` = blocks
    /// absorbed from the donor).
    Rejoin = 20,
    /// Shard `id` evicted after repeated failures (`a` = the
    /// consecutive-failure threshold that tripped).
    Evict = 21,
    /// Rejoin attempt for shard slot `id` backoff-rescheduled
    /// (`a` = attempt, `b` = delay ticks).
    Backoff = 22,
    /// One driver tick (`a` = active lanes, `b` = queue depth).
    DecodeStep = 23,
    /// Pipeline stage `id` (a shard index) ran one micro-batch of a
    /// pipelined decode step (`a` = micro-batch index within the step,
    /// `b` = lanes in the micro-batch) — the per-stage lane-occupancy
    /// signal that makes the shard-overlap visible on Perfetto shard
    /// tracks.
    StageRun = 24,
}

pub const EVENT_KINDS: usize = 25;

impl EventKind {
    pub fn from_u64(v: u64) -> Option<EventKind> {
        use EventKind::*;
        const ALL: [EventKind; EVENT_KINDS] = [
            Submit,
            Admit,
            Shed,
            PrefillStart,
            PrefillEnd,
            SpecPrefill,
            Catchup,
            Adopt,
            FirstToken,
            Done,
            Expired,
            Cancelled,
            Failed,
            LaneStart,
            LaneEnd,
            Requeue,
            ShardFault,
            Reroute,
            SpliceStart,
            SpliceEnd,
            Rejoin,
            Evict,
            Backoff,
            DecodeStep,
            StageRun,
        ];
        ALL.get(v as usize).copied()
    }

    pub fn name(self) -> &'static str {
        use EventKind::*;
        match self {
            Submit => "submit",
            Admit => "admit",
            Shed => "shed",
            PrefillStart => "prefill_start",
            PrefillEnd => "prefill_end",
            SpecPrefill => "spec_prefill",
            Catchup => "catchup",
            Adopt => "adopt",
            FirstToken => "first_token",
            Done => "done",
            Expired => "expired",
            Cancelled => "cancelled",
            Failed => "failed",
            LaneStart => "lane_start",
            LaneEnd => "lane_end",
            Requeue => "requeue",
            ShardFault => "shard_fault",
            Reroute => "reroute",
            SpliceStart => "splice_start",
            SpliceEnd => "splice_end",
            Rejoin => "rejoin",
            Evict => "evict",
            Backoff => "backoff",
            DecodeStep => "decode_step",
            StageRun => "stage_run",
        }
    }

    /// Terminal request-lifecycle kinds — each request records exactly
    /// one of these (pinned by `rust/tests/obs.rs`).
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            EventKind::Done | EventKind::Expired | EventKind::Cancelled | EventKind::Failed
        )
    }

    /// Kinds whose `id` is a shard index (rendered on the shard tracks).
    pub fn is_shard(self) -> bool {
        matches!(
            self,
            EventKind::ShardFault
                | EventKind::Reroute
                | EventKind::SpliceStart
                | EventKind::SpliceEnd
                | EventKind::Rejoin
                | EventKind::Evict
                | EventKind::Backoff
                | EventKind::StageRun
        )
    }
}

/// One trace record.  `Copy` and exactly five words so the ring can
/// move it without touching the heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Scheduler decode-step counter at record time (tick domain).
    pub tick: u64,
    pub kind: EventKind,
    /// Request id or shard index, per `kind`.
    pub id: u64,
    pub a: u64,
    pub b: u64,
}

impl Event {
    pub fn to_words(self) -> [u64; 5] {
        [self.tick, self.kind as u64, self.id, self.a, self.b]
    }

    pub fn from_words(w: [u64; 5]) -> Option<Event> {
        Some(Event { tick: w[0], kind: EventKind::from_u64(w[1])?, id: w[2], a: w[3], b: w[4] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_roundtrip_every_kind() {
        for k in 0..EVENT_KINDS as u64 {
            let kind = EventKind::from_u64(k).unwrap();
            let e = Event { tick: 7, kind, id: 3, a: 11, b: 13 };
            assert_eq!(Event::from_words(e.to_words()), Some(e));
        }
        assert_eq!(EventKind::from_u64(EVENT_KINDS as u64), None);
    }

    #[test]
    fn names_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for k in 0..EVENT_KINDS as u64 {
            assert!(seen.insert(EventKind::from_u64(k).unwrap().name()));
        }
    }
}
