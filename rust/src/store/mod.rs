//! Persistence substrate: JSON codec, the .eqz compressed-model
//! container, and the compression pipeline that produces it.

pub mod container;
pub mod json;
pub mod pipeline;

pub use container::{CompressedBlock, CompressedModel};
pub use pipeline::{compress_model, CompressOpts, CompressionReport};
