//! The model-level compression pipeline (paper Algorithm 1 applied per
//! layer, §A.1 block-joint ANS framing, §A.2 super-weight exclusions).
//!
//! This is the "<30 min for 70B" path: layers are independent, so the
//! per-layer RD optimizations fan out across the shared
//! `parallel::Pool` (work-stealing over layer jobs, deterministic
//! result order), and each block's ANS bitstream encodes its chunks on
//! the same pool.  `threads = 1` degenerates to the scalar loop and is
//! byte-identical to any other thread count.

use crate::ans::{Bitstream, DEFAULT_CHUNK};
use crate::model::{Model, BLOCK_LINEARS};
use crate::parallel::Pool;
use crate::quant::{superweight, Format};
use crate::rd::{calibrate_lambda, encode_layer, EncodeOpts, LayerStats};
use crate::store::container::{CompressedBlock, CompressedModel, LayerMeta};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct CompressOpts {
    /// Direct lambda; ignored when `target_bits` is set.
    pub lam: f64,
    /// If set, calibrate lambda by bisection on a probe layer (Fig A.1).
    pub target_bits: Option<f64>,
    pub fmt: Format,
    /// super-weight exclusion threshold (paper §A.2); None = no probing
    pub superweight_threshold: Option<f32>,
    pub max_iters: usize,
    pub chunk_size: usize,
    pub threads: usize,
}

impl Default for CompressOpts {
    fn default() -> Self {
        CompressOpts {
            lam: 0.1,
            target_bits: None,
            fmt: Format::F8E4M3,
            superweight_threshold: None,
            max_iters: 60,
            chunk_size: DEFAULT_CHUNK,
            threads: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CompressionReport {
    pub lam: f64,
    pub per_layer: Vec<(String, LayerStats)>,
    /// entropy over all linear-layer symbols (the paper's reported rate,
    /// which "always accounts for" super-weight-excluded layers)
    pub mean_entropy_bits: f64,
    pub effective_bits_per_param: f64,
    pub total_distortion: f64,
    pub mean_sparsity: f64,
    pub excluded_blocks: Vec<usize>,
    pub wall_s: f64,
    pub params_compressed: usize,
}

/// Compress a model end-to-end.  Data-free: only the weights go in.
// entlint: allow(no-panic-on-untrusted) — offline compression of an in-memory model:
// every index ranges over the model's own blocks/jobs vectors built above it; the
// non-empty ensure() guards the probe-layer access
pub fn compress_model(model: &Model, opts: &CompressOpts) -> Result<(CompressedModel, CompressionReport)> {
    let t0 = std::time::Instant::now();
    anyhow::ensure!(
        !model.blocks.is_empty() && model.linear_params() > 0,
        "compress_model: model has no linear parameters to compress"
    );

    // 0. lambda selection
    let lam = match opts.target_bits {
        Some(bits) => {
            // probe layer: the first block's gate projection is a good
            // stand-in (Fig A.1: the map is near model-independent)
            let probe = &model.blocks[0].w_gate;
            calibrate_lambda(probe, bits, opts.fmt)
        }
        None => opts.lam,
    };

    // 1. super-weight probe (single forward pass, paper A.2)
    let excluded_blocks: Vec<usize> = match opts.superweight_threshold {
        Some(th) if th.is_finite() => superweight::detect(model, th).excluded_blocks,
        _ => vec![],
    };

    // 2. per-layer RD optimization (parallel across layers)
    struct Job {
        block: usize,
        name: &'static str,
    }
    let jobs: Vec<Job> = (0..model.blocks.len())
        .flat_map(|b| BLOCK_LINEARS.iter().map(move |&name| Job { block: b, name }))
        .collect();

    let pool = Pool::new(opts.threads);
    let run_job = |j: &Job| {
        let w = model.blocks[j.block].linear(j.name);
        // paper A.2: excluded blocks' *down projections* skip the
        // entropy optimization and stay at 8-bit AbsMax
        let skip = j.name == "w_down" && excluded_blocks.contains(&j.block);
        encode_layer(
            w,
            &EncodeOpts { lam, fmt: opts.fmt, max_iters: opts.max_iters, skip_optimization: skip },
        )
    };
    let results: Vec<(crate::quant::QMat, LayerStats)> =
        pool.par_map_indexed(jobs.len(), |i| run_job(&jobs[i]));

    // 3. block-joint ANS framing (paper A.1: one bitstream per block)
    let mut blocks = Vec::with_capacity(model.blocks.len());
    let mut per_layer = Vec::new();
    let mut hist_total = [0u64; 256];
    let mut params = 0usize;
    let mut dist_weighted = 0.0f64;
    let mut sparsity_weighted = 0.0f64;
    for (b, bw) in model.blocks.iter().enumerate() {
        let mut symbols: Vec<u8> = Vec::new();
        let mut layers = Vec::new();
        for (li, &name) in BLOCK_LINEARS.iter().enumerate() {
            let (q, stats) = &results[b * BLOCK_LINEARS.len() + li];
            let n = q.symbols.len();
            symbols.extend_from_slice(&q.symbols);
            layers.push(LayerMeta {
                name: name.to_string(),
                rows: q.rows,
                cols: q.cols,
                scales: std::sync::Arc::new(q.scales.clone()),
                excluded: name == "w_down" && excluded_blocks.contains(&b),
            });
            per_layer.push((format!("blocks.{b}.{name}"), stats.clone()));
            params += n;
            dist_weighted += stats.distortion * n as f64;
            sparsity_weighted += stats.sparsity * n as f64;
        }
        let h = crate::entropy::histogram(&symbols);
        for i in 0..256 {
            hist_total[i] += h[i];
        }
        let bitstream = Bitstream::encode_parallel(&symbols, opts.chunk_size, opts.threads);
        blocks.push(std::sync::Arc::new(CompressedBlock {
            layers,
            bitstream,
            norm_attn: bw.norm_attn.clone(),
            norm_mlp: bw.norm_mlp.clone(),
        }));
    }

    // Arc-backed shared storage from birth: every downstream consumer
    // (shard slices, retained reroute containers, engine views) shares
    // these allocations instead of deep-copying them.
    let cm = CompressedModel {
        config: model.config.clone(),
        fmt: opts.fmt,
        embed: (&model.embed).into(),
        head: (&model.head).into(),
        norm_final: std::sync::Arc::new(model.norm_final.clone()),
        blocks,
    };
    let report = CompressionReport {
        lam,
        mean_entropy_bits: crate::entropy::entropy_bits(&hist_total),
        effective_bits_per_param: cm.effective_bits_per_param(),
        total_distortion: dist_weighted / params as f64,
        mean_sparsity: sparsity_weighted / params as f64,
        excluded_blocks,
        wall_s: t0.elapsed().as_secs_f64(),
        params_compressed: params,
        per_layer,
    };
    Ok((cm, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loader::synthetic_model;
    use crate::model::Config;

    fn tiny(seed: u64) -> Model {
        synthetic_model(
            Config { name: "T".into(), vocab: 64, d_model: 16, n_layers: 3, n_heads: 2, d_ff: 24, max_ctx: 32 },
            seed,
        )
    }

    #[test]
    fn roundtrip_reconstruction_is_lossless_wrt_quantized() {
        let m = tiny(1);
        let (cm, _) = compress_model(&m, &CompressOpts { lam: 0.2, ..Default::default() }).unwrap();
        // decode and requantize: the ANS stage is lossless, so decoding
        // must give back exactly the quantized symbols
        let q = cm.to_qmodel().unwrap();
        for (b, bw) in m.blocks.iter().enumerate() {
            for (li, &name) in BLOCK_LINEARS.iter().enumerate() {
                let qm = &q.blocks[b].linears[li];
                let requant = crate::quant::quantize(bw.linear(name), &qm.scales, qm.fmt);
                assert_eq!(qm.symbols, requant.symbols, "block {b} {name}");
            }
        }
    }

    #[test]
    fn higher_lambda_fewer_bits() {
        let m = tiny(2);
        let (_, r1) = compress_model(&m, &CompressOpts { lam: 0.01, ..Default::default() }).unwrap();
        let (_, r2) = compress_model(&m, &CompressOpts { lam: 10.0, ..Default::default() }).unwrap();
        assert!(r2.mean_entropy_bits < r1.mean_entropy_bits - 0.3,
                "{} vs {}", r2.mean_entropy_bits, r1.mean_entropy_bits);
        assert!(r2.total_distortion > r1.total_distortion);
    }

    #[test]
    fn target_bits_calibration() {
        let m = synthetic_model(
            Config { name: "T".into(), vocab: 64, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 96, max_ctx: 32 },
            3,
        );
        let (_, rep) = compress_model(
            &m,
            &CompressOpts { target_bits: Some(4.0), ..Default::default() },
        ).unwrap();
        assert!((rep.mean_entropy_bits - 4.0).abs() < 1.2, "{}", rep.mean_entropy_bits);
    }

    #[test]
    fn parallel_matches_scalar() {
        let m = tiny(4);
        let (c1, _) = compress_model(&m, &CompressOpts { lam: 0.3, threads: 1, ..Default::default() }).unwrap();
        let (c2, _) = compress_model(&m, &CompressOpts { lam: 0.3, threads: 4, ..Default::default() }).unwrap();
        assert_eq!(c1.serialize(), c2.serialize());
    }

    #[test]
    fn empty_model_is_error_not_nan() {
        // zero linear params would otherwise divide to NaN in the report
        let m = synthetic_model(
            Config { name: "E".into(), vocab: 8, d_model: 4, n_layers: 0, n_heads: 1, d_ff: 8, max_ctx: 8 },
            9,
        );
        assert!(compress_model(&m, &CompressOpts::default()).is_err());
    }

    #[test]
    fn superweight_exclusion_marks_layers() {
        let mut m = tiny(5);
        crate::quant::superweight::plant_super_weight(&mut m, 1, 50.0);
        let base = crate::quant::superweight::detect(&m, f32::INFINITY);
        let th = base.activation_maxima[1] / 2.0;
        let (cm, rep) = compress_model(
            &m,
            &CompressOpts { lam: 5.0, superweight_threshold: Some(th), ..Default::default() },
        ).unwrap();
        assert!(rep.excluded_blocks.contains(&1));
        let idx = BLOCK_LINEARS.iter().position(|&n| n == "w_down").unwrap();
        assert!(cm.blocks[1].layers[idx].excluded);
        assert!(!cm.blocks[0].layers[idx].excluded || rep.excluded_blocks.contains(&0));
    }

    #[test]
    fn report_accounting_consistent() {
        let m = tiny(6);
        let (cm, rep) = compress_model(&m, &CompressOpts::default()).unwrap();
        assert_eq!(rep.params_compressed, m.linear_params());
        assert_eq!(rep.per_layer.len(), 21);
        assert!((rep.effective_bits_per_param - cm.effective_bits_per_param()).abs() < 1e-9);
        assert!(rep.wall_s >= 0.0);
    }
}
