//! Minimal JSON parser/writer (serde is not available in this image).
//!
//! Supports the full JSON grammar we produce and consume: the artifact
//! manifest, .eqw headers, fixtures, eval-task files and bench reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&Vec<Value>> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Path lookup: `v.path(&["config", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn f64_array(&self) -> Option<Vec<f64>> {
        self.as_array()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }
}

// ---------------------------------------------------------------- parser

/// Containers may nest at most this deep — parsing is recursive, so
/// unbounded nesting in hostile input would overflow the stack (the
/// `.eqz` loader hands this parser untrusted bytes before any crc
/// check can reject them).
const MAX_DEPTH: usize = 128;

pub fn parse(text: &str) -> Result<Value, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0, depth: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    // entlint: allow(no-panic-on-untrusted) — `b[i]` sits behind the `i < b.len()`
    // guard on the same line
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.nested(Self::object),
            Some(b'[') => self.nested(Self::array),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn nested(
        &mut self,
        f: fn(&mut Self) -> Result<Value, String>,
    ) -> Result<Value, String> {
        if self.depth >= MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        self.depth += 1;
        let v = f(self);
        self.depth -= 1;
        v
    }

    // entlint: allow(no-panic-on-untrusted) — the cursor invariant `i <= b.len()`
    // holds everywhere (i only advances past bytes peek() saw), so `b[i..]` cannot
    // panic; starts_with handles the short-tail case
    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    // entlint: allow(no-panic-on-untrusted) — `b[start..i]` with start <= i <= b.len()
    // by the cursor invariant (i only advances past bytes peek() saw)
    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    // entlint: allow(no-panic-on-untrusted) — `b[start..i]` with start <= i <= b.len()
    // by the cursor invariant; every escape branch re-checks peek() before advancing
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            // no surrogate-pair support needed for our data
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Array(out));
                }
                other => return Err(format!("expected , or ] got {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Object(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Object(out));
                }
                other => return Err(format!("expected , or }} got {other:?} at {}", self.i)),
            }
        }
    }
}

// ---------------------------------------------------------------- writer

pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Array(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(v, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Value::Str(k.clone()), out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builders used across the bench harness.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
    Value::Array(items.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "x\"y\n"}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.path(&["b", "c"]), Some(&Value::Null));
        assert_eq!(v.get("a").unwrap().f64_array().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("e").unwrap().as_str().unwrap(), "x\"y\n");
        let text2 = write(&v);
        assert_eq!(parse(&text2).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(BTreeMap::new()));
        assert_eq!(parse(" [ { } , [ ] ] ").unwrap(),
                   Value::Array(vec![Value::Object(BTreeMap::new()), Value::Array(vec![])]));
    }

    #[test]
    fn numbers() {
        for (t, want) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0),
                          ("2.5E-2", 0.025), ("123456789", 123456789.0)] {
            assert_eq!(parse(t).unwrap().as_f64().unwrap(), want, "{t}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn rejects_hostile_nesting_without_stack_overflow() {
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        assert!(parse(&deep).is_err());
        // but legitimate nesting below the cap still parses
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn writer_escapes() {
        let v = s("a\"b\\c\nd");
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(write(&num(42.0)), "42");
        assert_eq!(write(&num(0.5)), "0.5");
    }
}
