//! The .eqz compressed-model container — what EntQuant ships instead of
//! a checkpoint: per-transformer-block ANS bitstreams (paper §A.1 joint
//! block-wise framing), channel scales, norms, and the uncompressed
//! high-precision embed/head tensors.
//!
//! Wire layout (little endian):
//!   magic  b"EQZ2"
//!   u32    header_len
//!   u32    crc32 over header + f32 region + bitstream region
//!   bytes  JSON header (config, fmt, block metadata, offsets)
//!   bytes  f32 region: embed | head | norm_final | per-block norms+scales
//!   bytes  per-block serialized Bitstreams
//!
//! Robustness contract (exercised by tests/corruption.rs): `.eqz` bytes
//! are treated as untrusted.  Every offset/length in the header is
//! bounds-checked, the container-wide crc32 must match, and per-block
//! layer shapes must agree with the embedded bitstreams — so corrupt or
//! truncated files load as `Err`, never a panic or a silent mis-decode.

use crate::ans::Bitstream;
use crate::model::{Config, Model, QBlock, QModel};
use crate::quant::{Format, QMat};
use crate::store::json::{self, arr, num, obj, s, Value};
use crate::tensor::Mat;
use crate::util::crc32;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"EQZ2";
/// magic + header_len + crc32
const PREFIX_LEN: usize = 12;

/// A row-major f32 matrix whose storage is reference-counted: slicing
/// a model per shard, retaining the pristine container across a
/// reroute, or handing the embed table to an engine bumps a refcount
/// instead of copying `vocab x d_model` floats.  The serving engines
/// build zero-copy `HostTensor::F32View`s straight over `data`, so a
/// tensor exists exactly once in memory however many engines share it.
#[derive(Clone, Debug, PartialEq)]
pub struct SharedMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Arc<Vec<f32>>,
}

impl SharedMat {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> SharedMat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        SharedMat { rows, cols, data: Arc::new(data) }
    }

    /// Materialize an owned `Mat` (offline-eval paths only; the serving
    /// stack never needs this copy).
    pub fn to_mat(&self) -> Mat {
        Mat::from_vec(self.rows, self.cols, (*self.data).clone())
    }
}

impl From<Mat> for SharedMat {
    fn from(m: Mat) -> SharedMat {
        SharedMat { rows: m.rows, cols: m.cols, data: Arc::new(m.data) }
    }
}

impl From<&Mat> for SharedMat {
    fn from(m: &Mat) -> SharedMat {
        SharedMat { rows: m.rows, cols: m.cols, data: Arc::new(m.data.clone()) }
    }
}

#[derive(Clone)]
pub struct LayerMeta {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Arc-backed so engine `BlockConsts` can view the same allocation
    /// (`HostTensor::f32_view`) instead of cloning per shard — the last
    /// weight-derived per-shard copies (`weight_copies == 1` tests pin
    /// the sharing)
    pub scales: Arc<Vec<f32>>,
    /// super-weight exclusion: quantized at plain AbsMax (still ANS coded)
    pub excluded: bool,
}

#[derive(Clone)]
pub struct CompressedBlock {
    pub layers: Vec<LayerMeta>, // order: BLOCK_LINEARS
    pub bitstream: Bitstream,   // joint symbols of all 7 linears
    pub norm_attn: Vec<f32>,
    pub norm_mlp: Vec<f32>,
}

impl CompressedBlock {
    pub fn n_symbols(&self) -> usize {
        self.layers.iter().map(|l| l.rows * l.cols).sum()
    }

    /// Byte offsets of each layer inside the decoded symbol buffer.
    pub fn layer_offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.layers.len());
        let mut off = 0;
        for l in &self.layers {
            let n = l.rows * l.cols;
            out.push((off, n));
            off += n;
        }
        out
    }
}

/// The in-memory container.  Every weight-bearing field is Arc-backed
/// (`SharedMat` / `Arc<Vec<f32>>` / `Vec<Arc<CompressedBlock>>`), so
/// `clone()`, per-shard slicing, and the reroute-retained pristine copy
/// all share one underlying allocation per tensor/block — the serving
/// stack's "exactly one logical copy" invariant
/// (`ShardedEngine::weight_copies`) rests on this.
#[derive(Clone)]
pub struct CompressedModel {
    pub config: Config,
    pub fmt: Format,
    pub embed: SharedMat,
    pub head: SharedMat,
    pub norm_final: Arc<Vec<f32>>,
    pub blocks: Vec<Arc<CompressedBlock>>,
}

impl CompressedModel {
    /// Effective bits per *linear* parameter: everything EntQuant must
    /// store for the compressed linears (bitstreams incl. freq tables &
    /// chunk index, plus BF16-equivalent scales), matching the paper's
    /// accounting (embeddings/head excluded, as in Tables 2/C.*).
    pub fn effective_bits_per_param(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut params = 0usize;
        for b in &self.blocks {
            bits += b.bitstream.serialized_len() as f64 * 8.0;
            for l in &b.layers {
                bits += l.scales.len() as f64 * 16.0; // scales stored BF16
                params += l.rows * l.cols;
            }
        }
        if params == 0 {
            return 0.0;
        }
        bits / params as f64
    }

    /// Total size in bytes of the serialized container.
    pub fn total_bytes(&self) -> usize {
        self.serialize().len()
    }

    /// Serialized bitstream bytes across all blocks — the compressed
    /// payload a serving process must keep resident (the
    /// `resident_compressed_bytes` gauge counts these, deduplicated by
    /// shared storage).
    pub fn compressed_stream_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bitstream.serialized_len()).sum()
    }

    /// Mutable access to block `i`, copy-on-write: blocks are shared
    /// (`Arc`) across container clones and shard slices, so mutating
    /// through a shared handle first unshares that one block.  Tests
    /// use this to plant in-memory corruption; production code never
    /// mutates blocks after compression.
    // entlint: allow(no-panic-on-untrusted) — in-process handle API: `i` is a
    // caller-chosen block index, not container data; out-of-range is a programming
    // error and should panic loudly
    pub fn block_mut(&mut self, i: usize) -> &mut CompressedBlock {
        Arc::make_mut(&mut self.blocks[i])
    }

    /// A sub-model holding blocks `range` of this container — the one
    /// authoritative slicing site (shard slices and rejoin sub-models
    /// both route through it).  Cheap: blocks are `Arc` bumps, and
    /// embed/head/final-norm ride along as shared handles so any slice
    /// can later be promoted to first/last pipeline duty without
    /// touching the container again.
    // entlint: allow(no-panic-on-untrusted) — `range` comes from shard planning over
    // this container's own n_blocks(), not from the wire; a bad plan is a programming
    // error
    pub fn slice_range(&self, range: std::ops::Range<usize>) -> CompressedModel {
        CompressedModel {
            config: self.config.clone(),
            fmt: self.fmt,
            embed: self.embed.clone(),
            head: self.head.clone(),
            norm_final: Arc::clone(&self.norm_final),
            blocks: self.blocks[range].to_vec(),
        }
    }

    /// Decode block `i`'s symbols into `buf` (len == n_symbols(i)).
    pub fn decode_block_into(&self, i: usize, buf: &mut [u8], threads: usize) -> Result<()> {
        let block = self.blocks.get(i).ok_or_else(|| {
            anyhow!("block {i} out of range ({} blocks)", self.blocks.len())
        })?;
        block
            .bitstream
            .decode_into(buf, threads)
            .map_err(|e| anyhow!("block {i}: {e}"))
    }

    /// Fused serving path: decode block `i` straight to f32 codes
    /// through the format's 256-entry dequant LUT (no intermediate
    /// symbol buffer).  `out.len()` must equal `n_symbols(i)`.
    pub fn decode_block_fused_into(
        &self,
        i: usize,
        out: &mut [f32],
        lut: &[f32; 256],
        threads: usize,
    ) -> Result<()> {
        let block = self.blocks.get(i).ok_or_else(|| {
            anyhow!("block {i} out of range ({} blocks)", self.blocks.len())
        })?;
        block
            .bitstream
            .decode_fused_into(out, lut, threads)
            .map_err(|e| anyhow!("block {i}: {e}"))
    }

    /// Offline-eval path: reconstruct the QModel (and from there a
    /// dequantized f32 model).
    // entlint: allow(no-panic-on-untrusted) — `buf[off..off + n]` offsets come from
    // layer_offsets(), which sums this block's own layer dims and allocated buf to
    // exactly that total; untrusted bytes were already validated by deserialize
    pub fn to_qmodel(&self) -> Result<QModel> {
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (i, cb) in self.blocks.iter().enumerate() {
            let mut buf = vec![0u8; cb.n_symbols()];
            self.decode_block_into(i, &mut buf, 1)?;
            let mut linears = Vec::with_capacity(cb.layers.len());
            for ((off, n), l) in cb.layer_offsets().into_iter().zip(&cb.layers) {
                linears.push(QMat {
                    rows: l.rows,
                    cols: l.cols,
                    fmt: self.fmt,
                    symbols: buf[off..off + n].to_vec(),
                    scales: (*l.scales).clone(),
                });
            }
            blocks.push(QBlock {
                linears,
                norm_attn: cb.norm_attn.clone(),
                norm_mlp: cb.norm_mlp.clone(),
            });
        }
        Ok(QModel {
            config: self.config.clone(),
            embed: self.embed.to_mat(),
            blocks,
            norm_final: (*self.norm_final).clone(),
            head: self.head.to_mat(),
        })
    }

    /// Convenience: dequantized f32 model for the eval harness.
    pub fn to_model(&self) -> Result<Model> {
        Ok(self.to_qmodel()?.dequantize())
    }

    // ------------------------------------------------------------ wire

    // entlint: allow(no-panic-on-untrusted) — serialization of an in-memory container;
    // the crc patch slices a buffer this fn just wrote (always >= PREFIX_LEN bytes)
    pub fn serialize(&self) -> Vec<u8> {
        let mut f32_region: Vec<u8> = Vec::new();
        let push_f32s = |region: &mut Vec<u8>, vals: &[f32]| -> (usize, usize) {
            let off = region.len();
            for &v in vals {
                region.extend_from_slice(&v.to_le_bytes());
            }
            (off, vals.len())
        };

        let (embed_off, _) = push_f32s(&mut f32_region, &self.embed.data);
        let (head_off, _) = push_f32s(&mut f32_region, &self.head.data);
        let (nf_off, _) = push_f32s(&mut f32_region, &self.norm_final);

        // scales ship as BF16 (2 bytes each, paper §2.2); the encoder
        // already rounded them onto the bf16 grid so this is lossless
        let push_bf16s = |region: &mut Vec<u8>, vals: &[f32]| -> (usize, usize) {
            let off = region.len();
            for &v in vals {
                region.extend_from_slice(&crate::quant::bf16::encode(v).to_le_bytes());
            }
            (off, vals.len())
        };

        let mut bs_region: Vec<u8> = Vec::new();
        let mut block_meta: Vec<Value> = Vec::new();
        for cb in &self.blocks {
            let (na_off, _) = push_f32s(&mut f32_region, &cb.norm_attn);
            let (nm_off, _) = push_f32s(&mut f32_region, &cb.norm_mlp);
            let mut layer_meta = Vec::new();
            for l in &cb.layers {
                let (s_off, _) = push_bf16s(&mut f32_region, &l.scales);
                layer_meta.push(obj(vec![
                    ("name", s(&l.name)),
                    ("rows", num(l.rows as f64)),
                    ("cols", num(l.cols as f64)),
                    ("scales_off", num(s_off as f64)),
                    ("excluded", Value::Bool(l.excluded)),
                ]));
            }
            let ser = cb.bitstream.serialize();
            let bs_off = bs_region.len();
            bs_region.extend_from_slice(&ser);
            block_meta.push(obj(vec![
                ("layers", Value::Array(layer_meta)),
                ("norm_attn_off", num(na_off as f64)),
                ("norm_mlp_off", num(nm_off as f64)),
                ("bs_off", num(bs_off as f64)),
                ("bs_len", num(ser.len() as f64)),
            ]));
        }

        let header = obj(vec![
            ("config", obj(vec![
                ("name", s(&self.config.name)),
                ("vocab", num(self.config.vocab as f64)),
                ("d_model", num(self.config.d_model as f64)),
                ("n_layers", num(self.config.n_layers as f64)),
                ("n_heads", num(self.config.n_heads as f64)),
                ("d_ff", num(self.config.d_ff as f64)),
                ("max_ctx", num(self.config.max_ctx as f64)),
            ])),
            ("fmt", s(self.fmt.name())),
            ("embed_off", num(embed_off as f64)),
            ("head_off", num(head_off as f64)),
            ("norm_final_off", num(nf_off as f64)),
            ("f32_region_len", num(f32_region.len() as f64)),
            ("bs_region_len", num(bs_region.len() as f64)),
            ("blocks", arr(block_meta)),
        ]);
        let htext = json::write(&header);
        let mut out =
            Vec::with_capacity(PREFIX_LEN + htext.len() + f32_region.len() + bs_region.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(htext.len() as u32).to_le_bytes());
        out.extend_from_slice(&[0u8; 4]); // crc placeholder
        out.extend_from_slice(htext.as_bytes());
        out.extend_from_slice(&f32_region);
        out.extend_from_slice(&bs_region);
        let crc = crc32(&out[PREFIX_LEN..]);
        out[8..PREFIX_LEN].copy_from_slice(&crc.to_le_bytes());
        out
    }

    // entlint: allow(no-panic-on-untrusted) — every region slice sits below the
    // PREFIX_LEN guard or the overflow-checked `extent <= bytes.len()` check;
    // try_into on exact 4-/2-byte chunks (chunks_exact) is infallible
    pub fn deserialize(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < PREFIX_LEN || &bytes[..4] != MAGIC {
            bail!("bad .eqz magic (or pre-EQZ2 container)");
        }
        let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let crc_stored = u32::from_le_bytes(bytes[8..PREFIX_LEN].try_into().unwrap());
        let htext = checked_slice(bytes, PREFIX_LEN, hlen, "header")?;
        let header = json::parse(std::str::from_utf8(htext)?)
            .map_err(|e| anyhow!("eqz header: {e}"))?;

        let g = |v: &Value, k: &str| -> Result<usize> {
            v.get(k).and_then(|x| x.as_usize()).ok_or(anyhow!("eqz header missing {k}"))
        };
        let f32_len = g(&header, "f32_region_len")?;
        let bs_len = g(&header, "bs_region_len")?;
        let f32_start = PREFIX_LEN + hlen;
        let extent = f32_start
            .checked_add(f32_len)
            .and_then(|x| x.checked_add(bs_len))
            .ok_or(anyhow!("corrupt .eqz: region lengths overflow"))?;
        if bytes.len() < extent {
            bail!(".eqz truncated: {} bytes, header claims {extent}", bytes.len());
        }
        if crc32(&bytes[PREFIX_LEN..extent]) != crc_stored {
            bail!("corrupt .eqz: crc32 mismatch");
        }
        let f32_region = &bytes[f32_start..f32_start + f32_len];
        let bs_region = &bytes[f32_start + f32_len..extent];

        let config = Config::from_json(header.get("config").ok_or(anyhow!("no config"))?)
            .map_err(|e| anyhow!(e))?;
        let fmt = match header.get("fmt").and_then(|v| v.as_str()) {
            Some("f8e4m3") => Format::F8E4M3,
            Some("int8") => Format::Int8,
            other => bail!("bad fmt {other:?}"),
        };

        let read_f32s = |off: usize, n: usize, what: &str| -> Result<Vec<f32>> {
            let raw = checked_slice(f32_region, off, n.checked_mul(4).ok_or(anyhow!("corrupt .eqz: {what} length overflow"))?, what)?;
            Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
        };
        let read_bf16s = |off: usize, n: usize, what: &str| -> Result<Vec<f32>> {
            let raw = checked_slice(f32_region, off, n.checked_mul(2).ok_or(anyhow!("corrupt .eqz: {what} length overflow"))?, what)?;
            Ok(raw
                .chunks_exact(2)
                .map(|c| crate::quant::bf16::decode(u16::from_le_bytes(c.try_into().unwrap())))
                .collect())
        };

        let (d, v) = (config.d_model, config.vocab);
        let vd = v.checked_mul(d).ok_or(anyhow!("corrupt .eqz: vocab*d_model overflows"))?;
        let embed = SharedMat::new(v, d, read_f32s(g(&header, "embed_off")?, vd, "embed")?);
        let head = SharedMat::new(v, d, read_f32s(g(&header, "head_off")?, vd, "head")?);
        let norm_final = Arc::new(read_f32s(g(&header, "norm_final_off")?, d, "norm_final")?);

        let mut blocks = Vec::new();
        for (bi, bm) in header
            .get("blocks")
            .and_then(|x| x.as_array())
            .ok_or(anyhow!("blocks"))?
            .iter()
            .enumerate()
        {
            let bs_off = g(bm, "bs_off")?;
            let bs_bytes = checked_slice(bs_region, bs_off, g(bm, "bs_len")?, "bitstream")?;
            let (bitstream, _) = Bitstream::deserialize(bs_bytes)
                .map_err(|e| anyhow!("block {bi} bitstream: {e}"))?;
            let mut layers = Vec::new();
            let mut symbols = 0usize;
            for lm in bm.get("layers").and_then(|x| x.as_array()).ok_or(anyhow!("layers"))? {
                let rows = g(lm, "rows")?;
                let cols = g(lm, "cols")?;
                symbols = rows
                    .checked_mul(cols)
                    .and_then(|n| symbols.checked_add(n))
                    .ok_or(anyhow!("corrupt .eqz: block {bi} layer shape overflows"))?;
                layers.push(LayerMeta {
                    name: lm.get("name").and_then(|x| x.as_str()).unwrap_or("?").to_string(),
                    rows,
                    cols,
                    scales: Arc::new(read_bf16s(g(lm, "scales_off")?, rows, "scales")?),
                    excluded: lm.get("excluded").and_then(|x| x.as_bool()).unwrap_or(false),
                });
            }
            // layer shapes must account for exactly the symbols the
            // bitstream holds, or block decode would mis-slice
            if symbols != bitstream.n_symbols {
                bail!(
                    "corrupt .eqz: block {bi} layers claim {symbols} symbols, bitstream holds {}",
                    bitstream.n_symbols
                );
            }
            blocks.push(Arc::new(CompressedBlock {
                layers,
                bitstream,
                norm_attn: read_f32s(g(bm, "norm_attn_off")?, d, "norm_attn")?,
                norm_mlp: read_f32s(g(bm, "norm_mlp_off")?, d, "norm_mlp")?,
            }));
        }
        Ok(CompressedModel { config, fmt, embed, head, norm_final, blocks })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.serialize()).with_context(|| format!("writing {path}"))
    }

    pub fn load(path: &str) -> Result<Self> {
        Self::deserialize(&std::fs::read(path).with_context(|| format!("reading {path}"))?)
    }
}

/// Bounds-checked subslice: `bytes[off..off + len]` or a descriptive
/// error (never a panic) when the range is out of bounds or overflows.
// entlint: allow(no-panic-on-untrusted) — this IS the checked-slice helper: the
// `bytes[off..end]` below is only reached after the overflow and bounds guards
fn checked_slice<'a>(bytes: &'a [u8], off: usize, len: usize, what: &str) -> Result<&'a [u8]> {
    let end = off
        .checked_add(len)
        .ok_or_else(|| anyhow!("corrupt .eqz: {what} range overflows"))?;
    if end > bytes.len() {
        bail!("corrupt .eqz: {what} out of bounds ({off}+{len} > {})", bytes.len());
    }
    Ok(&bytes[off..end])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loader::synthetic_model;
    use crate::store::pipeline::{compress_model, CompressOpts};

    fn tiny() -> crate::model::Model {
        synthetic_model(
            Config { name: "T".into(), vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, max_ctx: 32 },
            11,
        )
    }

    #[test]
    fn serialize_roundtrip_preserves_decode() {
        let m = tiny();
        let (cm, _) = compress_model(&m, &CompressOpts { lam: 0.5, ..Default::default() }).unwrap();
        let ser = cm.serialize();
        let cm2 = CompressedModel::deserialize(&ser).unwrap();
        let q1 = cm.to_qmodel().unwrap();
        let q2 = cm2.to_qmodel().unwrap();
        for b in 0..2 {
            for l in 0..7 {
                assert_eq!(q1.blocks[b].linears[l].symbols, q2.blocks[b].linears[l].symbols);
                assert_eq!(q1.blocks[b].linears[l].scales, q2.blocks[b].linears[l].scales);
            }
        }
        assert_eq!(cm2.config, m.config);
    }

    #[test]
    fn effective_bits_reasonable() {
        let m = tiny();
        let (cm, _) = compress_model(&m, &CompressOpts { lam: 0.01, ..Default::default() }).unwrap();
        let bits = cm.effective_bits_per_param();
        // tiny layers: metadata dominates, but must stay well under 16
        assert!(bits > 0.5 && bits < 16.0, "{bits}");
    }

    #[test]
    fn corrupt_rejected() {
        let m = tiny();
        let (cm, _) = compress_model(&m, &CompressOpts::default()).unwrap();
        let mut ser = cm.serialize();
        ser[0] = b'X';
        assert!(CompressedModel::deserialize(&ser).is_err());
    }

    #[test]
    fn mismatched_layer_shapes_rejected() {
        let m = tiny();
        let (mut cm, _) = compress_model(&m, &CompressOpts::default()).unwrap();
        // in-memory tamper: layer metadata no longer matches the
        // bitstream symbol count; serialize then reload must reject
        cm.block_mut(0).layers[0].rows += 1;
        let ser = cm.serialize();
        assert!(CompressedModel::deserialize(&ser).is_err());
        // decode on the tampered in-memory struct errors (no panic)
        let mut buf = vec![0u8; cm.blocks[0].n_symbols()];
        assert!(cm.decode_block_into(0, &mut buf, 1).is_err());
    }

    #[test]
    fn out_of_range_block_is_error() {
        let m = tiny();
        let (cm, _) = compress_model(&m, &CompressOpts::default()).unwrap();
        let mut buf = vec![0u8; 16];
        assert!(cm.decode_block_into(99, &mut buf, 1).is_err());
    }
}
