//! .eqw checkpoint loader — the rust half of python/compile/eqw_io.py.
//!
//! Layout: b"EQW1" | u32 header_len | JSON header | raw f32 data.

use super::{BlockWeights, Config, Model};
use crate::store::json;
use crate::tensor::Mat;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

pub fn load_eqw(path: &str) -> Result<Model> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    parse_eqw(&bytes).with_context(|| format!("parsing {path}"))
}

pub fn parse_eqw(bytes: &[u8]) -> Result<Model> {
    if bytes.len() < 8 || &bytes[..4] != b"EQW1" {
        bail!("bad .eqw magic");
    }
    let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    if bytes.len() < 8 + hlen {
        bail!(".eqw truncated header");
    }
    let header = json::parse(std::str::from_utf8(&bytes[8..8 + hlen])?)
        .map_err(|e| anyhow!("header json: {e}"))?;
    let data = &bytes[8 + hlen..];

    let config = Config::from_json(header.get("config").ok_or(anyhow!("no config"))?)
        .map_err(|e| anyhow!(e))?;

    let mut tensors: HashMap<String, Mat> = HashMap::new();
    for rec in header.get("tensors").and_then(|t| t.as_array()).ok_or(anyhow!("no tensors"))? {
        let name = rec.get("name").and_then(|v| v.as_str()).ok_or(anyhow!("tensor name"))?;
        let shape: Vec<usize> = rec
            .get("shape")
            .and_then(|v| v.f64_array())
            .ok_or(anyhow!("tensor shape"))?
            .iter()
            .map(|&x| x as usize)
            .collect();
        let offset = rec.get("offset").and_then(|v| v.as_usize()).ok_or(anyhow!("offset"))?;
        let nbytes = rec.get("nbytes").and_then(|v| v.as_usize()).ok_or(anyhow!("nbytes"))?;
        // header offsets are untrusted: checked arithmetic (a huge
        // offset must not wrap past the bounds test) and an exact
        // f32-multiple length, then bulk-parse 4-byte chunks
        let end = offset
            .checked_add(nbytes)
            .ok_or_else(|| anyhow!("tensor {name} range overflows"))?;
        if end > data.len() {
            bail!("tensor {name} out of bounds ({offset}+{nbytes} > {})", data.len());
        }
        if nbytes % 4 != 0 {
            bail!("tensor {name} byte length {nbytes} is not a multiple of 4");
        }
        let vals: Vec<f32> = data[offset..end]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let (rows, cols) = match shape.len() {
            1 => (1, shape[0]),
            2 => (shape[0], shape[1]),
            _ => bail!("unsupported rank for {name}"),
        };
        let want = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow!("tensor {name} shape overflows"))?;
        if vals.len() != want {
            bail!("tensor {name}: {} f32s but shape {rows}x{cols}", vals.len());
        }
        tensors.insert(name.to_string(), Mat::from_vec(rows, cols, vals));
    }

    let take_mat = |t: &mut HashMap<String, Mat>, name: &str| -> Result<Mat> {
        t.remove(name).ok_or(anyhow!("missing tensor {name}"))
    };
    let take_vec = |t: &mut HashMap<String, Mat>, name: &str| -> Result<Vec<f32>> {
        Ok(take_mat(t, name)?.data)
    };

    let mut t = tensors;
    let embed = take_mat(&mut t, "embed")?;
    let mut blocks = Vec::with_capacity(config.n_layers);
    for i in 0..config.n_layers {
        let p = |f: &str| format!("blocks.{i}.{f}");
        blocks.push(BlockWeights {
            wq: take_mat(&mut t, &p("wq"))?,
            wk: take_mat(&mut t, &p("wk"))?,
            wv: take_mat(&mut t, &p("wv"))?,
            wo: take_mat(&mut t, &p("wo"))?,
            w_gate: take_mat(&mut t, &p("w_gate"))?,
            w_up: take_mat(&mut t, &p("w_up"))?,
            w_down: take_mat(&mut t, &p("w_down"))?,
            norm_attn: take_vec(&mut t, &p("norm_attn"))?,
            norm_mlp: take_vec(&mut t, &p("norm_mlp"))?,
        });
    }
    let norm_final = take_vec(&mut t, "norm_final")?;
    let head = take_mat(&mut t, "head")?;

    // sanity: shapes must agree with the config
    let (d, f, v) = (config.d_model, config.d_ff, config.vocab);
    if embed.rows != v || embed.cols != d {
        bail!("embed shape {}x{} != {v}x{d}", embed.rows, embed.cols);
    }
    for (i, b) in blocks.iter().enumerate() {
        if b.wq.rows != d || b.wq.cols != d || b.w_gate.rows != f || b.w_down.cols != f {
            bail!("block {i} shapes inconsistent with config");
        }
    }

    Ok(Model { config, embed, blocks, norm_final, head })
}

/// Write a Model back to .eqw (used by tests and the synthetic-model
/// generators in the bench harness).
pub fn write_eqw(path: &str, model: &Model) -> Result<()> {
    use json::{arr, num, obj, s, Value};

    let mut records: Vec<Value> = Vec::new();
    let mut blob: Vec<u8> = Vec::new();
    let push = |records: &mut Vec<Value>, blob: &mut Vec<u8>, name: &str, m: &Mat, rank1: bool| {
        while blob.len() % 16 != 0 {
            blob.push(0);
        }
        let offset = blob.len();
        for &v in &m.data {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        let shape = if rank1 {
            arr(vec![num(m.cols as f64)])
        } else {
            arr(vec![num(m.rows as f64), num(m.cols as f64)])
        };
        records.push(obj(vec![
            ("name", s(name)),
            ("shape", shape),
            ("dtype", s("f32")),
            ("offset", num(offset as f64)),
            ("nbytes", num((m.data.len() * 4) as f64)),
        ]));
    };

    push(&mut records, &mut blob, "embed", &model.embed, false);
    for (i, b) in model.blocks.iter().enumerate() {
        for name in super::BLOCK_LINEARS {
            push(&mut records, &mut blob, &format!("blocks.{i}.{name}"), b.linear(name), false);
        }
        let na = Mat::from_vec(1, b.norm_attn.len(), b.norm_attn.clone());
        let nm = Mat::from_vec(1, b.norm_mlp.len(), b.norm_mlp.clone());
        push(&mut records, &mut blob, &format!("blocks.{i}.norm_attn"), &na, true);
        push(&mut records, &mut blob, &format!("blocks.{i}.norm_mlp"), &nm, true);
    }
    let nf = Mat::from_vec(1, model.norm_final.len(), model.norm_final.clone());
    push(&mut records, &mut blob, "norm_final", &nf, true);
    push(&mut records, &mut blob, "head", &model.head, false);

    let cfg = obj(vec![
        ("name", s(&model.config.name)),
        ("vocab", num(model.config.vocab as f64)),
        ("d_model", num(model.config.d_model as f64)),
        ("n_layers", num(model.config.n_layers as f64)),
        ("n_heads", num(model.config.n_heads as f64)),
        ("d_ff", num(model.config.d_ff as f64)),
        ("max_ctx", num(model.config.max_ctx as f64)),
    ]);
    let header = json::write(&obj(vec![
        ("config", cfg),
        ("tensors", Value::Array(records)),
        ("meta", obj(vec![])),
    ]));
    let mut out = Vec::with_capacity(8 + header.len() + blob.len());
    out.extend_from_slice(b"EQW1");
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(&blob);
    std::fs::write(path, out)?;
    Ok(())
}

/// Generate a small random model (tests / ablations without artifacts).
pub fn synthetic_model(config: Config, seed: u64) -> Model {
    use crate::tensor::Rng;
    let mut rng = Rng::new(seed);
    let (d, f, v) = (config.d_model, config.d_ff, config.vocab);
    let mut dense = |rows: usize, cols: usize| {
        let std = 1.0 / (cols as f64).sqrt();
        Mat::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| (rng.normal() * std * (rng.normal() * 0.5).exp()) as f32)
                .collect(),
        )
    };
    let blocks = (0..config.n_layers)
        .map(|_| BlockWeights {
            wq: dense(d, d),
            wk: dense(d, d),
            wv: dense(d, d),
            wo: dense(d, d),
            w_gate: dense(f, d),
            w_up: dense(f, d),
            w_down: dense(d, f),
            norm_attn: vec![1.0; d],
            norm_mlp: vec![1.0; d],
        })
        .collect();
    let embed = dense(v, d);
    let head = dense(v, d);
    Model { config, embed, blocks, norm_final: vec![1.0; d], head }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> Config {
        Config {
            name: "T".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_ctx: 16,
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let m = synthetic_model(tiny_config(), 1);
        let path = std::env::temp_dir().join("eq_test_roundtrip.eqw");
        write_eqw(path.to_str().unwrap(), &m).unwrap();
        let m2 = load_eqw(path.to_str().unwrap()).unwrap();
        assert_eq!(m2.config, m.config);
        assert_eq!(m2.embed, m.embed);
        assert_eq!(m2.blocks[1].w_down, m.blocks[1].w_down);
        assert_eq!(m2.norm_final, m.norm_final);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_eqw(b"NOPE....").is_err());
        assert!(parse_eqw(b"EQ").is_err());
    }

    #[test]
    fn hostile_tensor_offsets_error_not_panic() {
        let cfg = r#""config":{"name":"T","vocab":32,"d_model":16,"n_layers":1,"n_heads":2,"d_ff":24,"max_ctx":16}"#;
        let mk = |tensor_json: &str| {
            let header = format!("{{{cfg},\"tensors\":[{tensor_json}]}}");
            let mut bytes = b"EQW1".to_vec();
            bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
            bytes.extend_from_slice(header.as_bytes());
            bytes.extend_from_slice(&[0u8; 64]); // data region
            bytes
        };
        // offset + nbytes overflows usize: Err, never a wrapped bounds
        // check followed by a slice panic
        let huge = format!(
            "{{\"name\":\"embed\",\"shape\":[16],\"dtype\":\"f32\",\"offset\":{},\"nbytes\":64}}",
            usize::MAX
        );
        assert!(parse_eqw(&mk(&huge)).is_err());
        // plain out of bounds
        let oob = r#"{"name":"embed","shape":[100],"dtype":"f32","offset":32,"nbytes":400}"#;
        assert!(parse_eqw(&mk(oob)).is_err());
        // in bounds but not an f32 multiple
        let ragged = r#"{"name":"embed","shape":[3],"dtype":"f32","offset":0,"nbytes":13}"#;
        assert!(parse_eqw(&mk(ragged)).is_err());
        // byte length disagrees with the declared shape
        let short = r#"{"name":"embed","shape":[3],"dtype":"f32","offset":0,"nbytes":16}"#;
        assert!(parse_eqw(&mk(short)).is_err());
    }

    #[test]
    fn loads_trained_checkpoint_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/model_S.eqw");
        if !std::path::Path::new(path).exists() {
            eprintln!("checkpoint missing; run `make artifacts` (skipping)");
            return;
        }
        let m = load_eqw(path).unwrap();
        assert_eq!(m.config.name, "S");
        assert_eq!(m.config.d_model, 128);
        assert_eq!(m.blocks.len(), 4);
        assert_eq!(m.embed.rows, 256);
        // trained weights should not be all-zero / constant
        assert!(m.blocks[0].wq.abs_max() > 0.01);
    }

    #[test]
    fn linear_params_accounting() {
        let m = synthetic_model(tiny_config(), 2);
        let d = 16usize;
        let f = 24usize;
        let want = 2 * (4 * d * d + 3 * d * f);
        assert_eq!(m.linear_params(), want);
    }
}
