//! f32 reference forward pass — numerically mirrors
//! python/compile/model.py::forward_train (RMSNorm, RoPE, causal MHA,
//! SwiGLU).  Used for offline evaluation of every quantization method;
//! optional dynamic activation quantization implements the paper's W8A8
//! configuration (Table 4).

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

use super::{BlockWeights, Config, Model};
use crate::quant::Format;
use crate::tensor::{dot, log_softmax, rmsnorm, softmax_inplace, Mat};

/// Dynamic (per-token) activation quantization mode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActQuant {
    None,
    /// quantize-dequantize activations per token before every linear
    Dynamic(Format),
}

pub struct Forward<'a> {
    pub model: &'a Model,
    pub act_quant: ActQuant,
}

impl<'a> Forward<'a> {
    pub fn new(model: &'a Model) -> Self {
        Forward { model, act_quant: ActQuant::None }
    }

    pub fn with_act_quant(model: &'a Model, aq: ActQuant) -> Self {
        Forward { model, act_quant: aq }
    }

    fn maybe_quant_acts(&self, x: &mut Mat) {
        if let ActQuant::Dynamic(fmt) = self.act_quant {
            for r in 0..x.rows {
                let row = x.row_mut(r);
                let amax = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                if amax == 0.0 {
                    continue;
                }
                let s = amax / fmt.qmax();
                for v in row.iter_mut() {
                    *v = fmt.round((*v / s).clamp(-fmt.qmax(), fmt.qmax())) * s;
                }
            }
        }
    }

    fn linear(&self, w: &Mat, x: &Mat) -> Mat {
        let mut xq = x.clone();
        self.maybe_quant_acts(&mut xq);
        w.matmul_t(&xq)
    }

    /// Full-sequence forward: tokens -> logits [S, V].
    pub fn logits(&self, tokens: &[u8]) -> Mat {
        let cfg = &self.model.config;
        let s_len = tokens.len();
        let d = cfg.d_model;
        let mut x = Mat::zeros(s_len, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.model.embed.row(t as usize));
        }
        for bw in &self.model.blocks {
            x = self.block(&x, bw, cfg);
        }
        // final norm + head
        let mut xn = Mat::zeros(s_len, d);
        for i in 0..s_len {
            rmsnorm(x.row(i), &self.model.norm_final, xn.row_mut(i));
        }
        self.model.head.matmul_t(&xn)
    }

    fn block(&self, x: &Mat, bw: &BlockWeights, cfg: &Config) -> Mat {
        let (s_len, d) = (x.rows, x.cols);
        let (h, hd) = (cfg.n_heads, cfg.head_dim());

        // attention over pre-norm
        let mut xn = Mat::zeros(s_len, d);
        for i in 0..s_len {
            rmsnorm(x.row(i), &bw.norm_attn, xn.row_mut(i));
        }
        let mut q = self.linear(&bw.wq, &xn);
        let mut k = self.linear(&bw.wk, &xn);
        let v = self.linear(&bw.wv, &xn);
        apply_rope_seq(&mut q, h, hd);
        apply_rope_seq(&mut k, h, hd);

        let mut ctx = Mat::zeros(s_len, d);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut att = vec![0.0f32; s_len];
        for head in 0..h {
            let off = head * hd;
            for i in 0..s_len {
                let qi = &q.row(i)[off..off + hd];
                for j in 0..=i {
                    att[j] = dot(qi, &k.row(j)[off..off + hd]) * scale;
                }
                softmax_inplace(&mut att[..=i]);
                let out = &mut ctx.row_mut(i)[off..off + hd];
                for j in 0..=i {
                    let vj = &v.row(j)[off..off + hd];
                    let p = att[j];
                    for t in 0..hd {
                        out[t] += p * vj[t];
                    }
                }
            }
        }
        let att_out = self.linear(&bw.wo, &ctx);
        let mut x1 = x.clone();
        for i in 0..x1.data.len() {
            x1.data[i] += att_out.data[i];
        }

        // MLP over pre-norm
        let mut xn2 = Mat::zeros(s_len, d);
        for i in 0..s_len {
            rmsnorm(x1.row(i), &bw.norm_mlp, xn2.row_mut(i));
        }
        let gate = self.linear(&bw.w_gate, &xn2);
        let up = self.linear(&bw.w_up, &xn2);
        let mut hmat = Mat::zeros(s_len, cfg.d_ff);
        for i in 0..hmat.data.len() {
            hmat.data[i] = silu(gate.data[i]) * up.data[i];
        }
        let down = self.linear(&bw.w_down, &hmat);
        for i in 0..x1.data.len() {
            x1.data[i] += down.data[i];
        }
        x1
    }

    /// Mean next-token NLL (nats) over a token window.
    pub fn nll(&self, tokens: &[u8]) -> f64 {
        assert!(tokens.len() >= 2);
        let logits = self.logits(&tokens[..tokens.len() - 1]);
        let mut total = 0.0f64;
        for i in 0..logits.rows {
            let lp = log_softmax(logits.row(i));
            total -= lp[tokens[i + 1] as usize] as f64;
        }
        total / logits.rows as f64
    }

    /// Sum log-likelihood of `continuation` given `context` (LM-Eval
    /// style continuation scoring; length-normalized by the caller).
    pub fn continuation_loglik(&self, context: &[u8], continuation: &[u8]) -> f64 {
        let mut full = context.to_vec();
        full.extend_from_slice(continuation);
        let logits = self.logits(&full[..full.len() - 1]);
        let mut ll = 0.0f64;
        let start = context.len() - 1; // logits[i] predicts full[i+1]
        for i in start..logits.rows {
            let lp = log_softmax(logits.row(i));
            ll += lp[full[i + 1] as usize] as f64;
        }
        ll
    }

    /// Capture the inputs seen by every linear of every block on a
    /// calibration sequence — the data GPTQ's Hessians are built from.
    /// Returns per block: (attn_in [S,D], attn_ctx [S,D], mlp_in [S,D],
    /// mlp_hidden [S,F]).
    pub fn capture_linear_inputs(&self, tokens: &[u8]) -> Vec<(Mat, Mat, Mat, Mat)> {
        let cfg = &self.model.config;
        let s_len = tokens.len();
        let d = cfg.d_model;
        let mut x = Mat::zeros(s_len, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.model.embed.row(t as usize));
        }
        let mut captures = Vec::with_capacity(self.model.blocks.len());
        for bw in &self.model.blocks {
            let mut xn = Mat::zeros(s_len, d);
            for i in 0..s_len {
                rmsnorm(x.row(i), &bw.norm_attn, xn.row_mut(i));
            }
            // attention context (input to wo)
            let ctx = {
                let (h, hd) = (cfg.n_heads, cfg.head_dim());
                let mut q = self.linear(&bw.wq, &xn);
                let mut k = self.linear(&bw.wk, &xn);
                let v = self.linear(&bw.wv, &xn);
                apply_rope_seq(&mut q, h, hd);
                apply_rope_seq(&mut k, h, hd);
                let mut ctx = Mat::zeros(s_len, d);
                let scale = 1.0 / (hd as f32).sqrt();
                let mut att = vec![0.0f32; s_len];
                for head in 0..h {
                    let off = head * hd;
                    for i in 0..s_len {
                        let qi = &q.row(i)[off..off + hd];
                        for j in 0..=i {
                            att[j] = dot(qi, &k.row(j)[off..off + hd]) * scale;
                        }
                        softmax_inplace(&mut att[..=i]);
                        let out = &mut ctx.row_mut(i)[off..off + hd];
                        for j in 0..=i {
                            let vj = &v.row(j)[off..off + hd];
                            let p = att[j];
                            for t in 0..hd {
                                out[t] += p * vj[t];
                            }
                        }
                    }
                }
                ctx
            };
            let att_out = self.linear(&bw.wo, &ctx);
            let mut x1 = x.clone();
            for i in 0..x1.data.len() {
                x1.data[i] += att_out.data[i];
            }
            let mut xn2 = Mat::zeros(s_len, d);
            for i in 0..s_len {
                rmsnorm(x1.row(i), &bw.norm_mlp, xn2.row_mut(i));
            }
            let gate = self.linear(&bw.w_gate, &xn2);
            let up = self.linear(&bw.w_up, &xn2);
            let mut hmat = Mat::zeros(s_len, cfg.d_ff);
            for i in 0..hmat.data.len() {
                hmat.data[i] = silu(gate.data[i]) * up.data[i];
            }
            let down = self.linear(&bw.w_down, &hmat);
            for i in 0..x1.data.len() {
                x1.data[i] += down.data[i];
            }
            captures.push((xn, ctx, xn2, hmat));
            x = x1;
        }
        captures
    }

    /// Record the max-|activation| entering each block's w_down — the
    /// probe the super-weight detector uses (Yu et al. 2024).
    pub fn down_proj_activation_maxima(&self, tokens: &[u8]) -> Vec<f32> {
        let cfg = &self.model.config;
        let s_len = tokens.len();
        let d = cfg.d_model;
        let mut x = Mat::zeros(s_len, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.model.embed.row(t as usize));
        }
        let mut maxima = Vec::with_capacity(self.model.blocks.len());
        for bw in &self.model.blocks {
            // replicate block() but capture the MLP hidden magnitude
            let x_next = self.block(&x, bw, cfg);
            let mut xn2 = Mat::zeros(s_len, d);
            // recompute the attention half to get the mlp input
            let att_delta = {
                let mut tmp = self.block_attention_only(&x, bw, cfg);
                for i in 0..tmp.data.len() {
                    tmp.data[i] += x.data[i];
                }
                tmp
            };
            for i in 0..s_len {
                rmsnorm(att_delta.row(i), &bw.norm_mlp, xn2.row_mut(i));
            }
            let gate = self.linear(&bw.w_gate, &xn2);
            let up = self.linear(&bw.w_up, &xn2);
            let mut m = 0.0f32;
            for i in 0..gate.data.len() {
                m = m.max((silu(gate.data[i]) * up.data[i]).abs());
            }
            maxima.push(m);
            x = x_next;
        }
        maxima
    }

    fn block_attention_only(&self, x: &Mat, bw: &BlockWeights, cfg: &Config) -> Mat {
        let (s_len, d) = (x.rows, x.cols);
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let mut xn = Mat::zeros(s_len, d);
        for i in 0..s_len {
            rmsnorm(x.row(i), &bw.norm_attn, xn.row_mut(i));
        }
        let mut q = self.linear(&bw.wq, &xn);
        let mut k = self.linear(&bw.wk, &xn);
        let v = self.linear(&bw.wv, &xn);
        apply_rope_seq(&mut q, h, hd);
        apply_rope_seq(&mut k, h, hd);
        let mut ctx = Mat::zeros(s_len, d);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut att = vec![0.0f32; s_len];
        for head in 0..h {
            let off = head * hd;
            for i in 0..s_len {
                let qi = &q.row(i)[off..off + hd];
                for j in 0..=i {
                    att[j] = dot(qi, &k.row(j)[off..off + hd]) * scale;
                }
                softmax_inplace(&mut att[..=i]);
                let out = &mut ctx.row_mut(i)[off..off + hd];
                for j in 0..=i {
                    let vj = &v.row(j)[off..off + hd];
                    let p = att[j];
                    for t in 0..hd {
                        out[t] += p * vj[t];
                    }
                }
            }
        }
        self.linear(&bw.wo, &ctx)
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RoPE over a [S, D] activation, heads laid out contiguously.
/// Matches python: x1/x2 = halves of each head's dims; theta = pos *
/// 10000^(-j/(hd/2)).
fn apply_rope_seq(x: &mut Mat, n_heads: usize, hd: usize) {
    let half = hd / 2;
    for pos in 0..x.rows {
        let row = x.row_mut(pos);
        for h in 0..n_heads {
            let off = h * hd;
            for j in 0..half {
                let freq = 10000f32.powf(-(j as f32) / half as f32);
                let theta = pos as f32 * freq;
                let (sin, cos) = theta.sin_cos();
                let a = row[off + j];
                let b = row[off + half + j];
                row[off + j] = a * cos - b * sin;
                row[off + half + j] = a * sin + b * cos;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loader::synthetic_model;
    use crate::model::Config;

    fn tiny() -> Model {
        synthetic_model(
            Config { name: "T".into(), vocab: 48, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, max_ctx: 32 },
            7,
        )
    }

    #[test]
    fn logits_shape_and_finite() {
        let m = tiny();
        let f = Forward::new(&m);
        let logits = f.logits(&[1, 2, 3, 4, 5]);
        assert_eq!(logits.rows, 5);
        assert_eq!(logits.cols, 48);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_holds() {
        let m = tiny();
        let f = Forward::new(&m);
        let l1 = f.logits(&[1, 2, 3, 4, 5]);
        let l2 = f.logits(&[1, 2, 3, 9, 9]);
        for i in 0..3 {
            for j in 0..48 {
                assert!((l1.at(i, j) - l2.at(i, j)).abs() < 1e-5, "pos {i}");
            }
        }
    }

    #[test]
    fn nll_near_uniform_at_random_init() {
        let m = tiny();
        let f = Forward::new(&m);
        let toks: Vec<u8> = (0..20).map(|i| (i * 7 % 48) as u8).collect();
        let nll = f.nll(&toks);
        assert!((nll - (48f64).ln()).abs() < 1.5, "{nll}");
    }

    #[test]
    fn continuation_loglik_additive() {
        let m = tiny();
        let f = Forward::new(&m);
        let ctx = [1u8, 2, 3];
        let cont = [4u8, 5];
        let ll = f.continuation_loglik(&ctx, &cont);
        assert!(ll < 0.0);
        // scoring a 1-token continuation twice = scoring 2 tokens once
        let ll1 = f.continuation_loglik(&ctx, &[4]);
        let ll2 = f.continuation_loglik(&[1, 2, 3, 4], &[5]);
        assert!((ll - (ll1 + ll2)).abs() < 1e-4);
    }

    #[test]
    fn act_quant_small_perturbation() {
        let m = tiny();
        let f = Forward::new(&m);
        let fq = Forward::with_act_quant(&m, ActQuant::Dynamic(Format::F8E4M3));
        let toks = [1u8, 5, 9, 13];
        let l = f.logits(&toks);
        let lq = fq.logits(&toks);
        let mut max_rel = 0.0f32;
        let spread = l.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for i in 0..l.data.len() {
            max_rel = max_rel.max((l.data[i] - lq.data[i]).abs() / spread);
        }
        assert!(max_rel > 0.0, "activation quant must change something");
        assert!(max_rel < 0.25, "but not catastrophically: {max_rel}");
    }

    #[test]
    fn matches_python_fixture_if_present() {
        let art = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        let fix_path = format!("{art}/fixtures/model_fwd.json");
        let model_path = format!("{art}/model_S.eqw");
        if !std::path::Path::new(&fix_path).exists() {
            eprintln!("fixture missing; run `make artifacts` (skipping)");
            return;
        }
        let m = crate::model::load_eqw(&model_path).unwrap();
        let fix = crate::store::json::parse(&std::fs::read_to_string(&fix_path).unwrap()).unwrap();
        let tokens_rows = fix.get("tokens").unwrap().as_array().unwrap();
        let want = fix.get("logits_sample").unwrap().as_array().unwrap();
        let f = Forward::new(&m);
        for (bi, row) in tokens_rows.iter().enumerate() {
            let toks: Vec<u8> = row.f64_array().unwrap().iter().map(|&x| x as u8).collect();
            let logits = f.logits(&toks);
            let want_row = want[bi].f64_array().unwrap();
            for j in 0..want_row.len() {
                let got = logits.at(logits.rows - 1, j);
                assert!(
                    (got - want_row[j] as f32).abs() < 2e-2 * want_row[j].abs().max(1.0) as f32,
                    "batch {bi} logit {j}: {got} vs {}",
                    want_row[j]
                );
            }
        }
    }
}
