//! The transformer substrate: model config, .eqw checkpoint loader, and
//! the f32 reference forward pass (RMSNorm + RoPE + causal MHA + SwiGLU)
//! — numerically equivalent to python/compile/model.py (cross-checked
//! against artifacts/fixtures/model_fwd.json).
//!
//! The reference forward drives offline evaluation (perplexity, zero-shot
//! suites) for all model sizes and all quantization baselines; the
//! serving path runs through PJRT artifacts instead (see `runtime`).

pub mod forward;
pub mod loader;

pub use forward::{ActQuant, Forward};
pub use loader::load_eqw;

use crate::quant::Format;
use crate::store::json::Value;
use crate::tensor::Mat;

#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_ctx: usize,
}

impl Config {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn from_json(v: &Value) -> Result<Self, String> {
        let get = |k: &str| -> Result<usize, String> {
            v.get(k).and_then(|x| x.as_usize()).ok_or(format!("config missing {k}"))
        };
        Ok(Config {
            name: v.get("name").and_then(|x| x.as_str()).unwrap_or("?").to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            max_ctx: get("max_ctx")?,
        })
    }

    /// Parameter count (matches python ModelConfig.params()).
    pub fn params(&self) -> usize {
        let (d, f) = (self.d_model, self.d_ff);
        let per_block = 4 * d * d + 3 * d * f + 2 * d;
        self.vocab * d * 2 + self.n_layers * per_block + d
    }
}

/// Canonical names of the 7 quantized linears per block — the
/// serialization order shared with python (configs.BLOCK_LINEARS).
pub const BLOCK_LINEARS: [&str; 7] = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

#[derive(Clone)]
pub struct BlockWeights {
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub w_gate: Mat,
    pub w_up: Mat,
    pub w_down: Mat,
    pub norm_attn: Vec<f32>,
    pub norm_mlp: Vec<f32>,
}

impl BlockWeights {
    pub fn linear(&self, name: &str) -> &Mat {
        match name {
            "wq" => &self.wq,
            "wk" => &self.wk,
            "wv" => &self.wv,
            "wo" => &self.wo,
            "w_gate" => &self.w_gate,
            "w_up" => &self.w_up,
            "w_down" => &self.w_down,
            _ => panic!("unknown linear {name}"),
        }
    }

    pub fn linear_mut(&mut self, name: &str) -> &mut Mat {
        match name {
            "wq" => &mut self.wq,
            "wk" => &mut self.wk,
            "wv" => &mut self.wv,
            "wo" => &mut self.wo,
            "w_gate" => &mut self.w_gate,
            "w_up" => &mut self.w_up,
            "w_down" => &mut self.w_down,
            _ => panic!("unknown linear {name}"),
        }
    }
}

#[derive(Clone)]
pub struct Model {
    pub config: Config,
    pub embed: Mat,
    pub blocks: Vec<BlockWeights>,
    pub norm_final: Vec<f32>,
    pub head: Mat,
}

impl Model {
    /// Storage footprint of the *quantizable* linears in parameters
    /// (the denominator of every effective-bits-per-parameter figure).
    pub fn linear_params(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| BLOCK_LINEARS.iter().map(move |n| b.linear(n).data.len()))
            .sum()
    }

    /// Apply a per-layer transform to every quantizable linear.
    pub fn map_linears<F>(&mut self, mut f: F)
    where
        F: FnMut(usize, &str, &mut Mat),
    {
        for (i, b) in self.blocks.iter_mut().enumerate() {
            for name in BLOCK_LINEARS {
                f(i, name, b.linear_mut(name));
            }
        }
    }

    /// Total bytes of a BF16 baseline (2 bytes/param, all tensors).
    pub fn bf16_bytes(&self) -> usize {
        2 * self.config.params()
    }
}

/// A model whose linears have been replaced by quantized versions —
/// the offline-eval twin of the served compressed model.
#[derive(Clone)]
pub struct QModel {
    pub config: Config,
    pub embed: Mat,
    pub blocks: Vec<QBlock>,
    pub norm_final: Vec<f32>,
    pub head: Mat,
}

#[derive(Clone)]
pub struct QBlock {
    pub linears: Vec<crate::quant::QMat>, // order: BLOCK_LINEARS
    pub norm_attn: Vec<f32>,
    pub norm_mlp: Vec<f32>,
}

impl QModel {
    /// Materialize the dequantized f32 model (offline eval path; the
    /// serving path never materializes full weights, see coordinator).
    pub fn dequantize(&self) -> Model {
        let blocks = self
            .blocks
            .iter()
            .map(|qb| {
                let d = |i: usize| qb.linears[i].dequantize();
                BlockWeights {
                    wq: d(0),
                    wk: d(1),
                    wv: d(2),
                    wo: d(3),
                    w_gate: d(4),
                    w_up: d(5),
                    w_down: d(6),
                    norm_attn: qb.norm_attn.clone(),
                    norm_mlp: qb.norm_mlp.clone(),
                }
            })
            .collect();
        Model {
            config: self.config.clone(),
            embed: self.embed.clone(),
            blocks,
            norm_final: self.norm_final.clone(),
            head: self.head.clone(),
        }
    }

    pub fn fmt(&self) -> Format {
        self.blocks[0].linears[0].fmt
    }
}
