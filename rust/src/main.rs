//! entquant CLI — compress, evaluate, serve, and regenerate every table
//! and figure of the paper.  `entquant help` lists subcommands.

use anyhow::{anyhow, bail, Result};

use entquant::coordinator::{EngineOpts, KvCfg, KvMode, Residency};
use entquant::eval::{perplexity, TaskSuite};
use entquant::model::loader::synthetic_model;
use entquant::model::{load_eqw, Config};
use entquant::quant::Format;
use entquant::runtime::fault::{FaultPlan, FaultRuntime, FaultScript};
use entquant::runtime::{Manifest, Runtime};
use entquant::serve::{
    Admission, Scheduler, SchedulerOpts, ShardPlan, ShardedEngine, Status, Supervisor,
    SupervisorOpts,
};
use entquant::store::container::CompressedModel;
use entquant::store::pipeline::{compress_model, CompressOpts};

mod tables;

fn usage() -> ! {
    eprintln!(
        "entquant <command> [args]\n\
         commands:\n\
           compress --model <size|path> [--bits B | --lam L] [--fmt f8|i8] [--sw TH] [--out P] [--threads N]\n\
           eval     --model <size|path> [--compressed P] [--windows N]\n\
           serve    --compressed P [--prompts N] [--max-new N] [--residency MODE] [--threads N] [--shards N]\n\
                    [--kv-mode raw|lossless|f8|bf16] [--kv-window W]  (KV-cache tail coding + lossless recent window)\n\
                    [--trace-out P]  (write the run's tick-domain trace as Chrome trace-event JSON)\n\
                    [--fault-shard K --fault-step S]  (fault drill: kill shard K at decode step S; reroutes + completes)\n\
                    [--rejoin-shard N --rejoin-step S] (rejoin drill: N replacement runtime(s) — a COUNT, default 1 —\n\
                     join S decode steps after a reroute, re-splitting the merged range: the contract->expand cycle)\n\
           serve-stdio [--synthetic L] [--shards N] [--max-queue-depth D] [--max-inflight-tokens T]\n\
                    [--min-healthy-shards H] [--step-budget B] [--fault-shard K --fault-step S]\n\
                    [--supervisor-spares N] [--evict-after F] [--threads N] [--trace-out P]\n\
                    [--kv-mode raw|lossless|f8|bf16] [--kv-window W]\n\
                    (chaos-harness server: a self-contained synthetic stack driven line-by-line over\n\
                     stdin/stdout — SUBMIT <cid> <max_new> <hexprompt> | TRACE <path> | QUIT in; READY,\n\
                     ADMITTED/SHED, FIRST, DONE/EXPIRED/FAILED, TRACED, STATS <json> out; see tools/chaosbench)\n\
           table1 | table2 | table3 | table4 | fig1 | fig4 | fig5 | fig6 | figA1 | figB1\n\
           ablate-blockwise | report-all\n\
         --threads defaults to ENTQUANT_THREADS or the machine's available parallelism"
    );
    std::process::exit(2);
}

fn arg_val(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

/// The `--threads` knob shared by compress and serve; defaults to the
/// parallel subsystem's detected width.
fn arg_threads(args: &[String]) -> Result<usize> {
    Ok(match arg_val(args, "--threads") {
        Some(v) => v.parse::<usize>()?.max(1),
        None => entquant::parallel::default_threads(),
    })
}

/// The `--kv-mode`/`--kv-window` knobs shared by the serve commands:
/// how the attention KV cache holds older rows (`raw`, `lossless`,
/// `f8`, `bf16`) and the lossless recent-window length.
fn arg_kv(args: &[String]) -> Result<KvCfg> {
    let mut kv = KvCfg::default();
    if let Some(m) = arg_val(args, "--kv-mode") {
        kv.mode = KvMode::parse(&m).map_err(|e| anyhow!(e))?;
    }
    if let Some(w) = arg_val(args, "--kv-window") {
        kv.window = w.parse::<usize>()?.max(1);
    }
    Ok(kv)
}

fn model_path(spec: &str) -> String {
    if spec.contains('/') || spec.ends_with(".eqw") {
        spec.to_string()
    } else {
        format!("{}/model_{spec}.eqw", entquant::artifacts_dir())
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "compress" => cmd_compress(&args[1..]),
        "eval" => cmd_eval(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "serve-stdio" => cmd_serve_stdio(&args[1..]),
        "table1" => tables::table1(),
        "table2" => tables::table2(),
        "table3" => tables::table3(),
        "table4" => tables::table4(),
        "fig1" => tables::fig1(),
        "fig4" => tables::fig4(),
        "fig5" => tables::fig5(),
        "fig6" => tables::fig6(),
        "figA1" => tables::fig_a1(),
        "figB1" => tables::fig_b1(),
        "ablate-blockwise" => tables::ablate_blockwise(),
        "report-all" => {
            tables::table1()?;
            tables::table2()?;
            tables::table3()?;
            tables::table4()?;
            tables::fig1()?;
            tables::fig4()?;
            tables::fig6()?;
            tables::fig_a1()?;
            tables::fig_b1()?;
            tables::fig5()?;
            tables::ablate_blockwise()
        }
        "help" | "--help" | "-h" => usage(),
        other => {
            eprintln!("unknown command {other}");
            usage()
        }
    }
}

fn cmd_compress(args: &[String]) -> Result<()> {
    let spec = arg_val(args, "--model").ok_or(anyhow!("--model required"))?;
    let model = load_eqw(&model_path(&spec))?;
    let fmt = match arg_val(args, "--fmt").as_deref() {
        None | Some("f8") => Format::F8E4M3,
        Some("i8") => Format::Int8,
        Some(f) => bail!("bad fmt {f}"),
    };
    let mut opts = CompressOpts { fmt, threads: arg_threads(args)?, ..Default::default() };
    if let Some(b) = arg_val(args, "--bits") {
        opts.target_bits = Some(b.parse()?);
    } else if let Some(l) = arg_val(args, "--lam") {
        opts.lam = l.parse()?;
    }
    if let Some(th) = arg_val(args, "--sw") {
        opts.superweight_threshold = Some(th.parse()?);
    }
    let (cm, rep) = compress_model(&model, &opts)?;
    let out = arg_val(args, "--out")
        .unwrap_or_else(|| format!("{}/compressed_{spec}.eqz", entquant::artifacts_dir()));
    cm.save(&out)?;
    println!(
        "compressed {} ({} params, {} threads) in {:.1}s\n  lam={:.4}  entropy={:.2} bits/param  effective={:.2} bits/param\n  distortion={:.4}  sparsity={:.3}  excluded_blocks={:?}\n  wrote {}",
        spec,
        rep.params_compressed,
        opts.threads,
        rep.wall_s,
        rep.lam,
        rep.mean_entropy_bits,
        rep.effective_bits_per_param,
        rep.total_distortion,
        rep.mean_sparsity,
        rep.excluded_blocks,
        out
    );
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let art = entquant::artifacts_dir();
    let model = if let Some(p) = arg_val(args, "--compressed") {
        CompressedModel::load(&p)?.to_model()?
    } else {
        let spec = arg_val(args, "--model").ok_or(anyhow!("--model or --compressed required"))?;
        load_eqw(&model_path(&spec))?
    };
    let windows: usize = arg_val(args, "--windows").map(|w| w.parse()).transpose()?.unwrap_or(8);
    let valid = std::fs::read(format!("{art}/corpus/valid.bin"))?;
    let ppl = perplexity(&model, &valid, 128, windows);
    let suite = TaskSuite::load(&format!("{art}/corpus/tasks_base.json"))?;
    let (per_task, avg) = suite.evaluate(&model, 25);
    println!("perplexity (C4-analogue, {windows} windows x 128): {ppl:.3}");
    for (name, acc) in &per_task {
        println!("  {name:<12} acc {:.1}%", acc * 100.0);
    }
    println!("  zero-shot avg: {:.1}%", avg * 100.0);
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let art = entquant::artifacts_dir();
    let path = arg_val(args, "--compressed").ok_or(anyhow!("--compressed required"))?;
    let cm = CompressedModel::load(&path)?;
    let residency = match arg_val(args, "--residency").as_deref() {
        None | Some("entquant") => Residency::EntQuant,
        Some("bf16") => Residency::Bf16Resident,
        Some("f8") => Residency::F8Resident,
        Some("offload") => Residency::DiskOffload,
        Some(r) => bail!("bad residency {r}"),
    };
    let decode_threads = arg_threads(args)?;
    let shards: usize = arg_val(args, "--shards").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let n_prompts: usize = arg_val(args, "--prompts").map(|v| v.parse()).transpose()?.unwrap_or(4);
    let max_new: usize = arg_val(args, "--max-new").map(|v| v.parse()).transpose()?.unwrap_or(32);

    // optional fault drill: arm one shard's runtime to fail at a
    // scripted decode step, demonstrating the reroute + replay path
    let fault_shard: Option<usize> =
        arg_val(args, "--fault-shard").map(|v| v.parse()).transpose()?;
    let fault_step: usize =
        arg_val(args, "--fault-step").map(|v| v.parse()).transpose()?.unwrap_or(4);
    // optional rejoin drill (the inverse): provision replacement
    // runtime(s) that re-split the merged range after the reroute.
    // Either flag arms the drill with at least one spare, so
    // `--rejoin-step S` alone (or a zero count) cannot silently
    // disable it.
    let rejoin_flagged = args.iter().any(|a| a == "--rejoin-shard" || a == "--rejoin-step");
    let rejoin_count: usize =
        arg_val(args, "--rejoin-shard").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let rejoin_shards = if rejoin_flagged { rejoin_count.max(1) } else { 0 };
    let rejoin_step: usize =
        arg_val(args, "--rejoin-step").map(|v| v.parse()).transpose()?.unwrap_or(4);

    // shard the blocks by compressed bytes; each shard gets its own
    // runtime, pool and decode arena
    let plan = ShardPlan::balance(&cm, shards);
    let faults = fault_shard.map(|k| {
        println!("fault drill: shard {k} scripted to fail at decode step {fault_step}");
        FaultPlan::scripted(vec![FaultScript { shard: k, step: fault_step, block: 0 }])
    });
    let mut runtimes = Vec::with_capacity(plan.n_shards());
    for i in 0..plan.n_shards() {
        let mut rt = Runtime::new(&art)?;
        if let Some(plan_faults) = &faults {
            rt = rt.with_fault(FaultRuntime::new(
                std::sync::Arc::clone(plan_faults),
                i,
                plan.ranges[i].len(),
            ));
        }
        runtimes.push(rt);
    }
    let platform = runtimes[0].platform();
    let kv = arg_kv(args)?;
    let engine = ShardedEngine::new(
        runtimes,
        &cm,
        plan,
        &EngineOpts { residency, decode_threads, kv, ..Default::default() },
    )?;
    for _ in 0..rejoin_shards {
        engine.arm_rejoin(Runtime::new(&art)?, rejoin_step);
    }
    if rejoin_shards > 0 {
        println!(
            "rejoin drill: {rejoin_shards} replacement runtime(s) armed to join {rejoin_step} decode step(s) after a reroute"
        );
    }
    println!(
        "serving on {platform}: {} shard(s) {:?} ({:?} residency, {} decode threads/shard)",
        engine.n_shards(),
        engine.plan().bytes,
        residency,
        decode_threads
    );

    let valid = std::fs::read(format!("{art}/corpus/valid.bin"))?;
    let scheduler = Scheduler::new(engine, SchedulerOpts::default());
    let t0 = std::time::Instant::now();
    let ids: Vec<u64> = (0..n_prompts)
        .map(|i| {
            let prompt = valid[i * 100..i * 100 + 48].to_vec();
            scheduler.submit(prompt, max_new).expect_admitted()
        })
        .collect();
    for (i, id) in ids.iter().enumerate() {
        let out = scheduler.wait(*id, std::time::Duration::from_secs(600))?;
        let text: String = out.iter().map(|&b| b as char).collect();
        println!("  req {i}: {text:?}");
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = scheduler.metrics();
    println!(
        "total: {} tokens in {wall:.2}s ({:.1} tok/s), p50 ttft {:.1} ms, {} fused admissions ({} speculative), {} reroute(s), {} rejoin(s), shard fresh allocs {:?}",
        m.tokens,
        m.tokens as f64 / wall,
        m.p50_ttft_ms,
        m.fused_admissions,
        m.speculative_admissions,
        m.reroutes,
        m.rejoins,
        m.shard_fresh_allocs
    );
    println!(
        "memory: weight_copies={} resident_compressed={} B, {} block(s) spliced by recovery ({:.2} ms stall)",
        m.weight_copies,
        m.resident_compressed_bytes,
        m.recovery_spliced_blocks,
        m.recovery_stall_ms
    );
    println!(
        "kv cache ({:?}, window {}): peak resident={} B (final sweep: {} B resident, {} B entropy-coded, {:.2}x vs raw)",
        kv.mode,
        kv.window,
        m.kv_peak_resident_bytes,
        m.kv_resident_bytes,
        m.kv_compressed_bytes,
        m.kv_compression_ratio
    );
    if let Some(plan_faults) = &faults {
        println!(
            "fault drill: {} scripted fault(s) fired, {} reroute(s), {} rejoin(s), {} request(s) failed",
            plan_faults.fired(),
            m.reroutes,
            m.rejoins,
            m.failed
        );
    }
    if let Some(path) = arg_val(args, "--trace-out") {
        let (events, dropped) = write_trace(&scheduler, &path)?;
        println!("trace: {events} event(s) -> {path} ({dropped} dropped)");
    }
    scheduler.shutdown().map_err(|e| anyhow!(e))?;
    Ok(())
}

/// Export the scheduler's trace as Chrome trace-event JSON (loadable
/// in Perfetto / chrome://tracing; `ts` is the decode-step tick, not
/// wall time).  Returns `(events, dropped)` for the caller's report.
fn write_trace(sched: &Scheduler, path: &str) -> Result<(usize, u64)> {
    let tracer = sched.tracer();
    std::fs::write(path, tracer.export_chrome())?;
    Ok((tracer.len(), tracer.dropped()))
}

/// The chaos-harness server (`tools/chaosbench` spawns this as a child
/// process): a self-contained synthetic serving stack — synthetic
/// checkpoint compressed in-process, sharded over native runtimes,
/// optionally under a recovery `Supervisor` — driven line-by-line over
/// stdin/stdout so an external harness can apply open-loop load, inject
/// faults (`--fault-shard/--fault-step`), kill -9 the whole process,
/// and measure shed/expiry/latency behavior from the outside.
///
/// Protocol (one event per line, flushed immediately):
///   in:  `SUBMIT <cid> <max_new> <hexprompt>` | `TRACE <path>` | `QUIT`
///   out: `READY <shards>`, then per request `ADMITTED <cid>` or
///        `SHED <cid> <retry_after_steps>`, later `FIRST <cid>` once
///        tokens exist and a terminal `DONE <cid> <hexout>` /
///        `EXPIRED <cid> <hexout>` / `FAILED <cid> <msg>`; `TRACE`
///        writes the Chrome trace-event JSON collected so far and
///        answers `TRACED <path> <events> <dropped>` (`--trace-out P`
///        does the same implicitly after QUIT drains); finally one
///        `STATS <json>`.
fn cmd_serve_stdio(args: &[String]) -> Result<()> {
    use std::io::{BufRead, Write};

    let n_layers: usize =
        arg_val(args, "--synthetic").map(|v| v.parse()).transpose()?.unwrap_or(6);
    let shards: usize = arg_val(args, "--shards").map(|v| v.parse()).transpose()?.unwrap_or(2);
    let max_queue_depth: usize =
        arg_val(args, "--max-queue-depth").map(|v| v.parse()).transpose()?.unwrap_or(usize::MAX);
    let max_inflight_tokens: usize = arg_val(args, "--max-inflight-tokens")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(usize::MAX);
    let min_healthy_shards: usize =
        arg_val(args, "--min-healthy-shards").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let step_budget: Option<usize> =
        arg_val(args, "--step-budget").map(|v| v.parse()).transpose()?;
    let fault_shard: Option<usize> =
        arg_val(args, "--fault-shard").map(|v| v.parse()).transpose()?;
    let fault_step: usize =
        arg_val(args, "--fault-step").map(|v| v.parse()).transpose()?.unwrap_or(4);
    let spares: usize =
        arg_val(args, "--supervisor-spares").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let evict_after: usize =
        arg_val(args, "--evict-after").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let trace_out = arg_val(args, "--trace-out");

    // the same tiny synthetic stack the serve bench uses: compress a
    // deterministic checkpoint in-process, no artifacts needed
    const SEQ: usize = 24;
    const CTX: usize = 48;
    let model = synthetic_model(
        Config {
            name: "chaos".into(),
            vocab: 64,
            d_model: 32,
            n_layers,
            n_heads: 4,
            d_ff: 48,
            max_ctx: 64,
        },
        71,
    );
    let threads = arg_threads(args)?;
    let (cm, _) = compress_model(
        &model,
        &CompressOpts { lam: 0.3, max_iters: 6, threads, ..Default::default() },
    )?;
    let native = |cm: &CompressedModel| {
        Runtime::native(Manifest::synthetic(
            cm.config.clone(),
            vec![(1, SEQ), (2, SEQ), (4, SEQ), (8, SEQ)],
            vec![(1, CTX), (2, CTX), (4, CTX), (8, CTX)],
        ))
    };
    let plan = ShardPlan::balance(&cm, shards);
    let n_shards = plan.n_shards();
    let faults = fault_shard
        .map(|k| FaultPlan::scripted(vec![FaultScript { shard: k, step: fault_step, block: 0 }]));
    let rts: Vec<Runtime> = (0..n_shards)
        .map(|i| {
            let rt = native(&cm);
            match &faults {
                Some(f) => rt.with_fault(FaultRuntime::new(
                    std::sync::Arc::clone(f),
                    i,
                    plan.ranges[i].len(),
                )),
                None => rt,
            }
        })
        .collect();
    let engine =
        ShardedEngine::new(rts, &cm, plan, &EngineOpts { kv: arg_kv(args)?, ..Default::default() })?;
    let opts = SchedulerOpts {
        max_queue_depth,
        max_inflight_tokens,
        min_healthy_shards,
        step_budget,
        ..Default::default()
    };
    let sched = if spares > 0 {
        let pool: Vec<Runtime> = (0..spares).map(|_| native(&cm)).collect();
        let sopts = SupervisorOpts { evict_after, ..Default::default() };
        Scheduler::new(Supervisor::new(engine, pool, sopts), opts)
    } else {
        Scheduler::new(engine, opts)
    };

    // stdin on its own thread: the main loop must keep publishing
    // progress events while waiting for the next command line
    let (tx, rx) = std::sync::mpsc::channel::<String>();
    // entlint: allow(no-stray-threads) — blocking stdin reader for the chaos
    // protocol; no work routes through it, so the parallel subsystem does not apply
    std::thread::spawn(move || {
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            let Ok(line) = line else { break };
            let quit = line.trim() == "QUIT";
            if tx.send(line).is_err() || quit {
                break;
            }
        }
    });

    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    writeln!(out, "READY {n_shards}")?;
    out.flush()?;

    let mut live: Vec<(u64, String, bool)> = Vec::new(); // (id, cid, first-token seen)
    let mut quitting = false;
    loop {
        loop {
            match rx.try_recv() {
                Ok(line) => {
                    let mut it = line.split_whitespace();
                    match it.next() {
                        Some("SUBMIT") => handle_submit(&sched, &mut out, &mut live, it)?,
                        Some("TRACE") => match it.next() {
                            Some(path) => match write_trace(&sched, path) {
                                Ok((n, d)) => writeln!(out, "TRACED {path} {n} {d}")?,
                                Err(e) => {
                                    writeln!(out, "ERR trace export: {}", fmt_oneline(&e))?
                                }
                            },
                            None => writeln!(out, "ERR TRACE needs a path")?,
                        },
                        Some("QUIT") => quitting = true,
                        Some(other) => writeln!(out, "ERR unknown command {other}")?,
                        None => {}
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    quitting = true;
                    break;
                }
            }
        }
        live.retain_mut(|(id, cid, first)| {
            let Some((status, output)) = sched.poll(*id) else { return false };
            if !*first && !output.is_empty() {
                *first = true;
                let _ = writeln!(out, "FIRST {cid}");
            }
            match status {
                Status::Done => {
                    let _ = writeln!(out, "DONE {cid} {}", hex_encode(&output));
                    false
                }
                Status::Expired => {
                    let _ = writeln!(out, "EXPIRED {cid} {}", hex_encode(&output));
                    false
                }
                Status::Cancelled => {
                    let _ = writeln!(out, "CANCELLED {cid}");
                    false
                }
                Status::Failed(msg) => {
                    let _ = writeln!(out, "FAILED {cid} {}", msg.replace(['\n', '\r'], " "));
                    false
                }
                Status::Queued | Status::Decoding => true,
            }
        });
        out.flush()?;
        if quitting && live.is_empty() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    if let Some(path) = &trace_out {
        let (n, d) = write_trace(&sched, path)?;
        writeln!(out, "TRACED {path} {n} {d}")?;
    }
    let m = sched.metrics();
    writeln!(out, "STATS {}", stats_json(&m))?;
    out.flush()?;
    sched.shutdown().map_err(|e| anyhow!(e))?;
    Ok(())
}

/// Collapse an error chain onto one line (the stdio protocol is
/// line-delimited).
fn fmt_oneline(e: &anyhow::Error) -> String {
    format!("{e:#}").replace(['\n', '\r'], " ")
}

/// One `SUBMIT <cid> <max_new> <hexprompt>` line: admit through the
/// scheduler and answer `ADMITTED <cid>` or `SHED <cid> <retry>`.
fn handle_submit(
    sched: &Scheduler,
    out: &mut impl std::io::Write,
    live: &mut Vec<(u64, String, bool)>,
    mut fields: std::str::SplitWhitespace,
) -> Result<()> {
    let (Some(cid), Some(mn), Some(hex)) = (fields.next(), fields.next(), fields.next()) else {
        writeln!(out, "ERR malformed SUBMIT")?;
        return Ok(());
    };
    let max_new: usize = mn.parse()?;
    let prompt = hex_decode(hex)?;
    anyhow::ensure!(!prompt.is_empty(), "empty prompt for {cid}");
    match sched.submit(prompt, max_new) {
        Admission::Admitted(id) => {
            writeln!(out, "ADMITTED {cid}")?;
            live.push((id, cid.to_string(), false));
        }
        Admission::Shed { retry_after_steps } => {
            writeln!(out, "SHED {cid} {retry_after_steps}")?;
        }
    }
    Ok(())
}

fn hex_encode(bytes: &[u8]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(s, "{b:02x}");
    }
    s
}

fn hex_decode(s: &str) -> Result<Vec<u8>> {
    anyhow::ensure!(s.len() % 2 == 0, "odd-length hex string");
    (0..s.len() / 2)
        .map(|i| {
            u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .map_err(|e| anyhow!("bad hex byte at {i}: {e}"))
        })
        .collect()
}

fn stats_json(m: &entquant::serve::MetricsSnapshot) -> String {
    format!(
        concat!(
            "{{\"submitted\": {}, \"completed\": {}, \"failed\": {}, \"cancelled\": {}, ",
            "\"shed\": {}, \"expired\": {}, \"tokens\": {}, \"decode_steps\": {}, ",
            "\"reroutes\": {}, \"rejoins\": {}, \"backoff_retries\": {}, ",
            "\"healthy_shards\": {}, \"degraded_shards\": {}, \"evicted_shards\": {}, ",
            "\"degradation_tier\": {}, \"weight_copies\": {}, \"queue_depth\": {}, ",
            "\"kv_resident_bytes\": {}, \"kv_compressed_bytes\": {}, ",
            "\"kv_peak_resident_bytes\": {}, \"kv_compression_ratio\": {:.3}, ",
            "\"p50_ttft_ms\": {:.3}, \"p99_ttft_ms\": {:.3}, \"p999_ttft_ms\": {:.3}, ",
            "\"p50_step_us\": {:.3}, \"p99_step_us\": {:.3}, \"p999_step_us\": {:.3}, ",
            "\"tokens_per_s\": {:.1}}}"
        ),
        m.submitted,
        m.completed,
        m.failed,
        m.cancelled,
        m.shed,
        m.expired,
        m.tokens,
        m.decode_steps,
        m.reroutes,
        m.rejoins,
        m.backoff_retries,
        m.healthy_shards,
        m.degraded_shards,
        m.evicted_shards,
        m.degradation_tier,
        m.weight_copies,
        m.queue_depth,
        m.kv_resident_bytes,
        m.kv_compressed_bytes,
        m.kv_peak_resident_bytes,
        m.kv_compression_ratio,
        m.p50_ttft_ms,
        m.p99_ttft_ms,
        m.p999_ttft_ms,
        m.p50_step_us,
        m.p99_step_us,
        m.p999_step_us,
        m.tokens_per_s,
    )
}
