//! Minimal row-major f32 matrix/vector math used by the f32 reference
//! forward pass, the baselines and the RD optimizer.
//!
//! This is deliberately dependency-free: the request path runs through
//! PJRT executables (see `runtime`), so this module only needs to be
//! correct and reasonably fast for offline evaluation and tests.

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `y = x @ self^T` where `self` is `[N, K]` (row = output channel)
    /// and `x` is `[M, K]`; returns `[M, N]`.  This matches the weight
    /// layout of the python model (nn.Linear convention).
    pub fn matmul_t(&self, x: &Mat) -> Mat {
        assert_eq!(self.cols, x.cols, "contraction mismatch");
        let (m, n, k) = (x.rows, self.rows, self.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let xi = x.row(i);
            let oi = out.row_mut(i);
            for j in 0..n {
                let wj = &self.data[j * k..(j + 1) * k];
                oi[j] = dot(xi, wj);
            }
        }
        out
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    pub fn l1_norm(&self) -> f64 {
        self.data.iter().map(|&x| x.abs() as f64).sum()
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 16 independent accumulators: wide enough for LLVM to lower to two
    // AVX-512 (or four AVX2) FMA chains (§Perf L3: ~4x over the 4-lane
    // version on this host).  Deterministic summation order per build.
    let n = a.len();
    let mut acc = [0.0f32; 16];
    let chunks = n / 16;
    for c in 0..chunks {
        let i = c * 16;
        let (av, bv) = (&a[i..i + 16], &b[i..i + 16]);
        for l in 0..16 {
            acc[l] += av[l] * bv[l];
        }
    }
    let mut s = 0.0f32;
    for l in 0..16 {
        s += acc[l];
    }
    for i in chunks * 16..n {
        s += a[i] * b[i];
    }
    s
}

pub fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let mut z = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

pub fn log_softmax(x: &[f32]) -> Vec<f32> {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let lse = m + x.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
    x.iter().map(|&v| v - lse).collect()
}

pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let eps = 1e-5f32;
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * g[i];
    }
}

/// Deterministic xorshift RNG (no `rand` crate in this image).
#[derive(Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_t_matches_naive() {
        let w = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let x = Mat::from_vec(2, 3, vec![1., 0., -1., 0.5, 0.5, 0.5]);
        let y = w.matmul_t(&x);
        assert_eq!(y.rows, 2);
        assert_eq!(y.cols, 2);
        assert!((y.at(0, 0) - (1. - 3.)).abs() < 1e-6);
        assert!((y.at(0, 1) - (4. - 6.)).abs() < 1e-6);
        assert!((y.at(1, 0) - (0.5 + 1.0 + 1.5)).abs() < 1e-6);
    }

    #[test]
    fn dot_handles_remainders() {
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 17] {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5).collect();
            let want: f32 = (0..n).map(|i| (i * i) as f32 * 0.5).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0] && x[0] > x[3]);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let x = vec![0.5, -0.5, 2.0];
        let ls = log_softmax(&x);
        let mut s = x.clone();
        softmax_inplace(&mut s);
        for i in 0..3 {
            assert!((ls[i].exp() - s[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let x = vec![1.0, -2.0, 3.0, -4.0];
        let g = vec![1.0; 4];
        let mut out = vec![0.0; 4];
        rmsnorm(&x, &g, &mut out);
        let ms = out.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-3);
    }

    #[test]
    fn rng_deterministic_and_spread() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(2);
        let mean: f64 = (0..10_000).map(|_| c.uniform()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
        let mut d = Rng::new(3);
        let nm: f64 = (0..10_000).map(|_| d.normal()).sum::<f64>() / 10_000.0;
        assert!(nm.abs() < 0.05, "{nm}");
    }
}
