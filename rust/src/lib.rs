//! EntQuant — entropy coding enables data-free model compression.
//!
//! Reproduction of Putzky, Genzel, et al. (2026).  See DESIGN.md for the
//! system inventory and README.md for the quickstart.
//!
//! Layer map (DESIGN.md §1):
//! * `quant`, `entropy`, `ans`, `rd` — the compression core (Algorithms 1/2)
//! * `model`, `store`, `baselines`, `eval` — substrates: transformer,
//!   container format, comparison methods, evaluation harness
//! * `parallel`, `util` — shared infrastructure: the scoped thread-pool
//!   subsystem behind every `--threads` knob, and the container checksum
//! * `runtime`, `coordinator` — the L3 serving engine over PJRT
//!   executables compiled from the JAX/Pallas layers (or the built-in
//!   native executor when PJRT is unavailable)
//! * `serve` — the multi-tenant frontend: sharded engines on a balanced
//!   block partition plus a continuously-batched admission scheduler
//! * `obs` — tick-domain tracing and log2 latency histograms threaded
//!   through the serve stack, with JSONL/Chrome-trace exporters

// The tree is unsafe-free and locked that way.  If a future SIMD kernel
// needs unsafe, relax this to `deny` in that one module — entlint then
// requires a `// SAFETY:` comment per block.
#![forbid(unsafe_code)]

pub mod ans;
pub mod baselines;
pub mod coordinator;
pub mod entropy;
pub mod eval;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod quant;
pub mod rd;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod util;

/// Repo-relative artifacts directory (overridable for tests).
pub fn artifacts_dir() -> String {
    std::env::var("ENTQUANT_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}
