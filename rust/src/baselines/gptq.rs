//! GPTQ (Frantar et al. 2023) — the calibration-based comparison method
//! of paper Table 3/D.1.  *Not* data-free: it needs activations.
//!
//! Per layer with inputs X:  H = 2 X^T X + eps*I.  Columns are quantized
//! in order; the rounding error of column j is propagated into the not-
//! yet-quantized columns via the Cholesky factorization of H^{-1}
//! (OBS update), per output row.  Grid: symmetric b-bit, group-wise
//! scales recomputed along the column walk (g=128 default).

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct GptqOpts {
    pub bits: u32,
    pub group: usize,
    /// Hessian damping as a fraction of mean diagonal.
    pub damp: f32,
}

impl GptqOpts {
    pub fn new(bits: u32, group: usize) -> Self {
        GptqOpts { bits, group, damp: 0.01 }
    }
}

#[derive(Clone, Debug)]
pub struct GptqResult {
    pub what: Mat,
    pub bits_per_param: f64,
}

/// Cholesky decomposition of a symmetric positive-definite matrix
/// (lower triangular L with A = L L^T), in place on a dense buffer.
fn cholesky(a: &mut Vec<f64>, n: usize) -> Result<(), String> {
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 {
            return Err(format!("not SPD at {j} (d={d})"));
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in j + 1..n {
            let mut v = a[i * n + j];
            for k in 0..j {
                v -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = v / d;
        }
    }
    // zero the upper triangle for cleanliness
    for i in 0..n {
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Invert an SPD matrix via its Cholesky factor.
fn spd_inverse(a: &[f32], n: usize, damp: f32) -> Result<Vec<f64>, String> {
    let mut m: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    // damping
    let mean_diag: f64 = (0..n).map(|i| m[i * n + i]).sum::<f64>() / n as f64;
    let eps = (damp as f64) * mean_diag.max(1e-12);
    for i in 0..n {
        m[i * n + i] += eps;
    }
    cholesky(&mut m, n)?;
    // solve L L^T X = I column by column
    let mut inv = vec![0.0f64; n * n];
    let mut col = vec![0.0f64; n];
    for c in 0..n {
        // forward solve L y = e_c
        for i in 0..n {
            let mut v = if i == c { 1.0 } else { 0.0 };
            for k in 0..i {
                v -= m[i * n + k] * col[k];
            }
            col[i] = v / m[i * n + i];
        }
        // back solve L^T x = y
        for i in (0..n).rev() {
            let mut v = col[i];
            for k in i + 1..n {
                v -= m[k * n + i] * inv[k * n + c];
            }
            inv[i * n + c] = v / m[i * n + i];
        }
    }
    Ok(inv)
}

/// Quantize one weight matrix given its calibration inputs `x` ([S, K]).
pub fn quantize_gptq(w: &Mat, x: &Mat, opts: &GptqOpts) -> Result<GptqResult, String> {
    let k = w.cols;
    assert_eq!(x.cols, k, "calibration inputs mismatch");
    let qmax = ((1u32 << (opts.bits - 1)) - 1) as f32;

    // H = 2 X^T X (the factor 2 cancels in the update; keep for fidelity)
    let mut h = vec![0.0f32; k * k];
    for s in 0..x.rows {
        let xs = x.row(s);
        for i in 0..k {
            let xi = 2.0 * xs[i];
            for j in i..k {
                h[i * k + j] += xi * xs[j];
            }
        }
    }
    for i in 0..k {
        for j in 0..i {
            h[i * k + j] = h[j * k + i];
        }
    }
    let hinv = spd_inverse(&h, k, opts.damp)?;
    // Cholesky of H^{-1}: the OBS update uses its upper factor; we use
    // hinv directly in the classic sequential form:
    //   err_j = (w_j - q_j) / Hinv[j,j];  w_l -= err_j * Hinv[j,l] (l > j)
    let mut what = Mat::zeros(w.rows, w.cols);
    let mut wrow: Vec<f32> = vec![0.0; k];
    for r in 0..w.rows {
        wrow.copy_from_slice(w.row(r));
        let out = what.row_mut(r);
        let mut scale = 0.0f32;
        for j in 0..k {
            if j % opts.group == 0 {
                // group scale from the *current* (error-compensated) values
                let g1 = (j + opts.group).min(k);
                let amax = wrow[j..g1].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                scale = if amax == 0.0 { 1.0 } else { amax / qmax };
            }
            let q = (wrow[j] / scale).round().clamp(-qmax, qmax) * scale;
            out[j] = q;
            let err = (wrow[j] - q) / hinv[j * k + j] as f32;
            for l in j + 1..k {
                wrow[l] -= err * hinv[j * k + l] as f32;
            }
        }
    }
    let n_groups = w.rows * w.cols.div_ceil(opts.group);
    let bits_per_param = opts.bits as f64 + 16.0 * n_groups as f64 / (w.rows * w.cols) as f64;
    Ok(GptqResult { what, bits_per_param })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::quantize_rtn;
    use crate::tensor::Rng;

    fn randmat(rows: usize, cols: usize, seed: u64, heavy: bool) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| {
                    let v = rng.normal();
                    (if heavy { v * (rng.normal() * 0.5).exp() } else { v }) as f32
                })
                .collect(),
        )
    }

    #[test]
    fn cholesky_identity() {
        let n = 4;
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        cholesky(&mut a, n).unwrap();
        for i in 0..n {
            assert!((a[i * n + i] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn spd_inverse_correct() {
        let n = 6;
        let x = randmat(20, n, 1, false);
        let mut h = vec![0.0f32; n * n];
        for s in 0..20 {
            for i in 0..n {
                for j in 0..n {
                    h[i * n + j] += x.at(s, i) * x.at(s, j);
                }
            }
        }
        for i in 0..n {
            h[i * n + i] += 0.5;
        }
        let inv = spd_inverse(&h, n, 0.0).unwrap();
        // H * Hinv ~ I
        for i in 0..n {
            for j in 0..n {
                let mut v = 0.0f64;
                for t in 0..n {
                    v += h[i * n + t] as f64 * inv[t * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((v - want).abs() < 1e-3, "({i},{j}) {v}");
            }
        }
    }

    /// GPTQ's whole point: on the calibration distribution its layer
    /// *output* error is lower than RTN's, even if weight error is not.
    #[test]
    fn output_error_beats_rtn() {
        let w = randmat(16, 64, 2, true);
        // correlated inputs (realistic activations)
        let base = randmat(96, 64, 3, false);
        let mut x = base.clone();
        for r in 0..x.rows {
            for c in 1..x.cols {
                x.data[r * 64 + c] = 0.6 * x.data[r * 64 + c - 1] + 0.4 * base.data[r * 64 + c];
            }
        }
        let g = quantize_gptq(&w, &x, &GptqOpts::new(3, 64)).unwrap();
        let rt = quantize_rtn(&w, 3, 64);
        let out_err = |what: &Mat| {
            let y = w.matmul_t(&x);
            let yq = what.matmul_t(&x);
            let mut e = 0.0f64;
            for i in 0..y.data.len() {
                e += ((y.data[i] - yq.data[i]) as f64).powi(2);
            }
            e
        };
        let eg = out_err(&g.what);
        let er = out_err(&rt.what);
        assert!(eg < er, "gptq {eg} vs rtn {er}");
    }

    #[test]
    fn high_bits_near_lossless() {
        let w = randmat(8, 32, 5, false);
        let x = randmat(64, 32, 6, false);
        let g = quantize_gptq(&w, &x, &GptqOpts::new(8, 32)).unwrap();
        let d = crate::quant::rel_l1_distortion(&w, &g.what);
        assert!(d < 0.02, "{d}");
    }

    #[test]
    fn degenerate_calibration_still_works() {
        // rank-deficient X: damping must keep H invertible
        let w = randmat(4, 16, 7, false);
        let mut x = Mat::zeros(8, 16);
        for r in 0..8 {
            for c in 0..16 {
                *x.at_mut(r, c) = (r as f32 + 1.0) * 0.1; // rank 1
            }
        }
        let g = quantize_gptq(&w, &x, &GptqOpts::new(4, 16)).unwrap();
        assert!(g.what.data.iter().all(|v| v.is_finite()));
    }
}
