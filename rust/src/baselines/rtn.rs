//! Round-to-nearest (RTN) group-wise baseline — the simplest data-free
//! quantizer the paper references, and the primitive GPTQ builds on.
//!
//! Symmetric b-bit integer grid per group of `group` consecutive
//! in-channel weights: s = absmax / (2^(b-1) - 1),  q = round(w/s).
//! Storage: b bits/weight + one BF16 scale per group.

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct RtnResult {
    pub what: Mat,
    pub bits_per_param: f64,
}

pub fn quantize_rtn(w: &Mat, bits: u32, group: usize) -> RtnResult {
    assert!((2..=8).contains(&bits));
    let qmax = ((1u32 << (bits - 1)) - 1) as f32;
    let mut what = Mat::zeros(w.rows, w.cols);
    for r in 0..w.rows {
        let row = w.row(r);
        let out = what.row_mut(r);
        for g0 in (0..w.cols).step_by(group) {
            let g1 = (g0 + group).min(w.cols);
            let amax = row[g0..g1].iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            if amax == 0.0 {
                continue;
            }
            let s = amax / qmax;
            for c in g0..g1 {
                let q = (row[c] / s).round().clamp(-qmax, qmax);
                out[c] = q * s;
            }
        }
    }
    let n_groups = w.rows * w.cols.div_ceil(group);
    let bits_per_param = bits as f64 + 16.0 * n_groups as f64 / (w.rows * w.cols) as f64;
    RtnResult { what, bits_per_param }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rel_l1_distortion;
    use crate::tensor::Rng;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn more_bits_less_error() {
        let w = randmat(8, 128, 1);
        let mut prev = f64::INFINITY;
        for bits in [2u32, 3, 4, 8] {
            let r = quantize_rtn(&w, bits, 64);
            let d = rel_l1_distortion(&w, &r.what);
            assert!(d < prev, "bits={bits}: {d} >= {prev}");
            prev = d;
        }
    }

    #[test]
    fn smaller_groups_less_error_more_bits() {
        let w = randmat(8, 128, 2);
        let a = quantize_rtn(&w, 3, 32);
        let b = quantize_rtn(&w, 3, 128);
        assert!(rel_l1_distortion(&w, &a.what) <= rel_l1_distortion(&w, &b.what));
        assert!(a.bits_per_param > b.bits_per_param);
    }

    #[test]
    fn bits_accounting() {
        let w = randmat(4, 128, 3);
        let r = quantize_rtn(&w, 4, 64);
        assert!((r.bits_per_param - (4.0 + 16.0 / 64.0)).abs() < 1e-9);
    }

    #[test]
    fn values_on_grid() {
        let w = randmat(2, 64, 4);
        let r = quantize_rtn(&w, 2, 64);
        // 2-bit symmetric: q in {-1, 0, 1} per group -> |values| in {0, s}
        for row in 0..2 {
            use std::collections::BTreeSet;
            let set: BTreeSet<u32> = r.what.row(row).iter().map(|v| v.abs().to_bits()).collect();
            assert!(set.len() <= 2, "{set:?}");
        }
    }

    #[test]
    fn zero_matrix_stays_zero() {
        let w = Mat::zeros(3, 16);
        let r = quantize_rtn(&w, 4, 8);
        assert!(r.what.data.iter().all(|&v| v == 0.0));
    }
}
