//! NF4 (NormalFloat-4, Dettmers et al. 2023 / QLoRA) — block-wise
//! codebook quantization with the information-theoretically-optimal
//! 16-level grid for N(0,1) weights.  The strongest 4-bit data-free
//! baseline in the paper's Table 2.
//!
//! Each block of `group` weights is scaled by its absmax into [-1, 1]
//! and snapped to the fixed NF4 codebook.  Storage: 4 bits/weight + one
//! BF16 scale per block.

use crate::tensor::Mat;

/// The QLoRA NF4 codebook (quantiles of N(0,1), normalized to [-1,1]).
pub const NF4_LEVELS: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

#[derive(Clone, Debug)]
pub struct Nf4Result {
    pub what: Mat,
    pub bits_per_param: f64,
}

#[inline]
fn nearest_level(x: f32) -> f32 {
    // levels are sorted: binary search + neighbor compare
    let mut lo = 0usize;
    let mut hi = 15usize;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if NF4_LEVELS[mid] < x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    if lo == 0 {
        return NF4_LEVELS[0];
    }
    let below = NF4_LEVELS[lo - 1];
    let above = NF4_LEVELS[lo];
    if (x - below) <= (above - x) {
        below
    } else {
        above
    }
}

pub fn quantize_nf4(w: &Mat, group: usize) -> Nf4Result {
    let mut what = Mat::zeros(w.rows, w.cols);
    for r in 0..w.rows {
        let row = w.row(r);
        let out = what.row_mut(r);
        for g0 in (0..w.cols).step_by(group) {
            let g1 = (g0 + group).min(w.cols);
            let amax = row[g0..g1].iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            if amax == 0.0 {
                continue;
            }
            for c in g0..g1 {
                out[c] = nearest_level(row[c] / amax) * amax;
            }
        }
    }
    let n_groups = w.rows * w.cols.div_ceil(group);
    let bits_per_param = 4.0 + 16.0 * n_groups as f64 / (w.rows * w.cols) as f64;
    Nf4Result { what, bits_per_param }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::quantize_rtn;
    use crate::quant::rel_l1_distortion;
    use crate::tensor::Rng;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn codebook_sorted_and_symmetric_ends() {
        for i in 1..16 {
            assert!(NF4_LEVELS[i] > NF4_LEVELS[i - 1]);
        }
        assert_eq!(NF4_LEVELS[0], -1.0);
        assert_eq!(NF4_LEVELS[15], 1.0);
        assert_eq!(NF4_LEVELS[7], 0.0);
    }

    #[test]
    fn nearest_level_correct() {
        assert_eq!(nearest_level(-1.5), -1.0);
        assert_eq!(nearest_level(1.5), 1.0);
        assert_eq!(nearest_level(0.0), 0.0);
        assert_eq!(nearest_level(0.079), 0.07958029955625534);
        // brute force check
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let x = (rng.uniform() * 2.0 - 1.0) as f32;
            let got = nearest_level(x);
            let want = NF4_LEVELS
                .iter()
                .copied()
                .min_by(|a, b| (a - x).abs().partial_cmp(&(b - x).abs()).unwrap())
                .unwrap();
            assert_eq!(got, want, "x={x}");
        }
    }

    #[test]
    fn beats_int4_rtn_on_gaussian_weights() {
        // NF4's raison d'etre: optimal for normally distributed weights
        let w = gaussian(16, 256, 1);
        let nf = quantize_nf4(&w, 64);
        let rtn = quantize_rtn(&w, 4, 64);
        let d_nf = rel_l1_distortion(&w, &nf.what);
        let d_rtn = rel_l1_distortion(&w, &rtn.what);
        assert!(d_nf < d_rtn, "nf4 {d_nf} vs rtn {d_rtn}");
    }

    #[test]
    fn block_absmax_is_exact() {
        // the absmax element of each block must be reconstructed exactly
        let w = gaussian(1, 64, 2);
        let r = quantize_nf4(&w, 64);
        let (mut idx, mut best) = (0, 0.0f32);
        for (i, &v) in w.row(0).iter().enumerate() {
            if v.abs() > best {
                best = v.abs();
                idx = i;
            }
        }
        assert!((r.what.at(0, idx) - w.at(0, idx)).abs() < 1e-6);
    }

    #[test]
    fn bits_accounting() {
        let w = gaussian(2, 128, 3);
        let r = quantize_nf4(&w, 64);
        assert!((r.bits_per_param - (4.0 + 16.0 / 64.0)).abs() < 1e-9);
    }
}
