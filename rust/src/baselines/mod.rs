//! Comparison methods (paper Tables 2/3/D.1/G.1): data-free RTN, NF4 and
//! HQQ, plus calibration-based GPTQ — each applied model-wide through a
//! single `Method` interface so the bench harness treats every method
//! uniformly.

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

pub mod gptq;
pub mod hqq;
pub mod nf4;
pub mod rtn;

use crate::model::{Forward, Model, BLOCK_LINEARS};
use crate::quant::{absmax_scales, quantize, Format};
use crate::tensor::Mat;
use anyhow::Result;

#[derive(Clone, Debug)]
pub enum Method {
    /// Lossless-coded Float8/Int8 at AbsMax (the paper's "Float8" row,
    /// ~6.5 effective bits after ANS).
    Float8Absmax { fmt: Format },
    Rtn { bits: u32, group: usize },
    Nf4 { group: usize },
    Hqq { bits: u32, group: usize },
    /// calibration-based; quantizes with error compensation from a
    /// Hessian built on `calib_tokens`
    Gptq { bits: u32, group: usize },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Float8Absmax { fmt } => format!("{}-absmax", fmt.name()),
            Method::Rtn { bits, group } => format!("rtn-{bits}b-g{group}"),
            Method::Nf4 { group } => format!("nf4-g{group}"),
            Method::Hqq { bits, group } => format!("hqq-{bits}b-g{group}"),
            Method::Gptq { bits, group } => format!("gptq-{bits}b-g{group}"),
        }
    }
}

pub struct BaselineModel {
    pub model: Model,
    /// effective storage bits per linear parameter
    pub bits_per_param: f64,
    pub wall_s: f64,
}

/// Apply a baseline method to every quantizable linear of `model`,
/// returning the dequantized model for evaluation plus the storage rate.
/// `calib_tokens` is only consumed by GPTQ.
pub fn apply(model: &Model, method: &Method, calib_tokens: Option<&[u8]>) -> Result<BaselineModel> {
    let t0 = std::time::Instant::now();
    let mut out = model.clone();
    let mut bits_weighted = 0.0f64;
    let mut params = 0usize;

    match method {
        Method::Gptq { bits, group } => {
            let toks = calib_tokens.ok_or_else(|| anyhow::anyhow!("GPTQ needs calibration data"))?;
            let fwd = Forward::new(model);
            let captures = fwd.capture_linear_inputs(toks);
            for (b, cap) in captures.iter().enumerate() {
                let (attn_in, attn_ctx, mlp_in, mlp_hidden) = cap;
                for &name in BLOCK_LINEARS.iter() {
                    let x: &Mat = match name {
                        "wq" | "wk" | "wv" => attn_in,
                        "wo" => attn_ctx,
                        "w_gate" | "w_up" => mlp_in,
                        "w_down" => mlp_hidden,
                        _ => unreachable!(),
                    };
                    let w = model.blocks[b].linear(name);
                    let r = gptq::quantize_gptq(w, x, &gptq::GptqOpts::new(*bits, *group))
                        .map_err(|e| anyhow::anyhow!("gptq blocks.{b}.{name}: {e}"))?;
                    bits_weighted += r.bits_per_param * w.data.len() as f64;
                    params += w.data.len();
                    *out.blocks[b].linear_mut(name) = r.what;
                }
            }
        }
        _ => {
            for b in 0..model.blocks.len() {
                for &name in BLOCK_LINEARS.iter() {
                    let w = model.blocks[b].linear(name);
                    let (what, bpp) = match method {
                        Method::Float8Absmax { fmt } => {
                            let s = absmax_scales(w, *fmt);
                            let q = quantize(w, &s, *fmt);
                            // effective bits after lossless coding of the
                            // 8-bit symbols (the paper's ~6.5-bit Float8 row)
                            let h = crate::entropy::entropy_of(&q.symbols);
                            let scale_bits = 16.0 * w.rows as f64 / w.data.len() as f64;
                            (q.dequantize(), h + scale_bits)
                        }
                        Method::Rtn { bits, group } => {
                            let r = rtn::quantize_rtn(w, *bits, *group);
                            (r.what, r.bits_per_param)
                        }
                        Method::Nf4 { group } => {
                            let r = nf4::quantize_nf4(w, *group);
                            (r.what, r.bits_per_param)
                        }
                        Method::Hqq { bits, group } => {
                            let r = hqq::quantize_hqq(w, &hqq::HqqOpts::new(*bits, *group));
                            (r.what, r.bits_per_param)
                        }
                        Method::Gptq { .. } => unreachable!(),
                    };
                    bits_weighted += bpp * w.data.len() as f64;
                    params += w.data.len();
                    *out.blocks[b].linear_mut(name) = what;
                }
            }
        }
    }

    Ok(BaselineModel {
        model: out,
        bits_per_param: bits_weighted / params as f64,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loader::synthetic_model;
    use crate::model::Config;

    fn tiny() -> Model {
        synthetic_model(
            Config { name: "T".into(), vocab: 96, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_ctx: 64 },
            21,
        )
    }

    #[test]
    fn all_methods_apply() {
        let m = tiny();
        let calib: Vec<u8> = (0..48u8).map(|i| i % 96).collect();
        for method in [
            Method::Float8Absmax { fmt: Format::F8E4M3 },
            Method::Rtn { bits: 4, group: 16 },
            Method::Nf4 { group: 16 },
            Method::Hqq { bits: 4, group: 16 },
            Method::Gptq { bits: 4, group: 16 },
        ] {
            let r = apply(&m, &method, Some(&calib)).unwrap();
            assert!(r.bits_per_param > 2.0 && r.bits_per_param < 9.0, "{method:?}: {}", r.bits_per_param);
            // quantized model must stay finite
            let f = Forward::new(&r.model);
            let logits = f.logits(&[1, 2, 3]);
            assert!(logits.data.iter().all(|v| v.is_finite()), "{method:?}");
        }
    }

    #[test]
    fn gptq_without_calibration_errors() {
        let m = tiny();
        assert!(apply(&m, &Method::Gptq { bits: 4, group: 16 }, None).is_err());
    }

    #[test]
    fn method_names_distinct() {
        use std::collections::BTreeSet;
        let names: BTreeSet<String> = [
            Method::Float8Absmax { fmt: Format::F8E4M3 },
            Method::Rtn { bits: 4, group: 64 },
            Method::Nf4 { group: 64 },
            Method::Hqq { bits: 2, group: 64 },
            Method::Gptq { bits: 2, group: 128 },
        ]
        .iter()
        .map(|m| m.name())
        .collect();
        assert_eq!(names.len(), 5);
    }
}
