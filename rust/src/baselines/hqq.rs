//! HQQ — Half-Quadratic Quantization (Badri & Shaji 2023), the paper's
//! main data-free competitor (Table 2).
//!
//! Asymmetric b-bit grid per group with a *float* zero point, fitted by
//! half-quadratic alternating optimization of  ||W - D(Q(W))||_p^p with
//! p < 1 (robust to outliers):
//!
//!   Q(w) = clamp(round(w/s + z), 0, 2^b - 1)       (quant)
//!   D(q) = s * (q - z)                             (dequant)
//!   repeat:  e   <- shrink_lp(W - D(Q(W)), beta, p)
//!            z   <- mean_g( Q(W) - (W - e)/s )
//!
//! `shrink_lp` is the generalized soft-threshold of the l_p prox.
//! Storage: b bits/weight + BF16 scale + BF16 zero per group.

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct HqqOpts {
    pub bits: u32,
    pub group: usize,
    pub iters: usize,
    pub p: f32,
    pub beta0: f32,
    pub kappa: f32,
}

impl HqqOpts {
    pub fn new(bits: u32, group: usize) -> Self {
        HqqOpts { bits, group, iters: 20, p: 0.7, beta0: 10.0, kappa: 1.01 }
    }
}

#[derive(Clone, Debug)]
pub struct HqqResult {
    pub what: Mat,
    pub bits_per_param: f64,
}

/// Generalized soft-thresholding: prox of (1/beta)|.|^p at x.
#[inline]
fn shrink_lp(x: f32, beta: f32, p: f32) -> f32 {
    // for p < 1 the standard approximation: sign(x) * max(0, |x| - |x|^(p-1)/beta)
    let a = x.abs();
    if a < 1e-12 {
        return 0.0;
    }
    let t = a - a.powf(p - 1.0) / beta;
    if t > 0.0 {
        x.signum() * t
    } else {
        0.0
    }
}

pub fn quantize_hqq(w: &Mat, opts: &HqqOpts) -> HqqResult {
    let qmax = ((1u32 << opts.bits) - 1) as f32;
    let mut what = Mat::zeros(w.rows, w.cols);
    for r in 0..w.rows {
        let row = w.row(r);
        let out = what.row_mut(r);
        for g0 in (0..w.cols).step_by(opts.group) {
            let g1 = (g0 + opts.group).min(w.cols);
            let grp = &row[g0..g1];
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &x in grp {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            if hi - lo < 1e-12 {
                for c in g0..g1 {
                    out[c] = row[c];
                }
                continue;
            }
            let s = (hi - lo) / qmax;
            let mut z = -lo / s; // float zero point (HQQ keeps it fp)
            let mut beta = opts.beta0;
            let n = grp.len() as f32;
            let mut q: Vec<f32> = vec![0.0; grp.len()];
            for _ in 0..opts.iters {
                for (i, &x) in grp.iter().enumerate() {
                    q[i] = (x / s + z).round().clamp(0.0, qmax);
                }
                // e = shrink(W - D(Q))
                // z update: mean(Q - (W - e)/s)
                let mut zsum = 0.0f32;
                for (i, &x) in grp.iter().enumerate() {
                    let d = s * (q[i] - z);
                    let e = shrink_lp(x - d, beta, opts.p);
                    zsum += q[i] - (x - e) / s;
                }
                z = zsum / n;
                beta *= opts.kappa;
            }
            for c in g0..g1 {
                let qi = (row[c] / s + z).round().clamp(0.0, qmax);
                out[c] = s * (qi - z);
            }
        }
    }
    let n_groups = w.rows * w.cols.div_ceil(opts.group);
    let bits_per_param =
        opts.bits as f64 + 32.0 * n_groups as f64 / (w.rows * w.cols) as f64;
    HqqResult { what, bits_per_param }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::rtn::quantize_rtn;
    use crate::quant::rel_l1_distortion;
    use crate::tensor::Rng;

    fn heavy(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| (rng.normal() * (rng.normal() * 0.7).exp()) as f32)
                .collect(),
        )
    }

    #[test]
    fn shrink_is_odd_and_contracting() {
        for x in [-3.0f32, -0.5, 0.5, 3.0] {
            let y = shrink_lp(x, 5.0, 0.7);
            assert!(y.abs() <= x.abs(), "contraction");
            assert_eq!(y, -shrink_lp(-x, 5.0, 0.7), "odd function");
        }
        assert_eq!(shrink_lp(0.0, 5.0, 0.7), 0.0);
        // small values are thresholded to exactly zero
        assert_eq!(shrink_lp(0.01, 1.0, 0.7), 0.0);
    }

    #[test]
    fn beats_rtn_on_heavy_tails_at_4bit() {
        // HQQ's claim: robust l_p fitting beats absmax RTN under outliers
        let w = heavy(16, 256, 1);
        let h = quantize_hqq(&w, &HqqOpts::new(4, 64));
        let r = quantize_rtn(&w, 4, 64);
        let dh = rel_l1_distortion(&w, &h.what);
        let dr = rel_l1_distortion(&w, &r.what);
        assert!(dh < dr, "hqq {dh} vs rtn {dr}");
    }

    #[test]
    fn distortion_grows_as_bits_shrink() {
        let w = heavy(8, 128, 2);
        let mut prev = 0.0f64;
        for bits in [4u32, 3, 2] {
            let h = quantize_hqq(&w, &HqqOpts::new(bits, 64));
            let d = rel_l1_distortion(&w, &h.what);
            assert!(d > prev, "bits={bits}");
            prev = d;
        }
        // 2-bit group-64 should be *bad* — the collapse Table 2 shows
        assert!(prev > 0.2, "2-bit HQQ distortion suspiciously low: {prev}");
    }

    #[test]
    fn small_groups_help_2bit() {
        let w = heavy(8, 128, 3);
        let g16 = quantize_hqq(&w, &HqqOpts::new(2, 16));
        let g64 = quantize_hqq(&w, &HqqOpts::new(2, 64));
        assert!(rel_l1_distortion(&w, &g16.what) < rel_l1_distortion(&w, &g64.what));
        assert!(g16.bits_per_param > g64.bits_per_param);
    }

    #[test]
    fn bits_accounting_includes_zero_point() {
        let w = heavy(4, 128, 4);
        let h = quantize_hqq(&w, &HqqOpts::new(3, 64));
        assert!((h.bits_per_param - (3.0 + 32.0 / 64.0)).abs() < 1e-9);
    }

    #[test]
    fn constant_group_passthrough() {
        let w = Mat::from_vec(1, 8, vec![2.5; 8]);
        let h = quantize_hqq(&w, &HqqOpts::new(2, 8));
        for &v in &h.what.data {
            assert!((v - 2.5).abs() < 1e-6);
        }
    }
}
