//! Paper Algorithm 1 — the EntQuant per-layer encoder:
//!   1. AbsMax scale init (eq. 1)
//!   2. L-BFGS over the (log-)scales minimizing the RD objective (eq. 3)
//!   3. quantize to the base format's symbol alphabet
//! Block grouping + ANS framing happens in `store::pipeline` (§A.1).
//!
//! Also hosts the lambda calibration: the paper exploits the log-linear,
//! model-independent lam <-> entropy map (Fig. A.1) to pick lam from a
//! global grid; we make the same map explicit via bisection on a probe
//! layer, then reuse it for the whole model.

use super::lbfgs::{minimize, LbfgsOpts};
use super::objective::RdObjective;
use crate::entropy::entropy_of;
use crate::quant::{absmax_scales, quantize, rel_l1_distortion, Format, QMat};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct EncodeOpts {
    pub lam: f64,
    pub fmt: Format,
    pub max_iters: usize,
    /// Skip the entropy optimization entirely (8-bit AbsMax path used
    /// for super-weight-excluded layers; still ANS-coded downstream).
    pub skip_optimization: bool,
}

impl Default for EncodeOpts {
    fn default() -> Self {
        EncodeOpts { lam: 0.1, fmt: Format::F8E4M3, max_iters: 60, skip_optimization: false }
    }
}

#[derive(Clone, Debug)]
pub struct LayerStats {
    pub entropy_bits: f64,
    pub distortion: f64,
    pub sparsity: f64,
    pub lbfgs_iters: usize,
    pub wall_ms: f64,
}

/// Encode one weight matrix (Algorithm 1 lines 1–3).
pub fn encode_layer(w: &Mat, opts: &EncodeOpts) -> (QMat, LayerStats) {
    let t0 = std::time::Instant::now();
    let s0 = absmax_scales(w, opts.fmt);

    let (mut scales, iters) = if opts.skip_optimization {
        (s0, 0)
    } else {
        let obj = RdObjective::new(w, opts.lam, opts.fmt);
        let u0: Vec<f64> = s0.iter().map(|&s| (s.max(1e-30) as f64).ln()).collect();
        let mut s_buf: Vec<f32> = Vec::with_capacity(w.rows);
        let lopts = LbfgsOpts { max_iters: opts.max_iters, ..Default::default() };
        let (u, _, iters) = minimize(
            |u, g| obj.value_grad_log(u, g, &mut s_buf),
            &u0,
            &lopts,
        );
        (u.iter().map(|&v| v.exp() as f32).collect::<Vec<f32>>(), iters)
    };

    // scales ship as BF16 (paper §2.2); round *before* quantizing so the
    // stored scales are exactly the ones the codes were produced under
    crate::quant::bf16::round_slice(&mut scales);
    let q = quantize(w, &scales, opts.fmt);
    let ent = entropy_of(&q.symbols);
    let what = q.dequantize();
    let dist = rel_l1_distortion(w, &what);
    let zero_sym = opts.fmt.quantize(0.0, 1.0).0;
    let sparsity = q.symbols.iter().filter(|&&b| b == zero_sym).count() as f64
        / q.symbols.len() as f64;
    let stats = LayerStats {
        entropy_bits: ent,
        distortion: dist,
        sparsity,
        lbfgs_iters: iters,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    };
    (q, stats)
}

/// Bisection calibration of lam for a target entropy on a probe matrix.
/// The map is monotone decreasing (more lam -> less entropy); Fig. A.1
/// shows it is near model-independent, so one probe layer suffices.
pub fn calibrate_lambda(probe: &Mat, target_bits: f64, fmt: Format) -> f64 {
    let ent_at = |lam: f64| {
        let (q, _) = encode_layer(probe, &EncodeOpts { lam, fmt, max_iters: 40, skip_optimization: false });
        entropy_of(&q.symbols)
    };
    let (mut lo, mut hi) = (1e-4f64, 3000.0f64);
    let e_lo = ent_at(lo);
    if target_bits >= e_lo {
        return lo;
    }
    let e_hi = ent_at(hi);
    if target_bits <= e_hi {
        return hi;
    }
    // bisection in log(lam)
    for _ in 0..12 {
        let mid = (lo.ln() + hi.ln()) / 2.0;
        let lam = mid.exp();
        let e = ent_at(lam);
        if e > target_bits {
            lo = lam;
        } else {
            hi = lam;
        }
        if (hi / lo).ln().abs() < 0.05 {
            break;
        }
    }
    (lo.ln() / 2.0 + hi.ln() / 2.0).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn heavy_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| (rng.normal() * (rng.normal() * 0.8).exp()) as f32)
                .collect(),
        )
    }

    #[test]
    fn optimization_beats_absmax_on_objective() {
        let w = heavy_mat(32, 64, 1);
        let lam = 0.3;
        let base = encode_layer(&w, &EncodeOpts { lam, skip_optimization: true, ..Default::default() });
        let opt = encode_layer(&w, &EncodeOpts { lam, ..Default::default() });
        let j = |st: &LayerStats, q: &QMat| {
            let rmean: f64 = {
                let cv = q.code_values();
                cv.data.iter().map(|&c| c.abs() as f64).sum::<f64>() / cv.data.len() as f64
            };
            st.distortion + lam * rmean
        };
        assert!(j(&opt.1, &opt.0) <= j(&base.1, &base.0) + 1e-9,
                "opt {} vs absmax {}", j(&opt.1, &opt.0), j(&base.1, &base.0));
    }

    #[test]
    fn entropy_monotone_in_lambda() {
        let w = heavy_mat(48, 96, 2);
        let mut prev = f64::INFINITY;
        for lam in [0.001, 0.3, 30.0] {
            let (_, st) = encode_layer(&w, &EncodeOpts { lam, ..Default::default() });
            assert!(st.entropy_bits <= prev + 0.2, "lam={lam}: {} > {}", st.entropy_bits, prev);
            prev = st.entropy_bits;
        }
    }

    #[test]
    fn high_lambda_reaches_low_entropy_with_bounded_distortion() {
        let w = heavy_mat(64, 128, 3);
        let (q, st) = encode_layer(&w, &EncodeOpts { lam: 300.0, max_iters: 80, ..Default::default() });
        assert!(st.entropy_bits < 3.5, "H={}", st.entropy_bits);
        assert!(st.distortion < 0.9, "d={}", st.distortion);
        assert!(st.sparsity > 0.05, "sparsity={}", st.sparsity);
        assert!(q.symbols.len() == 64 * 128);
    }

    #[test]
    fn skip_optimization_is_absmax() {
        let w = heavy_mat(8, 16, 4);
        let (q, st) = encode_layer(&w, &EncodeOpts { skip_optimization: true, ..Default::default() });
        let mut s0 = absmax_scales(&w, Format::F8E4M3);
        crate::quant::bf16::round_slice(&mut s0); // scales ship as BF16
        assert_eq!(q.scales, s0);
        assert_eq!(st.lbfgs_iters, 0);
        assert!(st.distortion < 0.05);
    }

    #[test]
    fn calibration_hits_target() {
        let w = heavy_mat(64, 128, 5);
        for target in [5.5f64, 3.0] {
            let lam = calibrate_lambda(&w, target, Format::F8E4M3);
            let (_, st) = encode_layer(&w, &EncodeOpts { lam, ..Default::default() });
            assert!((st.entropy_bits - target).abs() < 0.8,
                    "target={target} got {} (lam={lam})", st.entropy_bits);
        }
    }

    #[test]
    fn int8_format_works_too() {
        let w = heavy_mat(32, 64, 6);
        let (q, st) = encode_layer(&w, &EncodeOpts { lam: 1.0, fmt: Format::Int8, ..Default::default() });
        assert_eq!(q.fmt, Format::Int8);
        assert!(st.entropy_bits < 8.0);
        assert!(st.distortion < 0.5);
    }
}
