//! EntQuant's rate–distortion core: the relaxed entropy objective
//! (paper eq. 3), the from-scratch L-BFGS solver, and the per-layer
//! encoder (Algorithm 1).

pub mod encoder;
pub mod lbfgs;
pub mod objective;

pub use encoder::{calibrate_lambda, encode_layer, EncodeOpts, LayerStats};
pub use lbfgs::{minimize, LbfgsOpts};
pub use objective::RdObjective;
