//! The native rate-distortion objective (paper eq. 3) with clipped-STE
//! gradients — semantics identical to python/compile/rd.py (cross-checked
//! against artifacts/fixtures/rd_grad.json).
//!
//! ```text
//! J(s) = ||W - What||_1 / ||W||_1  +  lam * mean(|codes|)
//! codes = clamp(round_gamma(W/s)),  What = s * codes
//! ```
//!
//! Gradient w.r.t. s (per output channel), straight-through across the
//! rounding, exact across the clamp:
//!   inside  |W/s| <= Qmax:  dcodes/ds = -W/s^2,  dWhat/ds = codes - W/s
//!   clamped |W/s|  > Qmax:  dcodes/ds = 0,       dWhat/ds = codes

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

use crate::quant::Format;
use crate::tensor::Mat;

pub struct RdObjective<'a> {
    pub w: &'a Mat,
    pub lam: f64,
    pub fmt: Format,
    /// precomputed ||W||_1
    pub w_l1: f64,
}

impl<'a> RdObjective<'a> {
    pub fn new(w: &'a Mat, lam: f64, fmt: Format) -> Self {
        let w_l1 = w.l1_norm() + 1e-12;
        RdObjective { w, lam, fmt, w_l1 }
    }

    /// Value and gradient w.r.t. the per-row scales `s`.
    pub fn value_grad(&self, s: &[f32], grad: &mut [f64]) -> f64 {
        let (rows, cols) = (self.w.rows, self.w.cols);
        assert_eq!(s.len(), rows);
        assert_eq!(grad.len(), rows);
        let qmax = self.fmt.qmax();
        let mn = (rows * cols) as f64;
        let inv_r = 1.0 / mn; // R = mean(|codes|)

        let mut dist = 0.0f64;
        let mut rsum = 0.0f64;
        for r in 0..rows {
            let sr = s[r];
            let mut gd = 0.0f64; // d(distortion)/ds
            let mut gr = 0.0f64; // d(R)/ds
            if sr == 0.0 {
                // codes = 0, What = 0: distortion = |W| row mass, grad 0
                for &w in self.w.row(r) {
                    dist += w.abs() as f64;
                }
                grad[r] = 0.0;
                continue;
            }
            for &w in self.w.row(r) {
                let u = w / sr;
                let inside = u.abs() <= qmax;
                let uc = u.clamp(-qmax, qmax);
                let code = self.fmt.round(uc);
                let what = sr * code;
                let resid = w - what;
                dist += resid.abs() as f64;
                rsum += code.abs() as f64;
                let sgn_resid = if resid > 0.0 { 1.0f64 } else if resid < 0.0 { -1.0 } else { 0.0 };
                let sgn_code = if code > 0.0 { 1.0f64 } else if code < 0.0 { -1.0 } else { 0.0 };
                if inside {
                    // dWhat/ds = code - u ; dcodes/ds = -u/s
                    gd += -sgn_resid * (code - u) as f64;
                    gr += sgn_code * (-(u as f64) / sr as f64);
                } else {
                    // dWhat/ds = code (u pinned at +-qmax); dcodes/ds = 0
                    gd += -sgn_resid * code as f64;
                }
            }
            grad[r] = gd / self.w_l1 + self.lam * gr * inv_r;
        }
        dist / self.w_l1 + self.lam * rsum * inv_r
    }

    /// Same objective over u = ln(s): the parametrization the encoder
    /// actually optimizes (scales travel orders of magnitude before the
    /// f8 grid's uniform denormal region is reached — see DESIGN.md).
    pub fn value_grad_log(&self, u: &[f64], grad_u: &mut [f64], s_buf: &mut Vec<f32>) -> f64 {
        s_buf.clear();
        s_buf.extend(u.iter().map(|&v| v.exp() as f32));
        let val = self.value_grad(s_buf, grad_u);
        for r in 0..u.len() {
            grad_u[r] *= s_buf[r] as f64; // chain rule d/du = s * d/ds
        }
        val
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::absmax_scales;
    use crate::tensor::Rng;

    fn heavy_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| (rng.normal() * rng.normal().exp()) as f32)
                .collect(),
        )
    }

    #[test]
    fn zero_distortion_on_grid() {
        let w = Mat::from_vec(1, 4, vec![1.0, 2.0, -0.5, 0.25]);
        let obj = RdObjective::new(&w, 0.0, Format::F8E4M3);
        let mut g = vec![0.0; 1];
        let v = obj.value_grad(&[1.0], &mut g);
        assert!(v.abs() < 1e-9, "{v}");
    }

    #[test]
    fn matches_python_fixture() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/fixtures/rd_grad.json");
        let Ok(text) = std::fs::read_to_string(path) else {
            eprintln!("fixture missing; run `make artifacts` (skipping)");
            return;
        };
        let v = crate::store::json::parse(&text).unwrap();
        let rows_json = v.get("w").unwrap().as_array().unwrap();
        let rows = rows_json.len();
        let cols = rows_json[0].as_array().unwrap().len();
        let w = Mat::from_vec(
            rows,
            cols,
            rows_json.iter().flat_map(|r| r.f64_array().unwrap()).map(|x| x as f32).collect(),
        );
        let s: Vec<f32> = v.get("s").unwrap().f64_array().unwrap().iter().map(|&x| x as f32).collect();
        let lam = v.get("lam").unwrap().as_f64().unwrap();
        let want_val = v.get("value").unwrap().as_f64().unwrap();
        let want_grad = v.get("grad").unwrap().f64_array().unwrap();

        let obj = RdObjective::new(&w, lam, Format::F8E4M3);
        let mut g = vec![0.0; rows];
        let val = obj.value_grad(&s, &mut g);
        assert!((val - want_val).abs() < 1e-4 * want_val.abs().max(1.0), "{val} vs {want_val}");
        for r in 0..rows {
            assert!(
                (g[r] - want_grad[r]).abs() < 1e-3 * want_grad[r].abs().max(1.0),
                "grad[{r}]: {} vs {}",
                g[r],
                want_grad[r]
            );
        }
    }

    #[test]
    fn gradient_is_descent_direction() {
        let w = heavy_mat(8, 32, 3);
        let s0 = absmax_scales(&w, Format::F8E4M3);
        let obj = RdObjective::new(&w, 0.05, Format::F8E4M3);
        let mut g = vec![0.0; 8];
        let v0 = obj.value_grad(&s0, &mut g);
        let gn: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
        let eps = 1e-3 * s0.iter().map(|&x| x as f64).sum::<f64>() / 8.0 / gn.max(1e-12);
        let s_minus: Vec<f32> = (0..8).map(|i| s0[i] - (eps * g[i]) as f32).collect();
        let s_plus: Vec<f32> = (0..8).map(|i| s0[i] + (eps * g[i]) as f32).collect();
        let mut tmp = vec![0.0; 8];
        let vm = obj.value_grad(&s_minus, &mut tmp);
        let vp = obj.value_grad(&s_plus, &mut tmp);
        assert!(vm <= vp + 0.05 * v0.abs(), "vm={vm} vp={vp}");
    }

    #[test]
    fn log_parametrization_chain_rule() {
        let w = heavy_mat(4, 16, 9);
        let s0 = absmax_scales(&w, Format::F8E4M3);
        let obj = RdObjective::new(&w, 0.1, Format::F8E4M3);
        let u: Vec<f64> = s0.iter().map(|&x| (x as f64).ln()).collect();
        let mut gu = vec![0.0; 4];
        let mut gs = vec![0.0; 4];
        let mut sbuf = Vec::new();
        let vu = obj.value_grad_log(&u, &mut gu, &mut sbuf);
        let vs = obj.value_grad(&s0, &mut gs);
        assert!((vu - vs).abs() < 1e-5 * vs.abs().max(1.0));
        for i in 0..4 {
            assert!((gu[i] - gs[i] * s0[i] as f64).abs() < 1e-6 * gs[i].abs().max(1.0));
        }
    }

    #[test]
    fn clamped_region_pushes_scale_up() {
        // all symbols saturated: gradient must point to *larger* s
        // (this is the clipped-STE regression python hit too)
        let w = heavy_mat(2, 16, 12);
        let s_tiny: Vec<f32> = vec![1e-6, 1e-6];
        let obj = RdObjective::new(&w, 0.0, Format::F8E4M3);
        let mut g = vec![0.0; 2];
        obj.value_grad(&s_tiny, &mut g);
        assert!(g[0] < 0.0 && g[1] < 0.0, "negative grad = increase s: {g:?}");
    }
}
