//! From-scratch L-BFGS (Liu & Nocedal 1989): two-loop recursion with
//! Armijo backtracking line search.  This is the solver the paper runs
//! per layer over the channel scales (§2.2); history m=8, which is
//! plenty for the smooth-ish STE landscape.

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

/// Minimize `f` starting from `x0`.  `f(x, grad_out) -> value` must fill
/// `grad_out` with the gradient.  Returns (x*, f(x*), iterations used).
pub struct LbfgsOpts {
    pub max_iters: usize,
    pub history: usize,
    pub grad_tol: f64,
    /// initial step of the backtracking search
    pub step0: f64,
    /// Armijo sufficient-decrease constant
    pub c1: f64,
}

impl Default for LbfgsOpts {
    fn default() -> Self {
        LbfgsOpts { max_iters: 60, history: 8, grad_tol: 1e-7, step0: 1.0, c1: 1e-4 }
    }
}

pub fn minimize<F>(mut f: F, x0: &[f64], opts: &LbfgsOpts) -> (Vec<f64>, f64, usize)
where
    F: FnMut(&[f64], &mut [f64]) -> f64,
{
    let n = x0.len();
    let mut x = x0.to_vec();
    let mut g = vec![0.0; n];
    let mut fx = f(&x, &mut g);

    let mut s_hist: Vec<Vec<f64>> = Vec::new();
    let mut y_hist: Vec<Vec<f64>> = Vec::new();
    let mut rho_hist: Vec<f64> = Vec::new();

    let mut iters = 0;
    for it in 0..opts.max_iters {
        iters = it + 1;
        let gnorm = norm(&g);
        if gnorm < opts.grad_tol {
            break;
        }

        // two-loop recursion: d = -H g
        let mut q = g.clone();
        let m = s_hist.len();
        let mut alpha = vec![0.0; m];
        for i in (0..m).rev() {
            alpha[i] = rho_hist[i] * dot(&s_hist[i], &q);
            axpy(&mut q, -alpha[i], &y_hist[i]);
        }
        // initial Hessian scaling gamma = s'y / y'y
        let gamma = if m > 0 {
            let sy = dot(&s_hist[m - 1], &y_hist[m - 1]);
            let yy = dot(&y_hist[m - 1], &y_hist[m - 1]);
            if yy > 0.0 { (sy / yy).max(1e-12) } else { 1.0 }
        } else {
            1.0 / gnorm.max(1.0)
        };
        for v in q.iter_mut() {
            *v *= gamma;
        }
        for i in 0..m {
            let beta = rho_hist[i] * dot(&y_hist[i], &q);
            axpy(&mut q, alpha[i] - beta, &s_hist[i]);
        }
        let mut d: Vec<f64> = q.iter().map(|&v| -v).collect();

        // ensure descent direction
        let mut dg = dot(&d, &g);
        if dg >= 0.0 {
            // fall back to steepest descent
            d = g.iter().map(|&v| -v).collect();
            dg = -gnorm * gnorm;
            s_hist.clear();
            y_hist.clear();
            rho_hist.clear();
        }

        // Armijo backtracking
        let mut step = opts.step0;
        let mut x_new = vec![0.0; n];
        let mut g_new = vec![0.0; n];
        let mut f_new;
        let mut ls_ok = false;
        for _ in 0..30 {
            for i in 0..n {
                x_new[i] = x[i] + step * d[i];
            }
            f_new = f(&x_new, &mut g_new);
            if f_new.is_finite() && f_new <= fx + opts.c1 * step * dg {
                // accept
                let s_vec: Vec<f64> = (0..n).map(|i| x_new[i] - x[i]).collect();
                let y_vec: Vec<f64> = (0..n).map(|i| g_new[i] - g[i]).collect();
                let sy = dot(&s_vec, &y_vec);
                if sy > 1e-10 * norm(&s_vec) * norm(&y_vec) {
                    if s_hist.len() == opts.history {
                        s_hist.remove(0);
                        y_hist.remove(0);
                        rho_hist.remove(0);
                    }
                    rho_hist.push(1.0 / sy);
                    s_hist.push(s_vec);
                    y_hist.push(y_vec);
                }
                x.copy_from_slice(&x_new);
                g.copy_from_slice(&g_new);
                fx = f_new;
                ls_ok = true;
                break;
            }
            step *= 0.5;
        }
        if !ls_ok {
            break; // line search failed: practical convergence
        }
    }
    (x, fx, iters)
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    for i in 0..y.len() {
        y[i] += a * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_converges_exactly() {
        // f = 0.5 * sum c_i (x_i - t_i)^2
        let c = [1.0, 10.0, 100.0];
        let t = [3.0, -2.0, 0.5];
        let f = |x: &[f64], g: &mut [f64]| {
            let mut v = 0.0;
            for i in 0..3 {
                g[i] = c[i] * (x[i] - t[i]);
                v += 0.5 * c[i] * (x[i] - t[i]).powi(2);
            }
            v
        };
        let (x, fx, _) = minimize(f, &[0.0; 3], &LbfgsOpts::default());
        for i in 0..3 {
            assert!((x[i] - t[i]).abs() < 1e-5, "{x:?}");
        }
        assert!(fx < 1e-10);
    }

    #[test]
    fn rosenbrock_2d() {
        let f = |x: &[f64], g: &mut [f64]| {
            let (a, b) = (x[0], x[1]);
            g[0] = -2.0 * (1.0 - a) - 400.0 * a * (b - a * a);
            g[1] = 200.0 * (b - a * a);
            (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
        };
        let opts = LbfgsOpts { max_iters: 300, ..Default::default() };
        let (x, fx, _) = minimize(f, &[-1.2, 1.0], &opts);
        assert!(fx < 1e-8, "fx={fx} x={x:?}");
        assert!((x[0] - 1.0).abs() < 1e-3 && (x[1] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn high_dim_quadratic() {
        let n = 200;
        let f = |x: &[f64], g: &mut [f64]| {
            let mut v = 0.0;
            for i in 0..n {
                let c = 1.0 + i as f64;
                g[i] = c * x[i];
                v += 0.5 * c * x[i] * x[i];
            }
            v
        };
        let x0 = vec![1.0; n];
        let (_, fx, iters) = minimize(f, &x0, &LbfgsOpts { max_iters: 200, ..Default::default() });
        assert!(fx < 1e-8, "fx={fx} after {iters}");
    }

    #[test]
    fn handles_nonfinite_trial_points() {
        // f = -log(1 - x^2): infinite outside |x|<1; line search must backtrack
        let f = |x: &[f64], g: &mut [f64]| {
            let v = 1.0 - x[0] * x[0];
            if v <= 0.0 {
                g[0] = 0.0;
                return f64::INFINITY;
            }
            g[0] = 2.0 * x[0] / v;
            -v.ln()
        };
        let (x, fx, _) = minimize(f, &[0.9], &LbfgsOpts::default());
        assert!(x[0].abs() < 1e-3, "{x:?}");
        assert!(fx < 1e-5);
    }

    #[test]
    fn zero_gradient_terminates_immediately() {
        let f = |_: &[f64], g: &mut [f64]| {
            g.fill(0.0);
            1.0
        };
        let (_, fx, iters) = minimize(f, &[5.0, 5.0], &LbfgsOpts::default());
        assert_eq!(fx, 1.0);
        assert_eq!(iters, 1);
    }
}
