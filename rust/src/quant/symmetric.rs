//! Symmetric channel-wise quantizer (paper §2.1) over the Float8/Int8
//! base formats:  W_q = clamp(round_gamma(W / s), -Qmax, Qmax),
//! dequant  What = s * W_q,  one scale per output channel (matrix row).

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

use super::f8e4m3;
use crate::tensor::Mat;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Format {
    F8E4M3,
    Int8,
}

impl Format {
    pub fn qmax(self) -> f32 {
        match self {
            Format::F8E4M3 => f8e4m3::F8_MAX,
            Format::Int8 => 127.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::F8E4M3 => "f8e4m3",
            Format::Int8 => "int8",
        }
    }

    /// Round one already-scaled value onto the format grid (no clamp —
    /// callers clamp first; encode saturates anyway for f8).
    #[inline]
    pub fn round(self, u: f32) -> f32 {
        match self {
            Format::F8E4M3 => f8e4m3::round_f8(u),
            Format::Int8 => {
                let r = u.abs().floor() + if u.abs().fract() >= 0.5 { 1.0 } else { 0.0 };
                (r.min(127.0)) * u.signum()
            }
        }
    }

    /// Quantize one value: returns (symbol byte, grid value).
    /// Symbols are the byte alphabet fed to the ANS coder:
    ///  * f8: the e4m3fn byte itself
    ///  * i8: the two's-complement byte of the integer code
    #[inline]
    pub fn quantize(self, w: f32, scale: f32) -> (u8, f32) {
        if scale == 0.0 {
            return (0, 0.0);
        }
        let u = (w / scale).clamp(-self.qmax(), self.qmax());
        match self {
            Format::F8E4M3 => {
                let b = f8e4m3::encode(u);
                (b, f8e4m3::decode(b))
            }
            Format::Int8 => {
                let q = self.round(u);
                ((q as i32 as i8) as u8, q)
            }
        }
    }

    /// Symbol byte -> grid value.
    #[inline]
    pub fn symbol_value(self, b: u8) -> f32 {
        match self {
            Format::F8E4M3 => {
                let v = f8e4m3::decode(b);
                if v.is_nan() {
                    0.0
                } else {
                    v
                }
            }
            Format::Int8 => (b as i8) as f32,
        }
    }

    /// Precomputed 256-entry symbol->value table (decode hot path).
    pub fn value_table(self) -> [f32; 256] {
        let mut t = [0.0f32; 256];
        for (b, slot) in t.iter_mut().enumerate() {
            *slot = self.symbol_value(b as u8);
        }
        t
    }
}

/// One quantized matrix: symbol bytes + per-row scales.
#[derive(Clone, Debug)]
pub struct QMat {
    pub rows: usize,
    pub cols: usize,
    pub fmt: Format,
    pub symbols: Vec<u8>,
    pub scales: Vec<f32>,
}

impl QMat {
    /// Dequantize into the grid-value matrix actually used by inference
    /// (codes as f32; multiply by scales happens in the GEMM epilogue).
    pub fn code_values(&self) -> Mat {
        let table = self.fmt.value_table();
        Mat::from_vec(
            self.rows,
            self.cols,
            self.symbols.iter().map(|&b| table[b as usize]).collect(),
        )
    }

    /// Full dequantization: What = s * codes.
    pub fn dequantize(&self) -> Mat {
        let mut m = self.code_values();
        for r in 0..self.rows {
            let s = self.scales[r];
            for v in m.row_mut(r) {
                *v *= s;
            }
        }
        m
    }

    /// Number of distinct dequantized values (Table 1 accounting).
    pub fn unique_values(&self) -> usize {
        use std::collections::BTreeSet;
        let m = self.dequantize();
        m.data.iter().map(|v| v.to_bits()).collect::<BTreeSet<_>>().len()
    }
}

/// Paper eq. (1): AbsMax per output channel.
pub fn absmax_scales(w: &Mat, fmt: Format) -> Vec<f32> {
    (0..w.rows)
        .map(|r| {
            let m = w.row(r).iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            m / fmt.qmax()
        })
        .collect()
}

/// Quantize a full matrix with the given per-row scales.
pub fn quantize(w: &Mat, scales: &[f32], fmt: Format) -> QMat {
    assert_eq!(scales.len(), w.rows);
    let mut symbols = Vec::with_capacity(w.rows * w.cols);
    for r in 0..w.rows {
        let s = scales[r];
        for &x in w.row(r) {
            symbols.push(fmt.quantize(x, s).0);
        }
    }
    QMat { rows: w.rows, cols: w.cols, fmt, symbols, scales: scales.to_vec() }
}

/// Relative entry-wise l1 distortion d(W, What) (paper §2.2).
pub fn rel_l1_distortion(w: &Mat, what: &Mat) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..w.data.len() {
        num += (w.data[i] - what.data[i]).abs() as f64;
        den += w.data[i].abs() as f64;
    }
    num / (den + 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.normal() * rng.normal().exp()) as f32)
            .collect();
        Mat::from_vec(rows, cols, data)
    }

    #[test]
    fn absmax_uses_full_range() {
        let w = randmat(8, 32, 1);
        for fmt in [Format::F8E4M3, Format::Int8] {
            let s = absmax_scales(&w, fmt);
            let q = quantize(&w, &s, fmt);
            let codes = q.code_values();
            let maxcode = codes.abs_max();
            assert!((maxcode - fmt.qmax()).abs() / fmt.qmax() < 0.1, "{fmt:?} {maxcode}");
        }
    }

    #[test]
    fn absmax_distortion_small() {
        let w = randmat(16, 64, 2);
        for (fmt, tol) in [(Format::F8E4M3, 0.05), (Format::Int8, 0.05)] {
            let s = absmax_scales(&w, fmt);
            let q = quantize(&w, &s, fmt);
            let d = rel_l1_distortion(&w, &q.dequantize());
            assert!(d < tol, "{fmt:?} d={d}");
        }
    }

    #[test]
    fn zero_scale_rows_are_zero() {
        let w = randmat(2, 8, 3);
        let q = quantize(&w, &[0.0, 1.0], Format::F8E4M3);
        assert!(q.dequantize().row(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn i8_symbols_roundtrip() {
        for q in -127i32..=127 {
            let b = (q as i8) as u8;
            assert_eq!(Format::Int8.symbol_value(b), q as f32);
        }
    }

    #[test]
    fn i8_round_half_away_from_zero() {
        assert_eq!(Format::Int8.round(0.5), 1.0);
        assert_eq!(Format::Int8.round(-0.5), -1.0);
        assert_eq!(Format::Int8.round(1.49), 1.0);
        assert_eq!(Format::Int8.round(-2.5), -3.0);
    }

    #[test]
    fn f8_symbols_match_codec() {
        let w = randmat(4, 16, 4);
        let s = absmax_scales(&w, Format::F8E4M3);
        let q = quantize(&w, &s, Format::F8E4M3);
        for r in 0..4 {
            for c in 0..16 {
                let (b, v) = Format::F8E4M3.quantize(w.at(r, c), s[r]);
                assert_eq!(q.symbols[r * 16 + c], b);
                assert_eq!(q.code_values().at(r, c), v);
            }
        }
    }

    #[test]
    fn unique_values_bounded_by_grid() {
        let w = randmat(32, 64, 5);
        let s = absmax_scales(&w, Format::F8E4M3);
        let q = quantize(&w, &s, Format::F8E4M3);
        // dequantized uniques can exceed 253 because scales differ per row
        assert!(q.unique_values() > 100);
        let codes = q.code_values();
        use std::collections::BTreeSet;
        let uc: BTreeSet<u32> = codes.data.iter().map(|v| v.to_bits()).collect();
        assert!(uc.len() <= 253);
    }

    #[test]
    fn matches_python_fakequant_fixture() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/fixtures/fakequant.json");
        let Ok(text) = std::fs::read_to_string(path) else {
            eprintln!("fixture missing; run `make artifacts` (skipping)");
            return;
        };
        let v = crate::store::json::parse(&text).unwrap();
        let wrows = v.get("w").unwrap().as_array().unwrap();
        let rows = wrows.len();
        let cols = wrows[0].as_array().unwrap().len();
        let data: Vec<f32> = wrows
            .iter()
            .flat_map(|r| r.f64_array().unwrap())
            .map(|x| x as f32)
            .collect();
        let w = Mat::from_vec(rows, cols, data);
        for (fmt, key) in [(Format::F8E4M3, "f8"), (Format::Int8, "i8")] {
            let s: Vec<f32> = v.get(&format!("s_{key}")).unwrap().f64_array().unwrap()
                .into_iter().map(|x| x as f32).collect();
            let want_codes: Vec<f32> = v.get(&format!("codes_{key}")).unwrap()
                .as_array().unwrap().iter()
                .flat_map(|r| r.f64_array().unwrap()).map(|x| x as f32).collect();
            let want_what: Vec<f32> = v.get(&format!("what_{key}")).unwrap()
                .as_array().unwrap().iter()
                .flat_map(|r| r.f64_array().unwrap()).map(|x| x as f32).collect();
            let q = quantize(&w, &s, fmt);
            let codes = q.code_values();
            let what = q.dequantize();
            for i in 0..rows * cols {
                assert_eq!(codes.data[i], want_codes[i], "{fmt:?} code {i}");
                assert!((what.data[i] - want_what[i]).abs() <= 1e-6 * want_what[i].abs().max(1.0),
                        "{fmt:?} what {i}: {} vs {}", what.data[i], want_what[i]);
            }
        }
    }
}
