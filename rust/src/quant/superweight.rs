//! Super-weight detection (Yu et al. 2024, paper §3.5 / §A.2).
//!
//! A handful of outlier weights — concentrated in early down-projection
//! layers — produce activation spikes whose destruction collapses the
//! model.  Detection: one forward pass on a dummy prompt, recording the
//! maximum |activation| entering each block's w_down; blocks whose spike
//! exceeds a per-family threshold are *excluded* from the entropy
//! optimization (they are still 8-bit quantized + ANS coded, ~6.5 bits).

// Index loops here mirror the JAX/Pallas reference kernel layouts (see the
// lint-posture note in Cargo.toml).
#![allow(clippy::needless_range_loop)]

use crate::model::{Forward, Model};

#[derive(Clone, Debug, Default)]
pub struct SuperWeightReport {
    /// max |mlp hidden| per block
    pub activation_maxima: Vec<f32>,
    /// block indices whose down-projection is excluded
    pub excluded_blocks: Vec<usize>,
    pub threshold: f32,
}

/// Probe with a dummy prompt (paper A.2 uses a single CPU forward).
pub fn detect(model: &Model, threshold: f32) -> SuperWeightReport {
    let vocab = model.config.vocab;
    let prompt: Vec<u8> = b"the quick brown fox jumps over the lazy dog . 1 + 2 = 3 ."
        .iter()
        .map(|&b| if (b as usize) < vocab { b } else { (b as usize % vocab) as u8 })
        .collect();
    let f = Forward::new(model);
    let maxima = f.down_proj_activation_maxima(&prompt);
    let excluded: Vec<usize> = maxima
        .iter()
        .enumerate()
        .filter(|(_, &m)| m > threshold)
        .map(|(i, _)| i)
        .collect();
    SuperWeightReport { activation_maxima: maxima, excluded_blocks: excluded, threshold }
}

/// Artificially plant a super weight (ablation harness for Figure 6 /
/// Table G.1): scale one w_down entry of an early block so its hidden
/// activation spikes, mimicking the LLaMA-style outlier.
pub fn plant_super_weight(model: &mut Model, block: usize, magnitude: f32) {
    let wd = &mut model.blocks[block].w_down;
    // largest-magnitude entry gets boosted
    let mut best = 0usize;
    for i in 0..wd.data.len() {
        if wd.data[i].abs() > wd.data[best].abs() {
            best = i;
        }
    }
    wd.data[best] *= magnitude;
    // also boost the corresponding up-projection row so the *hidden*
    // activation feeding this weight spikes (what the detector probes)
    let col = best % wd.cols; // hidden index feeding this weight
    let wu = &mut model.blocks[block].w_up;
    for c in 0..wu.cols {
        *wu.at_mut(col, c) *= magnitude;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loader::synthetic_model;
    use crate::model::Config;

    fn tiny() -> Model {
        synthetic_model(
            Config { name: "T".into(), vocab: 128, d_model: 16, n_layers: 3, n_heads: 2, d_ff: 24, max_ctx: 64 },
            3,
        )
    }

    #[test]
    fn clean_model_has_no_superweights_at_high_threshold() {
        let m = tiny();
        let rep = detect(&m, 1e6);
        assert!(rep.excluded_blocks.is_empty());
        assert_eq!(rep.activation_maxima.len(), 3);
    }

    #[test]
    fn planted_superweight_is_detected() {
        let mut m = tiny();
        let base = detect(&m, f32::INFINITY);
        plant_super_weight(&mut m, 1, 400.0);
        let rep = detect(&m, base.activation_maxima[1] * 5.0);
        assert!(
            rep.excluded_blocks.contains(&1),
            "maxima before {:?} after {:?}",
            base.activation_maxima,
            rep.activation_maxima
        );
    }

    #[test]
    fn threshold_infinity_excludes_nothing() {
        let mut m = tiny();
        plant_super_weight(&mut m, 0, 100.0);
        let rep = detect(&m, f32::INFINITY);
        assert!(rep.excluded_blocks.is_empty());
    }
}
