//! From-scratch Float8 E4M3FN codec (the paper's default base format).
//!
//! Layout: 1 sign / 4 exponent (bias 7) / 3 mantissa.  "FN" = finite +
//! NaN only: there are no infinities; `S.1111.111` is NaN and the
//! largest finite magnitude is `S.1111.110` = 448.  Denormals use
//! absolute spacing 2^-9 — this uniform bottom region is what makes the
//! EntQuant entropy optimization work: large scales park most weights on
//! a handful of denormal levels (+ zero) while outliers keep the full
//! log-range.  Signed zero is resolved to +0 on encode (paper §A.1).

/// NaN byte pattern (positive variant).
pub const NAN_BYTE: u8 = 0x7F;
/// Largest finite magnitude.
pub const F8_MAX: f32 = 448.0;

/// Decode one e4m3fn byte to f32.  NaN patterns map to f32::NAN.
#[inline]
pub fn decode(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = (b >> 3) & 0xF;
    let m = (b & 7) as f32;
    if e == 15 && b & 7 == 7 {
        return f32::NAN;
    }
    let mag = if e == 0 {
        // denormal: m * 2^-9
        m * (1.0 / 512.0)
    } else {
        // normal: (8 + m) * 2^(e - 10)
        (8.0 + m) * 2.0f32.powi(e as i32 - 10)
    };
    sign * mag
}

/// The 121 distinct non-negative finite values, ascending (0x00..=0x7E).
fn positive_grid() -> &'static [f32; 127] {
    use std::sync::OnceLock;
    static GRID: OnceLock<[f32; 127]> = OnceLock::new();
    GRID.get_or_init(|| {
        let mut g = [0.0f32; 127];
        for (i, slot) in g.iter_mut().enumerate() {
            *slot = decode(i as u8);
        }
        g
    })
}

/// Encode f32 to the nearest e4m3fn byte: round-to-nearest-even in value
/// space, saturating at +-448, signed zero resolved to +0, NaN -> 0x7F.
pub fn encode(x: f32) -> u8 {
    if x.is_nan() {
        return NAN_BYTE;
    }
    let neg = x < 0.0;
    let a = x.abs().min(F8_MAX);
    let grid = positive_grid();
    // binary search for the first grid value >= a
    let mut lo = 0usize;
    let mut hi = 126usize;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if grid[mid] < a {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    let code = if lo == 0 {
        0
    } else {
        let below = grid[lo - 1];
        let above = grid[lo];
        let d_lo = a - below;
        let d_hi = above - a;
        if d_lo < d_hi {
            lo - 1
        } else if d_hi < d_lo {
            lo
        } else {
            // tie: pick even mantissa (round-to-nearest-even)
            if (lo - 1) & 1 == 0 {
                lo - 1
            } else {
                lo
            }
        }
    } as u8;
    if code == 0 {
        0 // resolve signed zero
    } else if neg {
        code | 0x80
    } else {
        code
    }
}

/// Quantize-dequantize onto the f8 grid (the rust-native `round_f8`).
#[inline]
pub fn round_f8(x: f32) -> f32 {
    decode(encode(x))
}

/// All finite representable values, including negatives (for tests and
/// the unique-value accounting of Table 1).
pub fn finite_values() -> Vec<f32> {
    (0u16..=255)
        .map(|b| decode(b as u8))
        .filter(|v| v.is_finite())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_monotone_ascending() {
        let g = positive_grid();
        for i in 1..127 {
            assert!(g[i] > g[i - 1], "grid not strictly ascending at {i}");
        }
        assert_eq!(g[0], 0.0);
        assert_eq!(g[126], 448.0);
    }

    #[test]
    fn denormal_spacing_is_uniform() {
        for m in 0..8u8 {
            assert_eq!(decode(m), m as f32 / 512.0);
        }
    }

    #[test]
    fn roundtrip_every_finite_byte() {
        for b in 0u16..=255 {
            let b = b as u8;
            let v = decode(b);
            if v.is_nan() {
                continue;
            }
            let b2 = encode(v);
            // signed zero is resolved: -0 encodes as +0
            if b == 0x80 {
                assert_eq!(b2, 0x00);
            } else {
                assert_eq!(b2, b, "byte {b:#x} value {v}");
            }
        }
    }

    #[test]
    fn saturates_beyond_max() {
        assert_eq!(decode(encode(1e9)), 448.0);
        assert_eq!(decode(encode(-1e9)), -448.0);
        assert_eq!(decode(encode(500.0)), 448.0);
    }

    #[test]
    fn nan_handling() {
        assert_eq!(encode(f32::NAN), NAN_BYTE);
        assert!(decode(NAN_BYTE).is_nan());
        assert!(decode(0xFF).is_nan());
    }

    #[test]
    fn round_to_nearest_even_at_ties() {
        // between 8+m spacing: e.g. between 16 (0b0_1011_000 -> 16) and 18:
        // values 16,18,20,... step 2 in [16,32) binade; tie at 17 -> 16 (even mantissa)
        assert_eq!(round_f8(17.0), 16.0);
        // tie at 19 -> 20 (mantissa 1 is odd, next is 2 even)
        assert_eq!(round_f8(19.0), 20.0);
    }

    #[test]
    fn nearest_not_floor() {
        // 15.9 is closer to 16 than to 15
        assert_eq!(round_f8(15.9), 16.0);
        assert_eq!(round_f8(15.4), 15.0);
    }

    #[test]
    fn signed_zero_resolved() {
        assert_eq!(encode(-0.0), 0u8);
        assert_eq!(encode(0.0), 0u8);
    }

    #[test]
    fn matches_mldtypes_grid_fixture() {
        // artifacts/fixtures/f8_grid.json is ml_dtypes' float8_e4m3fn view
        // of all byte patterns — the authoritative oracle.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/fixtures/f8_grid.json");
        let Ok(text) = std::fs::read_to_string(path) else {
            eprintln!("fixture missing; run `make artifacts` (skipping)");
            return;
        };
        let vals = crate::store::json::parse(&text).unwrap();
        let arr = vals.as_array().unwrap();
        assert_eq!(arr.len(), 256);
        for (b, v) in arr.iter().enumerate() {
            let got = decode(b as u8);
            match v.as_f64() {
                None => assert!(got.is_nan(), "byte {b} should be NaN"),
                Some(want) => {
                    assert_eq!(got, want as f32, "byte {b:#x}");
                }
            }
        }
    }

    #[test]
    fn unique_finite_value_count() {
        use std::collections::BTreeSet;
        let set: BTreeSet<u32> = finite_values().iter().map(|v| v.to_bits()).collect();
        // 254 finite byte patterns, two zeros collapse to... two distinct
        // bit patterns (+0/-0) but equal values; count distinct values:
        let mut vals: Vec<f32> = finite_values();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        assert_eq!(vals.len(), 253); // 126 pos + 126 neg + zero
        assert!(set.len() >= 253);
    }
}
