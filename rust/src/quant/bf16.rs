//! Minimal BFloat16 codec — the storage format of the channel scales
//! (paper §2.2: "the storage overhead of the high-precision (BFloat16)
//! scales is negligible").  Scales are rounded to BF16 *before* the
//! final quantization pass so the stored scales are bit-exact with the
//! ones the codes were produced under.

/// Round f32 to the nearest BF16 (round-to-nearest-even on the dropped
/// 16 mantissa bits) and return the f32 the stored BF16 decodes to.
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    decode(encode(x))
}

/// f32 -> bf16 bits (RNE).
#[inline]
pub fn encode(x: f32) -> u16 {
    let bits = x.to_bits();
    // RNE: add 0x7FFF + lsb of the kept part
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb);
    if x.is_nan() {
        return 0x7FC0; // canonical NaN
    }
    (rounded >> 16) as u16
}

/// bf16 bits -> f32.
#[inline]
pub fn decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

pub fn round_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round_bf16(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn exact_on_bf16_grid() {
        for b in [0u16, 0x3F80 /*1.0*/, 0xBF80 /*-1.0*/, 0x4000 /*2.0*/] {
            let v = decode(b);
            assert_eq!(encode(v), b);
            assert_eq!(round_bf16(v), v);
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let x = (rng.normal() * (rng.normal() * 4.0).exp()) as f32;
            if x == 0.0 {
                continue;
            }
            let r = round_bf16(x);
            // bf16 has 8 mantissa bits -> rel err <= 2^-8
            assert!(((r - x) / x).abs() <= 1.0 / 256.0 + 1e-7, "{x} -> {r}");
        }
    }

    #[test]
    fn rne_ties() {
        // 1 + 2^-8 is exactly between 1.0 and the next bf16; RNE keeps even
        let x = f32::from_bits(0x3F80_8000);
        let r = round_bf16(x);
        assert_eq!(r, 1.0, "{r}");
        // and the next tie rounds up to even
        let y = f32::from_bits(0x3F81_8000);
        assert_eq!(encode(y), 0x3F82);
    }

    #[test]
    fn specials() {
        assert_eq!(round_bf16(0.0), 0.0);
        assert_eq!(round_bf16(-0.0), -0.0);
        assert!(round_bf16(f32::NAN).is_nan());
        assert_eq!(round_bf16(f32::INFINITY), f32::INFINITY);
    }
}
