//! Quantization substrate: the Float8 E4M3 codec, the symmetric
//! channel-wise quantizer over Float8/Int8, and super-weight detection.

pub mod bf16;
pub mod f8e4m3;
pub mod superweight;
pub mod symmetric;

pub use symmetric::{absmax_scales, quantize, rel_l1_distortion, Format, QMat};
