//! The serve subsystem — a sharded, continuously-batched serving
//! frontend over the one-shot `coordinator` engine, in three pieces:
//!
//! * **`shard`** — `ShardPlan` splits a `CompressedModel`'s blocks into
//!   contiguous ranges balanced by compressed byte size;
//!   `ShardedEngine` gives each range its own `ServingEngine` (own
//!   `Runtime`, `parallel::Pool`, `DecodeArena`) and pipelines
//!   activations shard-to-shard, embed on the first and LM head on the
//!   last.  Any shard count is byte-identical to the monolithic engine.
//! * **`scheduler`** — a multi-tenant admission queue with a
//!   submit/poll/cancel lifecycle and continuous batching: a long-lived
//!   `parallel::Service` driver retires lanes at their
//!   `max_new_tokens` deadlines, grafts queued requests into free lanes
//!   between decode steps (solo prefill + catch-up, then
//!   `DecodeState::adopt_lane`), and re-slots the batch through the
//!   `batcher` tables as occupancy changes — FCFS throughout.
//! * **`metrics`** — queue depth, lifecycle tallies, time-to-first-
//!   token, token throughput and per-shard decode-arena gauges,
//!   snapshotted lock-free from any thread.
//!
//! The split mirrors the serving designs in Heilper & Singer 2025 and
//! Mao et al. 2024: decode-on-demand weights partitioned across
//! workers behind a continuous admission queue.  Everything here is
//! engine-agnostic via `StepEngine`, so the scheduler drives one
//! engine or a shard pipeline identically — and, through the native
//! executor, the whole stack runs end-to-end in CI.
//!
//! The stack is **fault-tolerant**: a shard failure mid-batch reroutes
//! that shard's block range onto survivors (`StepEngine::try_recover`)
//! and the scheduler replays the interrupted decode step, so in-flight
//! requests still complete byte-identically; `runtime::fault` injects
//! deterministic failures to prove it in CI (`rust/tests/serve.rs`).

pub mod metrics;
pub mod scheduler;
pub mod shard;

pub use metrics::{MetricsSnapshot, ServeMetrics};
pub use scheduler::{Scheduler, SchedulerOpts, Status};
pub use shard::{ShardPlan, ShardedEngine};

use crate::coordinator::engine::DecodeState;
use crate::coordinator::{Batch, ServingEngine};
use anyhow::Result;

/// The step-wise engine surface the scheduler drives: prefill a batch
/// into a `DecodeState`, then advance it one token at a time so
/// admission can interleave between steps.  Implemented by the single
/// `ServingEngine` and the `ShardedEngine` pipeline.
pub trait StepEngine: Send {
    fn prefill_state(&self, batch: &Batch) -> Result<DecodeState>;
    /// One decode step; `false` (without stepping) once the decode
    /// context is exhausted.  Implementations must be **resumable**: a
    /// step that returned `Err` partway may be replayed on the same
    /// state and complete byte-identically (both engines guarantee
    /// this; see `ServingEngine::decode_step`).
    fn decode_step(&self, st: &mut DecodeState) -> Result<bool>;
    fn prefill_slots(&self) -> Vec<(usize, usize)>;
    fn decode_slots(&self) -> Vec<(usize, usize)>;
    /// Decode-arena fresh allocations per shard (one entry per shard; 0
    /// each in steady state).
    fn fresh_allocs_per_shard(&self) -> Vec<usize>;

    fn n_shards(&self) -> usize {
        self.fresh_allocs_per_shard().len()
    }

    /// Attempt recovery after a `prefill_state`/`decode_step` error —
    /// e.g. reroute a failed shard's block range onto survivors.
    /// `true` means the engine recovered and the caller should replay
    /// the interrupted operation; the default (a single engine has no
    /// spare capacity to reroute to) is unrecoverable.
    fn try_recover(&self) -> bool {
        false
    }
}

impl StepEngine for ServingEngine {
    fn prefill_state(&self, batch: &Batch) -> Result<DecodeState> {
        ServingEngine::prefill_state(self, batch)
    }

    fn decode_step(&self, st: &mut DecodeState) -> Result<bool> {
        ServingEngine::decode_step(self, st)
    }

    fn prefill_slots(&self) -> Vec<(usize, usize)> {
        self.runtime().manifest.prefill_slots.clone()
    }

    fn decode_slots(&self) -> Vec<(usize, usize)> {
        self.runtime().manifest.decode_slots.clone()
    }

    fn fresh_allocs_per_shard(&self) -> Vec<usize> {
        vec![self.decode_arena_fresh_allocs()]
    }
}

impl StepEngine for ShardedEngine {
    fn prefill_state(&self, batch: &Batch) -> Result<DecodeState> {
        ShardedEngine::prefill_state(self, batch)
    }

    fn decode_step(&self, st: &mut DecodeState) -> Result<bool> {
        ShardedEngine::decode_step(self, st)
    }

    fn prefill_slots(&self) -> Vec<(usize, usize)> {
        ShardedEngine::prefill_slots(self)
    }

    fn decode_slots(&self) -> Vec<(usize, usize)> {
        ShardedEngine::decode_slots(self)
    }

    fn fresh_allocs_per_shard(&self) -> Vec<usize> {
        self.fresh_allocs()
    }

    fn try_recover(&self) -> bool {
        ShardedEngine::try_recover(self)
    }
}
