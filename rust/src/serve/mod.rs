//! The serve subsystem — a sharded, continuously-batched serving
//! frontend over the one-shot `coordinator` engine:
//!
//! * **`shard`** — `ShardPlan` splits a `CompressedModel`'s blocks into
//!   contiguous ranges balanced by compressed byte size;
//!   `ShardedEngine` gives each range its own `ServingEngine` (own
//!   `Runtime`, `parallel::Pool`, `DecodeArena`) and pipelines
//!   activations shard-to-shard, embed on the first and LM head on the
//!   last.  Any shard count is byte-identical to the monolithic engine.
//! * **`scheduler`** — a multi-tenant admission queue with a
//!   submit/poll/cancel lifecycle and continuous batching: a long-lived
//!   `parallel::Service` driver retires lanes at their
//!   `max_new_tokens` deadlines, grafts queued requests into free lanes
//!   between decode steps (solo prefill + catch-up, then
//!   `DecodeState::adopt_lane`), and re-slots the batch through the
//!   `batcher` tables as occupancy changes — FCFS throughout.
//! * **`admission`** — the bounded front door: queue-depth and
//!   inflight-token caps turn `submit` into `Admitted | Shed` with a
//!   deterministic, decode-step-denominated retry hint, plus
//!   degradation tiers keyed off shard health.
//! * **`supervisor`** — the self-healing wrapper: per-shard
//!   consecutive-failure eviction, a spare-`Runtime` pool, and
//!   tick-counted (seeded-jitter) backoff between rejoin attempts.
//! * **`metrics`** — queue depth, lifecycle tallies, token throughput,
//!   health/eviction/backoff gauges, per-shard decode-arena gauges, and
//!   `obs::Log2Hist` latency distributions (ttft, queue wait, per-step,
//!   recovery stall), snapshotted lock-free from any thread.
//!
//! The whole stack is traced: the scheduler owns an `obs::Tracer` and
//! hands it to the engine via `StepEngine::set_tracer`, so request
//! lifecycle events (scheduler-side) and shard lifecycle events
//! (engine-side) interleave in one tick-stamped ring, exportable as
//! JSONL or Chrome trace-event JSON (`serve --trace-out`, the
//! `serve-stdio` `TRACE` command).
//!
//! The split mirrors the serving designs in Heilper & Singer 2025 and
//! Mao et al. 2024: decode-on-demand weights partitioned across
//! workers behind a continuous admission queue.  Everything here is
//! engine-agnostic via `StepEngine`, so the scheduler drives one
//! engine or a shard pipeline identically — and, through the native
//! executor, the whole stack runs end-to-end in CI.
//!
//! The stack is **fault-tolerant and elastic**: a shard failure
//! mid-batch reroutes that shard's block range onto survivors
//! (`StepEngine::try_recover` — an incremental splice that decodes
//! only the absorbed range) and the scheduler replays the interrupted
//! decode step, so in-flight requests still complete byte-identically;
//! a provisioned replacement later re-splits the merged range back out
//! (`StepEngine::try_rejoin`, polled between decode steps).
//! `runtime::fault` injects deterministic failures to prove all of it
//! in CI (`rust/tests/serve.rs`).
//!
//! Weight memory is **shared, not multiplied**: `CompressedModel` is
//! Arc-backed, so shard slices, the retained reroute container, and
//! splice merges reference one allocation per block — the
//! `weight_copies` / `resident_compressed_bytes` gauges pin exactly
//! one logical copy at any shard count.  The scheduler driver sweeps
//! these gauges at startup and after every successful reroute/rejoin
//! (the only events that can move them) — a new topology-mutating
//! path must refresh them itself.

pub mod admission;
pub mod metrics;
pub mod scheduler;
pub mod shard;
pub mod supervisor;

pub use admission::{Admission, AdmissionOpts, ShedReason};
pub use metrics::{MetricsSnapshot, ServeMetrics};
pub use scheduler::{Scheduler, SchedulerOpts, Status};
pub use shard::{ShardPlan, ShardedEngine};
pub use supervisor::{ShardHealth, Supervisor, SupervisorOpts};

use crate::coordinator::engine::DecodeState;
use crate::coordinator::{Batch, ServingEngine};
use crate::obs::Tracer;
use anyhow::Result;
use std::sync::Arc;

/// The step-wise engine surface the scheduler drives: prefill a batch
/// into a `DecodeState`, then advance it one token at a time so
/// admission can interleave between steps.  Implemented by the single
/// `ServingEngine` and the `ShardedEngine` pipeline.
pub trait StepEngine: Send {
    fn prefill_state(&self, batch: &Batch) -> Result<DecodeState>;
    /// One decode step; `false` (without stepping) once the decode
    /// context is exhausted.  Implementations must be **resumable**: a
    /// step that returned `Err` partway may be replayed on the same
    /// state and complete byte-identically (both engines guarantee
    /// this; see `ServingEngine::decode_step`).
    fn decode_step(&self, st: &mut DecodeState) -> Result<bool>;
    fn prefill_slots(&self) -> Vec<(usize, usize)>;
    fn decode_slots(&self) -> Vec<(usize, usize)>;
    /// Fresh allocations forced on the steady-state decode hot path,
    /// per shard: decode arena plus packed-KV materialization ring
    /// (one entry per shard; 0 each in steady state).
    fn fresh_allocs_per_shard(&self) -> Vec<usize>;

    /// Allocation-free variant of `fresh_allocs_per_shard`: overwrite
    /// `out` with one entry per shard.  The scheduler driver calls this
    /// every tick with a reused scratch buffer, so steady-state ticks
    /// stay allocation-free; engines should override the default (which
    /// falls back to the allocating form).
    fn fresh_allocs_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.fresh_allocs_per_shard());
    }

    /// Install the scheduler's tracer so engine-side lifecycle events
    /// (shard faults, reroutes, splices, rejoins, evictions) land in
    /// the same tick-stamped ring as the scheduler's request events.
    /// The default — a plain engine with no shard lifecycle — records
    /// nothing and ignores the tracer.
    fn set_tracer(&self, _tracer: &Arc<Tracer>) {}

    fn n_shards(&self) -> usize {
        self.fresh_allocs_per_shard().len()
    }

    /// Attempt recovery after a `prefill_state`/`decode_step` error —
    /// e.g. reroute a failed shard's block range onto survivors.
    /// `true` means the engine recovered and the caller should replay
    /// the interrupted operation; the default (a single engine has no
    /// spare capacity to reroute to) is unrecoverable.
    fn try_recover(&self) -> bool {
        false
    }

    /// Expand a contracted topology (a provisioned replacement shard
    /// re-splits a merged range).  Polled by the scheduler driver
    /// between decode steps; the default has nothing to expand.
    fn try_rejoin(&self) -> bool {
        false
    }

    /// `try_rejoin` for a moment the caller knows the engine is idle
    /// (no in-flight batch, nothing queued): any post-reroute pacing
    /// delay is waived, since an idle rejoin stalls nobody.
    fn try_rejoin_idle(&self) -> bool {
        self.try_rejoin()
    }

    /// Max distinct storage copies of any compressed block across the
    /// engine's containers/slices — exactly 1 under Arc-backed sharing
    /// (the invariant the serve tests pin).
    fn weight_copies(&self) -> usize {
        1
    }

    /// Resident compressed bytes, deduplicated by storage.
    fn resident_compressed_bytes(&self) -> usize {
        0
    }

    /// Blocks spliced into survivors by reroutes so far.
    fn spliced_blocks(&self) -> usize {
        0
    }

    /// Shard health as `(healthy, degraded, evicted)` counts, swept by
    /// the scheduler driver every tick into `serve::metrics` and the
    /// admission controller (degradation tiers key off `healthy`).
    /// The default — no health tracking — reports every shard healthy.
    fn shard_health(&self) -> (usize, usize, usize) {
        (self.n_shards(), 0, 0)
    }

    /// Rejoin attempts that failed and were backoff-rescheduled so far
    /// (the supervisor's retry counter, exported as a metric).
    fn backoff_retries(&self) -> usize {
        0
    }
}

impl StepEngine for ServingEngine {
    fn prefill_state(&self, batch: &Batch) -> Result<DecodeState> {
        ServingEngine::prefill_state(self, batch)
    }

    fn decode_step(&self, st: &mut DecodeState) -> Result<bool> {
        ServingEngine::decode_step(self, st)
    }

    fn prefill_slots(&self) -> Vec<(usize, usize)> {
        self.runtime().manifest.prefill_slots.clone()
    }

    fn decode_slots(&self) -> Vec<(usize, usize)> {
        self.runtime().manifest.decode_slots.clone()
    }

    fn fresh_allocs_per_shard(&self) -> Vec<usize> {
        vec![self.decode_arena_fresh_allocs() + self.kv_fresh_allocs()]
    }

    fn fresh_allocs_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.push(self.decode_arena_fresh_allocs() + self.kv_fresh_allocs());
    }

    fn resident_compressed_bytes(&self) -> usize {
        self.compressed().compressed_stream_bytes()
    }

    fn spliced_blocks(&self) -> usize {
        ServingEngine::spliced_blocks(self)
    }
}

impl StepEngine for ShardedEngine {
    fn prefill_state(&self, batch: &Batch) -> Result<DecodeState> {
        ShardedEngine::prefill_state(self, batch)
    }

    fn decode_step(&self, st: &mut DecodeState) -> Result<bool> {
        ShardedEngine::decode_step(self, st)
    }

    fn prefill_slots(&self) -> Vec<(usize, usize)> {
        ShardedEngine::prefill_slots(self)
    }

    fn decode_slots(&self) -> Vec<(usize, usize)> {
        ShardedEngine::decode_slots(self)
    }

    fn fresh_allocs_per_shard(&self) -> Vec<usize> {
        self.fresh_allocs()
    }

    fn fresh_allocs_into(&self, out: &mut Vec<usize>) {
        ShardedEngine::fresh_allocs_into(self, out)
    }

    fn set_tracer(&self, tracer: &Arc<Tracer>) {
        ShardedEngine::set_tracer(self, tracer)
    }

    fn try_recover(&self) -> bool {
        ShardedEngine::try_recover(self)
    }

    fn try_rejoin(&self) -> bool {
        ShardedEngine::try_rejoin(self)
    }

    fn try_rejoin_idle(&self) -> bool {
        ShardedEngine::try_rejoin_idle(self)
    }

    fn weight_copies(&self) -> usize {
        ShardedEngine::weight_copies(self)
    }

    fn resident_compressed_bytes(&self) -> usize {
        ShardedEngine::resident_compressed_bytes(self)
    }

    fn spliced_blocks(&self) -> usize {
        ShardedEngine::spliced_blocks(self)
    }
}
