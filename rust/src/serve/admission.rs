//! Admission control & backpressure — the bounded front door of the
//! scheduler.
//!
//! `Scheduler::submit` no longer accepts unconditionally: every
//! submission passes through an `AdmissionCtl` that enforces a bounded
//! queue (`max_queue_depth`), a committed-work budget
//! (`max_inflight_tokens`: the sum of `max_new` over every
//! non-terminal request), and the degradation policy (once reroutes
//! leave fewer than `min_healthy_shards` healthy shards, new
//! admissions are shed before anything else is sacrificed).  A refused
//! submission returns `Admission::Shed { retry_after_steps }` — a
//! deterministic hint derived from the *observed* queue drain rate
//! (completed requests per decode step), denominated in decode steps,
//! never wall time, so a client replaying the same trace gets the same
//! hints.
//!
//! Degradation tiers (`tier` = healthy-shard deficit):
//!
//! * tier 0 — healthy: admit normally.
//! * tier 1 — below `min_healthy_shards`: shed every new admission;
//!   in-flight and queued requests keep their capacity.
//! * tier ≥ 2 — deeper deficit: additionally shrink the max batch (the
//!   driver stops upsizing and halves fresh-batch groups), trading
//!   throughput for per-step latency on the survivors.
//!
//! Everything here is Relaxed atomics: each knob/counter is an
//! independent bound checked opportunistically at submit time; no
//! cross-variable ordering invariant exists (the queue lock, held by
//! the caller across the decision, is what makes depth checks exact).

// entlint: allow-file(ordering-audit) — independent admission counters and
// gauges; the submit-side queue lock provides the only ordering that matters
use std::sync::atomic::{AtomicUsize, Ordering};

/// The outcome of a `submit`: either a request id to `poll`/`wait` on,
/// or a shed with a deterministic retry hint in decode steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Admitted(u64),
    Shed { retry_after_steps: usize },
}

impl Admission {
    /// The admitted request id, `None` when shed.
    pub fn id(&self) -> Option<u64> {
        match self {
            Admission::Admitted(id) => Some(*id),
            Admission::Shed { .. } => None,
        }
    }

    /// The shed retry hint, `None` when admitted.
    pub fn retry_after(&self) -> Option<usize> {
        match self {
            Admission::Admitted(_) => None,
            Admission::Shed { retry_after_steps } => Some(*retry_after_steps),
        }
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, Admission::Shed { .. })
    }

    /// Unwrap the id; panics on a shed (tests and trusting callers).
    pub fn expect_admitted(self) -> u64 {
        match self {
            Admission::Admitted(id) => id,
            Admission::Shed { retry_after_steps } => {
                panic!("request shed (retry after {retry_after_steps} steps)")
            }
        }
    }
}

/// Why a submission was shed — carried on the `shed` trace event so an
/// operator reading a Chrome trace can tell backpressure (queue/token
/// bounds) apart from degradation (shard deficit) without correlating
/// against supervisor logs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ShedReason {
    /// Healthy-shard deficit put the controller at tier >= 1.
    Degraded = 0,
    /// The bounded queue is at `max_queue_depth`.
    QueueFull = 1,
    /// Admitting would push the committed-token ledger past
    /// `max_inflight_tokens`.
    TokenBudget = 2,
}

impl ShedReason {
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::Degraded => "degraded",
            ShedReason::QueueFull => "queue_full",
            ShedReason::TokenBudget => "token_budget",
        }
    }
}

/// The admission knobs, split out of `SchedulerOpts` so the controller
/// is testable without a scheduler.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionOpts {
    /// Queue-depth bound: submissions beyond it are shed.  The default
    /// (`usize::MAX`) preserves the historical unbounded queue.
    pub max_queue_depth: usize,
    /// Committed-work bound: the sum of `max_new` over every
    /// non-terminal request may not exceed this.
    pub max_inflight_tokens: usize,
    /// Degradation threshold: with fewer healthy shards than this, new
    /// admissions are shed (tier 1); two or more below, the driver also
    /// shrinks the max batch (tier 2).  0 disables degradation.
    pub min_healthy_shards: usize,
}

impl Default for AdmissionOpts {
    fn default() -> Self {
        AdmissionOpts {
            max_queue_depth: usize::MAX,
            max_inflight_tokens: usize::MAX,
            min_healthy_shards: 0,
        }
    }
}

/// The shared admission state: bounds from `AdmissionOpts`, the
/// committed-token ledger, and the driver-maintained healthy-shard
/// gauge.
pub(crate) struct AdmissionCtl {
    opts: AdmissionOpts,
    /// sum of `max_new` over non-terminal requests — incremented under
    /// the queue lock at admission, decremented at terminalization
    inflight_tokens: AtomicUsize,
    /// driver-updated: the engine's current shard count
    healthy_shards: AtomicUsize,
}

impl AdmissionCtl {
    pub fn new(opts: AdmissionOpts) -> AdmissionCtl {
        AdmissionCtl {
            opts,
            inflight_tokens: AtomicUsize::new(0),
            // optimistic until the driver's first sweep: degradation
            // never fires before the engine has reported its topology
            healthy_shards: AtomicUsize::new(usize::MAX),
        }
    }

    /// Decide one submission.  Call with the queue lock held (so
    /// `queue_depth` cannot be raced past its bound); on `Ok` the
    /// request's `max_new` has been charged to the inflight ledger.
    /// `completed`/`decode_steps` are the drain-rate observations the
    /// retry hint is derived from; `Err` carries the hint plus the
    /// reason the submission was refused.
    pub fn try_admit(
        &self,
        max_new: usize,
        queue_depth: usize,
        completed: usize,
        decode_steps: usize,
    ) -> Result<(), (usize, ShedReason)> {
        if self.tier() >= 1 {
            let hint = retry_after_steps(queue_depth, completed, decode_steps);
            return Err((hint, ShedReason::Degraded));
        }
        if queue_depth >= self.opts.max_queue_depth {
            let hint = retry_after_steps(queue_depth, completed, decode_steps);
            return Err((hint, ShedReason::QueueFull));
        }
        let committed = self.inflight_tokens.load(Ordering::Relaxed);
        if committed.saturating_add(max_new) > self.opts.max_inflight_tokens {
            let hint = retry_after_steps(queue_depth, completed, decode_steps);
            return Err((hint, ShedReason::TokenBudget));
        }
        self.inflight_tokens.fetch_add(max_new, Ordering::Relaxed);
        Ok(())
    }

    /// Release a terminal request's committed tokens.
    pub fn on_terminal(&self, max_new: usize) {
        // saturating: a double-release bug must not wrap the ledger
        let mut cur = self.inflight_tokens.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(max_new);
            match self.inflight_tokens.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    pub fn set_healthy_shards(&self, n: usize) {
        self.healthy_shards.store(n, Ordering::Relaxed);
    }

    /// Current degradation tier: the healthy-shard deficit (0 = none).
    pub fn tier(&self) -> usize {
        self.opts.min_healthy_shards.saturating_sub(self.healthy_shards.load(Ordering::Relaxed))
    }

    /// Committed inflight tokens (diagnostic; tests pin the ledger
    /// returns to 0 after drain).
    pub fn inflight_tokens(&self) -> usize {
        self.inflight_tokens.load(Ordering::Relaxed)
    }
}

/// The deterministic retry hint: how many decode steps until the
/// scheduler has plausibly drained one queue slot, from the observed
/// drain rate (`decode_steps / completed` = steps per retirement).
/// Before any request has completed there is no observation, so the
/// fallback is proportional to the backlog itself (at least 1) — still
/// deterministic, still wall-clock-free.
pub fn retry_after_steps(queue_depth: usize, completed: usize, decode_steps: usize) -> usize {
    if completed == 0 || decode_steps == 0 {
        return queue_depth.max(1);
    }
    // ceil(decode_steps / completed): one retirement's worth of steps
    decode_steps.div_ceil(completed).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_queue_sheds_past_depth() {
        let ctl = AdmissionCtl::new(AdmissionOpts { max_queue_depth: 2, ..Default::default() });
        assert!(ctl.try_admit(4, 0, 0, 0).is_ok());
        assert!(ctl.try_admit(4, 1, 0, 0).is_ok());
        let (hint, reason) = ctl.try_admit(4, 2, 0, 0).unwrap_err();
        assert!(hint >= 1, "shed must always carry a usable hint");
        assert_eq!(reason, ShedReason::QueueFull);
    }

    #[test]
    fn inflight_token_budget_is_charged_and_released() {
        let ctl =
            AdmissionCtl::new(AdmissionOpts { max_inflight_tokens: 10, ..Default::default() });
        assert!(ctl.try_admit(6, 0, 0, 0).is_ok());
        assert_eq!(ctl.inflight_tokens(), 6);
        let (_, reason) = ctl.try_admit(6, 0, 0, 0).unwrap_err();
        assert_eq!(reason, ShedReason::TokenBudget, "6+6 > 10 must shed");
        assert!(ctl.try_admit(4, 0, 0, 0).is_ok());
        assert_eq!(ctl.inflight_tokens(), 10);
        ctl.on_terminal(6);
        assert!(ctl.try_admit(6, 0, 0, 0).is_ok());
        ctl.on_terminal(6);
        ctl.on_terminal(4);
        assert_eq!(ctl.inflight_tokens(), 0);
        // double release saturates instead of wrapping
        ctl.on_terminal(100);
        assert_eq!(ctl.inflight_tokens(), 0);
    }

    #[test]
    fn degradation_tier_follows_healthy_deficit() {
        let ctl = AdmissionCtl::new(AdmissionOpts { min_healthy_shards: 3, ..Default::default() });
        assert_eq!(ctl.tier(), 0, "optimistic before the first driver sweep");
        ctl.set_healthy_shards(3);
        assert_eq!(ctl.tier(), 0);
        assert!(ctl.try_admit(1, 0, 0, 0).is_ok());
        ctl.set_healthy_shards(2);
        assert_eq!(ctl.tier(), 1);
        let (_, reason) = ctl.try_admit(1, 0, 0, 0).unwrap_err();
        assert_eq!(reason, ShedReason::Degraded, "tier 1 sheds new admissions");
        ctl.set_healthy_shards(1);
        assert_eq!(ctl.tier(), 2);
    }

    #[test]
    fn retry_hint_tracks_observed_drain_rate() {
        // no observation yet: backlog-proportional fallback
        assert_eq!(retry_after_steps(0, 0, 0), 1);
        assert_eq!(retry_after_steps(7, 0, 12), 7);
        // observed: ceil(steps per completed request)
        assert_eq!(retry_after_steps(5, 10, 100), 10);
        assert_eq!(retry_after_steps(5, 3, 100), 34);
        assert_eq!(retry_after_steps(5, 100, 7), 1, "fast drain still hints >= 1");
    }

    /// Property sweep over the (queue_depth, completed, decode_steps)
    /// grid, cold-start corners included: the shed hint must always be
    /// finite (usize), nonzero, deterministic call-to-call, and match
    /// the documented two-regime formula exactly.
    #[test]
    fn retry_hint_holds_over_the_input_grid() {
        const DEPTHS: &[usize] = &[0, 1, 2, 7, 63, 1024, usize::MAX / 2];
        const COMPLETED: &[usize] = &[0, 1, 2, 5, 100, 10_000];
        const STEPS: &[usize] = &[0, 1, 2, 9, 1_000, 1_000_000];
        for &q in DEPTHS {
            for &c in COMPLETED {
                for &s in STEPS {
                    let hint = retry_after_steps(q, c, s);
                    assert!(hint >= 1, "zero hint at q={q} c={c} s={s}");
                    assert_eq!(
                        hint,
                        retry_after_steps(q, c, s),
                        "hint must be deterministic at q={q} c={c} s={s}"
                    );
                    let want = if c == 0 || s == 0 { q.max(1) } else { s.div_ceil(c).max(1) };
                    assert_eq!(hint, want, "regime mismatch at q={q} c={c} s={s}");
                }
            }
        }
    }
}
