//! Serve-level counters — the observability face of the scheduler:
//! request lifecycle tallies, queue depth, time-to-first-token, token
//! throughput, and the per-shard decode-arena fresh-alloc gauges
//! (which must stay 0 in steady state, same contract as the engine's
//! `decode_arena_fresh_allocs`).
//!
//! Everything is lock-free atomics except the TTFT reservoir (a short
//! mutex-guarded vec; one push per request, read only at snapshot
//! time), so the driver's hot loop pays near nothing.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

pub struct ServeMetrics {
    submitted: AtomicUsize,
    completed: AtomicUsize,
    cancelled: AtomicUsize,
    failed: AtomicUsize,
    /// requests grafted into an in-flight batch between decode steps
    /// (the continuous-batching path, as opposed to riding a freshly
    /// formed batch)
    fused_admissions: AtomicUsize,
    tokens: AtomicUsize,
    decode_steps: AtomicUsize,
    queue_depth: AtomicUsize,
    ttft_ms: Mutex<Vec<f64>>,
    shard_fresh_allocs: Mutex<Vec<usize>>,
    started: Instant,
}

/// A plain-data copy of the counters at one instant.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: usize,
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub fused_admissions: usize,
    pub tokens: usize,
    pub decode_steps: usize,
    pub queue_depth: usize,
    pub p50_ttft_ms: f64,
    pub mean_ttft_ms: f64,
    pub elapsed_s: f64,
    pub tokens_per_s: f64,
    pub shard_fresh_allocs: Vec<usize>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            submitted: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            cancelled: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            fused_admissions: AtomicUsize::new(0),
            tokens: AtomicUsize::new(0),
            decode_steps: AtomicUsize::new(0),
            queue_depth: AtomicUsize::new(0),
            ttft_ms: Mutex::new(Vec::new()),
            shard_fresh_allocs: Mutex::new(Vec::new()),
            started: Instant::now(),
        }
    }

    pub fn inc_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_fused(&self) {
        self.fused_admissions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_tokens(&self, n: usize) {
        self.tokens.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_decode_steps(&self) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    pub fn record_ttft_ms(&self, ms: f64) {
        self.ttft_ms.lock().unwrap().push(ms);
    }

    pub fn set_shard_fresh_allocs(&self, allocs: Vec<usize>) {
        *self.shard_fresh_allocs.lock().unwrap() = allocs;
    }

    pub fn fused_admissions(&self) -> usize {
        self.fused_admissions.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let ttft = self.ttft_ms.lock().unwrap().clone();
        let (p50, mean) = percentile_and_mean(&ttft);
        let tokens = self.tokens.load(Ordering::Relaxed);
        let elapsed_s = self.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            fused_admissions: self.fused_admissions.load(Ordering::Relaxed),
            tokens,
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            p50_ttft_ms: p50,
            mean_ttft_ms: mean,
            elapsed_s,
            tokens_per_s: if elapsed_s > 0.0 { tokens as f64 / elapsed_s } else { 0.0 },
            shard_fresh_allocs: self.shard_fresh_allocs.lock().unwrap().clone(),
        }
    }
}

/// (p50, mean) of a sample; (0, 0) when empty.  The median of an even
/// count takes the lower-middle element — deterministic and fine at
/// trace sizes.
fn percentile_and_mean(samples: &[f64]) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let p50 = sorted[(sorted.len() - 1) / 2];
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    (p50, mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = ServeMetrics::new();
        for _ in 0..3 {
            m.inc_submitted();
        }
        m.inc_completed();
        m.inc_cancelled();
        m.inc_fused();
        m.add_tokens(42);
        m.inc_decode_steps();
        m.set_queue_depth(2);
        m.record_ttft_ms(10.0);
        m.record_ttft_ms(30.0);
        m.record_ttft_ms(20.0);
        m.set_shard_fresh_allocs(vec![0, 0]);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.fused_admissions, 1);
        assert_eq!(s.tokens, 42);
        assert_eq!(s.decode_steps, 1);
        assert_eq!(s.queue_depth, 2);
        assert_eq!(s.p50_ttft_ms, 20.0);
        assert!((s.mean_ttft_ms - 20.0).abs() < 1e-9);
        assert_eq!(s.shard_fresh_allocs, vec![0, 0]);
        assert!(s.tokens_per_s >= 0.0);
    }

    #[test]
    fn empty_ttft_is_zero_not_nan() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.p50_ttft_ms, 0.0);
        assert_eq!(s.mean_ttft_ms, 0.0);
        assert_eq!(s.tokens_per_s, 0.0);
    }

    #[test]
    fn p50_even_count_takes_lower_middle() {
        assert_eq!(percentile_and_mean(&[4.0, 1.0, 3.0, 2.0]).0, 2.0);
        assert_eq!(percentile_and_mean(&[5.0]).0, 5.0);
    }
}
