//! Serve-level counters — the observability face of the scheduler:
//! request lifecycle tallies, queue depth, time-to-first-token, token
//! throughput, fault-tolerance counters (shard reroutes), the
//! speculative-admission counters, and the per-shard decode-arena
//! fresh-alloc gauges (which must stay 0 in steady state, same
//! contract as the engine's `decode_arena_fresh_allocs`).
//!
//! Everything is lock-free: the latency distributions (ttft,
//! queue-wait, per-decode-step, recovery-stall) are `obs::Log2Hist`
//! fixed-bucket histograms — bounded memory however many requests pass
//! (the unbounded mutex-guarded TTFT sample vec they replaced grew one
//! `f64` per request forever), recordable from the driver's hot loop,
//! and snapshotted as mergeable `HistSnapshot`s with p50/p99/p999.

// entlint: allow-file(ordering-audit) — this module is nothing but independent
// monotonic counters and point-in-time gauges; no cross-variable ordering
// invariants exist here, so Relaxed is correct at every site
use crate::obs::{HistSnapshot, Log2Hist, Stopwatch};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

pub struct ServeMetrics {
    submitted: AtomicUsize,
    completed: AtomicUsize,
    cancelled: AtomicUsize,
    failed: AtomicUsize,
    /// requests grafted into an in-flight batch between decode steps
    /// (the continuous-batching path, as opposed to riding a freshly
    /// formed batch)
    fused_admissions: AtomicUsize,
    /// fused admissions served from the speculative slot — the prefill
    /// (and catch-up) ran *before* a lane freed, so adoption cost
    /// nothing at the moment of adoption
    speculative_admissions: AtomicUsize,
    /// solo catch-up decode steps run at adoption time (after a lane
    /// freed); 0 for speculative adoptions — the zero-cost property the
    /// serve tests pin
    adoption_catchup_steps: AtomicUsize,
    /// solo prefills run at adoption time (after a lane freed); 0 for
    /// speculative adoptions
    adoption_prefills: AtomicUsize,
    /// shard failures rerouted onto surviving engines (the interrupted
    /// step was replayed; in-flight requests kept their trajectories)
    reroutes: AtomicUsize,
    /// replacement shards that rejoined after a reroute (a merged range
    /// re-split, the topology expanded back toward its target)
    rejoins: AtomicUsize,
    /// submissions refused by admission control (bounded queue /
    /// inflight-token budget / degradation tier) — every shed response
    /// carries a `retry_after_steps` hint
    shed: AtomicUsize,
    /// requests that hit their step-budget deadline (tick-counted, never
    /// wall-clock) and were expired between decode steps
    expired: AtomicUsize,
    /// supervisor rejoin attempts that failed and were re-scheduled
    /// under tick-counted exponential backoff
    backoff_retries: AtomicUsize,
    /// gauge: shards the supervisor currently counts Healthy
    healthy_shards: AtomicUsize,
    /// gauge: shards currently Degraded (failed, below evict threshold)
    degraded_shards: AtomicUsize,
    /// cumulative shards evicted (rerouted away by the supervisor)
    evicted_shards: AtomicUsize,
    /// gauge: current degradation tier (0 = none; 1 = shedding new
    /// admissions; >= 2 = also shrinking max batch)
    degradation_tier: AtomicUsize,
    /// wall time spent inside successful recoveries (reroute splices) —
    /// the recovery-stall series `benches/serve.rs` tracks, in µs
    recovery_stall_us: AtomicU64,
    /// gauge: max distinct storage copies of any compressed block
    /// across the engine's containers and shard slices; Arc-backed
    /// sharing keeps this at exactly 1 (the one-copy invariant)
    weight_copies: AtomicUsize,
    /// gauge: resident compressed bytes, deduplicated by storage
    resident_compressed_bytes: AtomicUsize,
    /// gauge: blocks spliced into survivors by reroutes so far
    recovery_spliced_blocks: AtomicUsize,
    /// gauge: raw f32 bytes the in-flight KV caches would occupy
    kv_raw_bytes: AtomicUsize,
    /// gauge: bytes the in-flight KV caches actually hold resident
    /// (equal to raw under `KvMode::Raw`; lossless window plus coded
    /// tail when packed)
    kv_resident_bytes: AtomicUsize,
    /// gauge: entropy-coded tail bytes within `kv_resident_bytes`
    kv_compressed_bytes: AtomicUsize,
    /// high-water mark of `kv_resident_bytes` (the current gauge drops
    /// to 0 between batches; end-of-run reports read the peak)
    kv_peak_resident_bytes: AtomicUsize,
    tokens: AtomicUsize,
    decode_steps: AtomicUsize,
    queue_depth: AtomicUsize,
    /// occupied lanes of the in-flight batch (gauge; must return to 0
    /// once every request is terminal — the lane-leak check)
    inflight_lanes: AtomicUsize,
    /// time-to-first-token distribution in µs — bounded log2 buckets,
    /// the unbounded per-request sample vec's successor
    ttft_us: Log2Hist,
    /// decode steps spent queued before entering a batch (tick domain)
    queue_wait_steps: Log2Hist,
    /// wall µs per driver decode step (annotation only)
    step_us: Log2Hist,
    /// wall µs per successful recovery splice (annotation only)
    recovery_stall_dist_us: Log2Hist,
    shard_fresh_allocs: Mutex<Vec<usize>>,
    started: Stopwatch,
}

/// A plain-data copy of the counters at one instant.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: usize,
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub fused_admissions: usize,
    pub speculative_admissions: usize,
    pub adoption_catchup_steps: usize,
    pub adoption_prefills: usize,
    pub reroutes: usize,
    pub rejoins: usize,
    pub shed: usize,
    pub expired: usize,
    pub backoff_retries: usize,
    pub healthy_shards: usize,
    pub degraded_shards: usize,
    pub evicted_shards: usize,
    pub degradation_tier: usize,
    pub recovery_stall_ms: f64,
    pub weight_copies: usize,
    pub resident_compressed_bytes: usize,
    pub recovery_spliced_blocks: usize,
    pub kv_resident_bytes: usize,
    pub kv_compressed_bytes: usize,
    pub kv_peak_resident_bytes: usize,
    /// raw-over-resident KV footprint ratio (1.0 when nothing is
    /// in flight or the caches are uncompressed)
    pub kv_compression_ratio: f64,
    pub tokens: usize,
    pub decode_steps: usize,
    pub queue_depth: usize,
    pub inflight_lanes: usize,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub p999_ttft_ms: f64,
    pub mean_ttft_ms: f64,
    pub p50_step_us: f64,
    pub p99_step_us: f64,
    pub p999_step_us: f64,
    pub mean_step_us: f64,
    pub p50_queue_wait_steps: u64,
    pub p99_queue_wait_steps: u64,
    /// full mergeable distributions (bucket counts + exact count/sum/max)
    pub ttft_hist: HistSnapshot,
    pub queue_wait_hist: HistSnapshot,
    pub step_hist: HistSnapshot,
    pub recovery_stall_hist: HistSnapshot,
    pub elapsed_s: f64,
    pub tokens_per_s: f64,
    pub shard_fresh_allocs: Vec<usize>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            submitted: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            cancelled: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            fused_admissions: AtomicUsize::new(0),
            speculative_admissions: AtomicUsize::new(0),
            adoption_catchup_steps: AtomicUsize::new(0),
            adoption_prefills: AtomicUsize::new(0),
            reroutes: AtomicUsize::new(0),
            rejoins: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
            backoff_retries: AtomicUsize::new(0),
            healthy_shards: AtomicUsize::new(0),
            degraded_shards: AtomicUsize::new(0),
            evicted_shards: AtomicUsize::new(0),
            degradation_tier: AtomicUsize::new(0),
            recovery_stall_us: AtomicU64::new(0),
            // one logical copy is the ground state even before the
            // driver's first gauge sweep
            weight_copies: AtomicUsize::new(1),
            resident_compressed_bytes: AtomicUsize::new(0),
            recovery_spliced_blocks: AtomicUsize::new(0),
            kv_raw_bytes: AtomicUsize::new(0),
            kv_resident_bytes: AtomicUsize::new(0),
            kv_compressed_bytes: AtomicUsize::new(0),
            kv_peak_resident_bytes: AtomicUsize::new(0),
            tokens: AtomicUsize::new(0),
            decode_steps: AtomicUsize::new(0),
            queue_depth: AtomicUsize::new(0),
            inflight_lanes: AtomicUsize::new(0),
            ttft_us: Log2Hist::new(),
            queue_wait_steps: Log2Hist::new(),
            step_us: Log2Hist::new(),
            recovery_stall_dist_us: Log2Hist::new(),
            shard_fresh_allocs: Mutex::new(Vec::new()),
            started: Stopwatch::start(),
        }
    }

    pub fn inc_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_fused(&self) {
        self.fused_admissions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_speculative(&self) {
        self.speculative_admissions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_adoption_catchup_steps(&self, n: usize) {
        self.adoption_catchup_steps.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_adoption_prefills(&self) {
        self.adoption_prefills.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_reroutes(&self) {
        self.reroutes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_rejoins(&self) {
        self.rejoins.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Swept from `StepEngine::backoff_retries` by the driver (the
    /// supervisor owns the authoritative count), hence a set not an inc.
    pub fn set_backoff_retries(&self, n: usize) {
        self.backoff_retries.store(n, Ordering::Relaxed);
    }

    /// Supervisor health gauges in one sweep (healthy/degraded are
    /// point-in-time; evicted is a cumulative tally).
    pub fn set_shard_health(&self, healthy: usize, degraded: usize, evicted: usize) {
        self.healthy_shards.store(healthy, Ordering::Relaxed);
        self.degraded_shards.store(degraded, Ordering::Relaxed);
        self.evicted_shards.store(evicted, Ordering::Relaxed);
    }

    pub fn set_degradation_tier(&self, tier: usize) {
        self.degradation_tier.store(tier, Ordering::Relaxed);
    }

    pub fn add_recovery_stall_us(&self, us: u64) {
        self.recovery_stall_us.fetch_add(us, Ordering::Relaxed);
        self.recovery_stall_dist_us.record(us);
    }

    pub fn set_weight_copies(&self, copies: usize) {
        self.weight_copies.store(copies, Ordering::Relaxed);
    }

    pub fn set_resident_compressed_bytes(&self, bytes: usize) {
        self.resident_compressed_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn set_recovery_spliced_blocks(&self, blocks: usize) {
        self.recovery_spliced_blocks.store(blocks, Ordering::Relaxed);
    }

    /// Gauge sweep of the in-flight KV-cache byte accounting: the
    /// scheduler driver sums `DecodeState::kv_bytes` across every
    /// in-flight and speculative state each tick and stores the totals
    /// here.
    pub fn set_kv_bytes(&self, raw: usize, resident: usize, compressed: usize) {
        self.kv_raw_bytes.store(raw, Ordering::Relaxed);
        self.kv_resident_bytes.store(resident, Ordering::Relaxed);
        self.kv_compressed_bytes.store(compressed, Ordering::Relaxed);
        self.kv_peak_resident_bytes.fetch_max(resident, Ordering::Relaxed);
    }

    pub fn add_tokens(&self, n: usize) {
        self.tokens.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_decode_steps(&self) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    pub fn set_inflight_lanes(&self, lanes: usize) {
        self.inflight_lanes.store(lanes, Ordering::Relaxed);
    }

    pub fn record_ttft_ms(&self, ms: f64) {
        self.ttft_us.record((ms * 1e3).max(0.0) as u64);
    }

    /// Decode steps a request waited in the queue before entering a
    /// batch (tick domain — deterministic under replay).
    pub fn record_queue_wait_steps(&self, steps: u64) {
        self.queue_wait_steps.record(steps);
    }

    /// Wall µs one driver decode step took (annotation only).
    pub fn record_step_us(&self, us: u64) {
        self.step_us.record(us);
    }

    /// Gauge sweep into the retained buffer: no allocation once its
    /// capacity covers the shard count (the driver passes a scratch
    /// slice it also reuses — no per-tick Vec changes hands).
    pub fn set_shard_fresh_allocs(&self, allocs: &[usize]) {
        let mut g = self.shard_fresh_allocs.lock().unwrap();
        g.clear();
        g.extend_from_slice(allocs);
    }

    pub fn fused_admissions(&self) -> usize {
        self.fused_admissions.load(Ordering::Relaxed)
    }

    /// Completed-request tally — one half of the observed drain rate the
    /// admission controller derives `retry_after_steps` from.
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::Relaxed)
    }

    /// Decode-step tally — the scheduler's deterministic clock: step
    /// budgets and shed retry hints are denominated in it (never wall
    /// time, so replay and the entlint `no-wallclock-in-replay` rule
    /// both survive).
    pub fn decode_steps(&self) -> usize {
        self.decode_steps.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let kv_resident = self.kv_resident_bytes.load(Ordering::Relaxed);
        let ttft = self.ttft_us.snapshot();
        let step = self.step_us.snapshot();
        let queue_wait = self.queue_wait_steps.snapshot();
        let recovery = self.recovery_stall_dist_us.snapshot();
        let tokens = self.tokens.load(Ordering::Relaxed);
        let elapsed_s = self.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            fused_admissions: self.fused_admissions.load(Ordering::Relaxed),
            speculative_admissions: self.speculative_admissions.load(Ordering::Relaxed),
            adoption_catchup_steps: self.adoption_catchup_steps.load(Ordering::Relaxed),
            adoption_prefills: self.adoption_prefills.load(Ordering::Relaxed),
            reroutes: self.reroutes.load(Ordering::Relaxed),
            rejoins: self.rejoins.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            backoff_retries: self.backoff_retries.load(Ordering::Relaxed),
            healthy_shards: self.healthy_shards.load(Ordering::Relaxed),
            degraded_shards: self.degraded_shards.load(Ordering::Relaxed),
            evicted_shards: self.evicted_shards.load(Ordering::Relaxed),
            degradation_tier: self.degradation_tier.load(Ordering::Relaxed),
            recovery_stall_ms: self.recovery_stall_us.load(Ordering::Relaxed) as f64 / 1e3,
            weight_copies: self.weight_copies.load(Ordering::Relaxed),
            resident_compressed_bytes: self.resident_compressed_bytes.load(Ordering::Relaxed),
            recovery_spliced_blocks: self.recovery_spliced_blocks.load(Ordering::Relaxed),
            kv_resident_bytes: kv_resident,
            kv_compressed_bytes: self.kv_compressed_bytes.load(Ordering::Relaxed),
            kv_peak_resident_bytes: self.kv_peak_resident_bytes.load(Ordering::Relaxed),
            kv_compression_ratio: if kv_resident > 0 {
                self.kv_raw_bytes.load(Ordering::Relaxed) as f64 / kv_resident as f64
            } else {
                1.0
            },
            tokens,
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            inflight_lanes: self.inflight_lanes.load(Ordering::Relaxed),
            p50_ttft_ms: ttft.percentile(0.5) as f64 / 1e3,
            p99_ttft_ms: ttft.percentile(0.99) as f64 / 1e3,
            p999_ttft_ms: ttft.percentile(0.999) as f64 / 1e3,
            mean_ttft_ms: ttft.mean() / 1e3,
            p50_step_us: step.percentile(0.5) as f64,
            p99_step_us: step.percentile(0.99) as f64,
            p999_step_us: step.percentile(0.999) as f64,
            mean_step_us: step.mean(),
            p50_queue_wait_steps: queue_wait.percentile(0.5),
            p99_queue_wait_steps: queue_wait.percentile(0.99),
            ttft_hist: ttft,
            queue_wait_hist: queue_wait,
            step_hist: step,
            recovery_stall_hist: recovery,
            elapsed_s,
            tokens_per_s: if elapsed_s > 0.0 { tokens as f64 / elapsed_s } else { 0.0 },
            shard_fresh_allocs: self.shard_fresh_allocs.lock().unwrap().clone(),
        }
    }
}

/// Nearest-rank percentile of an unsorted sample: the smallest element
/// whose rank is `>= ceil(q * n)` (rank 1-based), i.e. the
/// `ceil(q*n)`-th order statistic.  Always an actual sample (no
/// interpolation), deterministic, and well-defined at the edges:
/// empty -> 0.0, a single sample -> that sample, `q <= 0` -> the
/// minimum, `q >= 1` -> the maximum.  For `q = 0.5` over an even count
/// this is the LOWER middle element.  This is also the exact reference
/// the `obs::Log2Hist` bucket quantiles are property-tested against
/// (rust/tests/obs.rs): the histogram reports a bucket upper bound
/// within 1/32 relative of this function's answer on the same samples.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as isize; // 1-based
    let idx = rank.clamp(1, n as isize) as usize - 1;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = ServeMetrics::new();
        for _ in 0..3 {
            m.inc_submitted();
        }
        m.inc_completed();
        m.inc_cancelled();
        m.inc_fused();
        m.inc_speculative();
        m.add_adoption_catchup_steps(4);
        m.inc_adoption_prefills();
        m.inc_reroutes();
        m.inc_rejoins();
        m.inc_shed();
        m.inc_shed();
        m.inc_expired();
        m.set_backoff_retries(1);
        m.set_shard_health(2, 1, 1);
        m.set_degradation_tier(1);
        m.add_recovery_stall_us(2500);
        m.set_weight_copies(1);
        m.set_resident_compressed_bytes(4096);
        m.set_recovery_spliced_blocks(3);
        m.set_kv_bytes(12000, 4000, 3000);
        m.add_tokens(42);
        m.inc_decode_steps();
        m.set_queue_depth(2);
        m.set_inflight_lanes(3);
        m.record_ttft_ms(10.0);
        m.record_ttft_ms(30.0);
        m.record_ttft_ms(20.0);
        m.record_step_us(1000);
        m.record_queue_wait_steps(3);
        m.set_shard_fresh_allocs(&[0, 0]);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.fused_admissions, 1);
        assert_eq!(s.speculative_admissions, 1);
        assert_eq!(s.adoption_catchup_steps, 4);
        assert_eq!(s.adoption_prefills, 1);
        assert_eq!(s.reroutes, 1);
        assert_eq!(s.rejoins, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.expired, 1);
        assert_eq!(s.backoff_retries, 1);
        assert_eq!(s.healthy_shards, 2);
        assert_eq!(s.degraded_shards, 1);
        assert_eq!(s.evicted_shards, 1);
        assert_eq!(s.degradation_tier, 1);
        assert!((s.recovery_stall_ms - 2.5).abs() < 1e-9);
        assert_eq!(s.weight_copies, 1);
        assert_eq!(s.resident_compressed_bytes, 4096);
        assert_eq!(s.recovery_spliced_blocks, 3);
        assert_eq!(s.kv_resident_bytes, 4000);
        assert_eq!(s.kv_compressed_bytes, 3000);
        assert_eq!(s.kv_peak_resident_bytes, 4000);
        assert!((s.kv_compression_ratio - 3.0).abs() < 1e-9);
        assert_eq!(s.tokens, 42);
        assert_eq!(s.decode_steps, 1);
        assert_eq!(s.queue_depth, 2);
        assert_eq!(s.inflight_lanes, 3);
        // histogram quantiles are bucket-quantised: within 1/32 relative
        assert!((s.p50_ttft_ms - 20.0).abs() <= 20.0 / 32.0 + 1e-9, "p50 {}", s.p50_ttft_ms);
        // the top-ranked sample clamps to the exact recorded max
        assert_eq!(s.p99_ttft_ms, 30.0);
        assert_eq!(s.p999_ttft_ms, 30.0);
        // the mean is exact: it comes from the histogram's running sum
        assert!((s.mean_ttft_ms - 20.0).abs() < 1e-9);
        assert_eq!(s.p50_step_us, 1000.0); // single sample: max-clamped, exact
        assert_eq!(s.p50_queue_wait_steps, 3); // below 32: exact bucket
        assert_eq!(s.ttft_hist.count, 3);
        assert_eq!(s.step_hist.count, 1);
        assert_eq!(s.queue_wait_hist.count, 1);
        assert_eq!(s.recovery_stall_hist.count, 1);
        assert_eq!(s.shard_fresh_allocs, vec![0, 0]);
        assert!(s.tokens_per_s >= 0.0);
    }

    #[test]
    fn empty_ttft_is_zero_not_nan() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.p50_ttft_ms, 0.0);
        assert_eq!(s.mean_ttft_ms, 0.0);
        assert_eq!(s.tokens_per_s, 0.0);
        assert!(s.p50_ttft_ms.is_finite() && s.mean_ttft_ms.is_finite());
    }

    #[test]
    fn single_sample_is_its_own_p50_and_mean() {
        let m = ServeMetrics::new();
        m.record_ttft_ms(7.5);
        let s = m.snapshot();
        assert_eq!(s.p50_ttft_ms, 7.5);
        assert_eq!(s.mean_ttft_ms, 7.5);
    }

    #[test]
    fn p50_even_count_takes_lower_middle() {
        assert_eq!(percentile(&[4.0, 1.0, 3.0, 2.0], 0.5), 2.0);
        assert_eq!(percentile(&[5.0], 0.5), 5.0);
    }

    #[test]
    fn percentile_nearest_rank_semantics() {
        let s = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&s, 0.5), 30.0); // odd count: true median
        assert_eq!(percentile(&s, 0.0), 10.0); // clamped to the minimum
        assert_eq!(percentile(&s, 1.0), 50.0); // the maximum
        assert_eq!(percentile(&s, 0.9), 50.0); // ceil(4.5) = rank 5
        assert_eq!(percentile(&s, 0.2), 10.0); // ceil(1.0) = rank 1
        assert_eq!(percentile(&[], 0.5), 0.0); // empty: 0, not NaN
        // out-of-range q is clamped, not a panic or index error
        assert_eq!(percentile(&s, -1.0), 10.0);
        assert_eq!(percentile(&s, 2.0), 50.0);
        // unsorted input sorts internally
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 0.5), 2.0);
    }
}
